//! NLP continual learning (paper §V-B2 / Table IV): the bert proxy on the
//! 20News-style benchmark — 10 scenarios of 2 topic classes each — plus the
//! semi-supervised mode (paper §IV-C): only 10% of the stream is labeled,
//! the rest trains through the SimSiam self-supervised artifact.
//!
//!     cargo run --release --example nlp_streaming

use etuner::prelude::*;

fn main() -> anyhow::Result<()> {
    let be = BackendSpec::auto(etuner::testkit::artifacts_dir()).create()?;

    println!("-- fully supervised (Table IV shape) --");
    for (name, tune, freeze) in [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("LazyTune", TunePolicyKind::LazyTune, FreezePolicyKind::None),
        ("SimFreeze", TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ] {
        let mut cfg = RunConfig::quickstart("bert", Benchmark::News20)
            .with_policies(tune, freeze);
        cfg.n_requests = 200;
        let r = Simulation::new(be.as_ref(), cfg)?.run()?;
        println!(
            "  {name:<10} acc {:.2}%  time {:.1}min  energy {:.2}Wh",
            r.avg_inference_accuracy * 100.0,
            r.energy.total_s() / 60.0,
            r.energy.total_wh(),
        );
    }

    println!("-- semi-supervised CV (Table VI shape): 10% labels, mbv2/NC --");
    for (name, tune, freeze) in [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ] {
        let mut cfg = RunConfig::quickstart("mbv2", Benchmark::Nc)
            .with_policies(tune, freeze);
        cfg.labeled_fraction = Some(0.1);
        cfg.n_requests = 200;
        let r = Simulation::new(be.as_ref(), cfg)?.run()?;
        println!(
            "  {name:<10} acc {:.2}%  energy {:.2}Wh",
            r.avg_inference_accuracy * 100.0,
            r.energy.total_wh(),
        );
    }
    Ok(())
}
