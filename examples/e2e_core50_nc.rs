//! End-to-end validation driver (DESIGN.md's required full-system run):
//! res50 on the NC benchmark — 8 continual scenarios, 240 training batches
//! (3 840 samples through the AOT train artifacts), 300 inference requests,
//! all four methods — logging the per-round validation-accuracy curve and
//! the final paper-shaped comparison.  Results recorded in EXPERIMENTS.md
//! §End-to-end.
//!
//!     cargo run --release --example e2e_core50_nc

use etuner::prelude::*;

fn main() -> anyhow::Result<()> {
    let be = BackendSpec::auto(etuner::testkit::artifacts_dir()).create()?;
    let methods = [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("LazyTune", TunePolicyKind::LazyTune, FreezePolicyKind::None),
        ("SimFreeze", TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ];
    let mut rows = Vec::new();
    for (name, tune, freeze) in methods {
        let mut cfg = RunConfig::quickstart("res50", Benchmark::Nc)
            .with_policies(tune, freeze);
        cfg.n_requests = 300;
        println!("=== {name} ===");
        let r = Simulation::new(be.as_ref(), cfg)?.run()?;
        // loss/accuracy curve: one line per fine-tuning round
        println!("round  t        scen  merged  frozen  val_acc");
        for (i, rr) in r.round_log.iter().enumerate() {
            if i % 8 == 0 || i + 1 == r.round_log.len() {
                println!(
                    "{:>5}  {:>7.0}  {:>4}  {:>6}  {:>6}  {:>6.3}",
                    i, rr.t, rr.scenario, rr.batches, rr.frozen_units, rr.val_acc
                );
            }
        }
        println!(
            "{name}: acc {:.2}%  time {:.0}s  energy {:.2}Wh  rounds {}  \
             changes detected {}  wall {:.1}s\n",
            r.avg_inference_accuracy * 100.0,
            r.energy.total_s(),
            r.energy.total_wh(),
            r.rounds,
            r.scenario_changes_detected,
            r.wall_exec_s,
        );
        rows.push((name, r));
    }

    let base = rows[0].1.energy.total_s();
    let base_j = rows[0].1.energy.total_j();
    let base_a = rows[0].1.avg_inference_accuracy;
    println!("summary (vs Immed.):");
    for (name, r) in &rows {
        println!(
            "  {name:<10} time x{:.2}  energy x{:.2}  acc {:+.2}%",
            r.energy.total_s() / base,
            r.energy.total_j() / base_j,
            (r.avg_inference_accuracy - base_a) * 100.0,
        );
    }
    Ok(())
}
