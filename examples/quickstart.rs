//! Quickstart: deploy a model, run one continual-learning benchmark under
//! ETuner (LazyTune + SimFreeze), and compare against immediate
//! fine-tuning.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full stack: the rust coordinator triggers fine-tuning
//! rounds, every train/infer/CKA step executes an AOT-compiled JAX/Pallas
//! artifact through PJRT, and costs are charged to the Jetson-scale device
//! model.

use etuner::prelude::*;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(etuner::testkit::artifacts_dir())?;

    // Immediate fine-tuning baseline: a round per arriving batch.
    let immediate = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
    // ETuner: lazy round merging + CKA-guided layer freezing.
    let etuner = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);

    println!("running immediate fine-tuning baseline ...");
    let base = Simulation::new(&rt, immediate)?.run()?;
    println!("  {}", base.summary());

    println!("running ETuner ...");
    let ours = Simulation::new(&rt, etuner)?.run()?;
    println!("  {}", ours.summary());

    let dt = 1.0 - ours.energy.total_s() / base.energy.total_s();
    let de = 1.0 - ours.energy.total_j() / base.energy.total_j();
    let da = (ours.avg_inference_accuracy - base.avg_inference_accuracy) * 100.0;
    println!("\nETuner vs immediate fine-tuning:");
    println!("  fine-tuning time   -{:.0}%", dt * 100.0);
    println!("  energy             -{:.0}%", de * 100.0);
    println!("  avg inference acc  {:+.2}%", da);
    println!(
        "  rounds {} -> {}  (delayed & merged)",
        base.rounds, ours.rounds
    );
    Ok(())
}
