//! Quickstart: deploy a model, run one continual-learning benchmark under
//! ETuner (LazyTune + SimFreeze), and compare against immediate
//! fine-tuning.
//!
//!     cargo run --release --example quickstart
//!
//! This exercises the full stack: the rust coordinator triggers fine-tuning
//! rounds, every train/infer/CKA step executes through the auto-selected
//! backend (the AOT-compiled JAX/Pallas artifacts over PJRT after `make
//! artifacts` + `--features xla`; the pure-rust reference executor
//! otherwise — no build-time dependencies at all), and costs are charged
//! to the Jetson-scale device model.

use etuner::prelude::*;

fn main() -> anyhow::Result<()> {
    let be = BackendSpec::auto(etuner::testkit::artifacts_dir()).create()?;

    // Immediate fine-tuning baseline: a round per arriving batch.
    let immediate = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
    // ETuner: lazy round merging + CKA-guided layer freezing.
    let etuner = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);

    println!("running immediate fine-tuning baseline ...");
    let base = Simulation::new(be.as_ref(), immediate)?.run()?;
    println!("  {}", base.summary());

    println!("running ETuner ...");
    let ours = Simulation::new(be.as_ref(), etuner)?.run()?;
    println!("  {}", ours.summary());

    let dt = 1.0 - ours.energy.total_s() / base.energy.total_s();
    let de = 1.0 - ours.energy.total_j() / base.energy.total_j();
    let da = (ours.avg_inference_accuracy - base.avg_inference_accuracy) * 100.0;
    println!("\nETuner vs immediate fine-tuning:");
    println!("  fine-tuning time   -{:.0}%", dt * 100.0);
    println!("  energy             -{:.0}%", de * 100.0);
    println!("  avg inference acc  {:+.2}%", da);
    println!(
        "  rounds {} -> {}  (delayed & merged)",
        base.rounds, ours.rounds
    );
    Ok(())
}
