//! Robot-assisted eldercare scenario (the paper's §I motivation): an
//! object-recognition model on a home robot sees *bursty* inference
//! requests (the resident interacts in sessions) while the home's
//! appearance drifts (lighting, furniture).  Uses the bursty real-shaped
//! trace for requests, the NICv2-79 mixed schedule for drift, and compares
//! ETuner against immediate fine-tuning on the battery-relevant metric
//! (energy), plus the freshness metric LazyTune trades on: how many
//! requests were served while training data was still buffered.
//!
//!     cargo run --release --example robot_deployment

use etuner::prelude::*;

fn main() -> anyhow::Result<()> {
    let be = BackendSpec::auto(etuner::testkit::artifacts_dir()).create()?;
    for (name, tune, freeze) in [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ] {
        let mut cfg = RunConfig::quickstart("mbv2", Benchmark::Nic79)
            .with_policies(tune, freeze);
        cfg.infer_arrival = ArrivalKind::Trace; // bursty interaction sessions
        cfg.n_requests = 300;
        let r = Simulation::new(be.as_ref(), cfg)?.run()?;
        let stale: usize = r.requests.iter().map(|q| q.stale_batches).sum();
        let burst_acc: f64 = {
            // accuracy inside bursts (requests < 30 virtual seconds apart)
            let mut in_burst = vec![];
            for w in r.requests.windows(2) {
                if w[1].t - w[0].t < 30.0 {
                    in_burst.push(w[1].accuracy as f64);
                }
            }
            in_burst.iter().sum::<f64>() / in_burst.len().max(1) as f64
        };
        println!(
            "{name:<8} acc {:.2}% (bursts {:.2}%)  energy {:.2}Wh  \
             rounds {}  avg staleness {:.2} batches",
            r.avg_inference_accuracy * 100.0,
            burst_acc * 100.0,
            r.energy.total_wh(),
            r.rounds,
            stale as f64 / r.requests.len() as f64,
        );
    }
    println!(
        "\nLazyTune's request-pressure decay keeps burst accuracy close to\n\
         immediate fine-tuning while cutting the battery cost."
    );
    Ok(())
}
