"""AOT compiler: lower every artifact to HLO *text* + emit the manifest.

Run once at build time (``make artifacts``); the rust coordinator then loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
touches python again.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate binds)
rejects (``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs:
    artifacts/<name>.hlo.txt        one per artifact (see DESIGN.md table)
    artifacts/<model>_theta0.bin    raw little-endian f32 initial params
    artifacts/manifest.json         everything rust needs: artifact names +
                                    signatures, flat-theta tensor layout,
                                    freeze-unit segments, paper-scale
                                    per-unit cost anchors
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import cka as cka_kernel

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def shape(s, dtype=F32):
    return jax.ShapeDtypeStruct(s, dtype)


# ---------------------------------------------------------------------------
# Paper-scale cost anchors (per freeze unit), carried into the manifest.
#
# The rust cost model charges time/energy as if the artifact were the real
# model on the Jetson: per-image forward FLOPs and per-unit parameter bytes
# are distributed over [embed, block_1..L, head].  Depth profiles follow the
# real networks coarsely: stem/embedding ~5-8% of FLOPs, head ~1-2%, blocks
# split the rest with later blocks slightly heavier (channel growth).
# ---------------------------------------------------------------------------

def paper_unit_costs(spec: M.ModelSpec):
    L = spec.blocks
    fwd_total = spec.paper_fwd_gflops * 1e9          # FLOPs per image fwd
    bytes_total = spec.paper_params_mb * 1e6         # param bytes
    embed_frac, head_frac = 0.07, 0.02
    rest = 1.0 - embed_frac - head_frac
    # later blocks heavier: weight i proportional to (1 + i/L)
    ws = [1.0 + i / L for i in range(1, L + 1)]
    wsum = sum(ws)
    fracs = [embed_frac] + [rest * w / wsum for w in ws] + [head_frac]
    return [
        {"fwd_flops": fwd_total * f, "param_bytes": bytes_total * f}
        for f in fracs
    ]


def model_manifest(spec: M.ModelSpec, lay: M.Layout, artifacts):
    segs = lay.unit_segments()
    head_w = lay.by_name("head.w")
    head_b = lay.by_name("head.b")
    return {
        "d": spec.d, "h": spec.h, "blocks": spec.blocks,
        "classes": spec.classes, "kind": spec.kind,
        "units": spec.units,
        "theta_len": lay.total,
        "batch_train": M.BATCH_TRAIN,
        "batch_infer": M.BATCH_INFER,
        "batch_probe": M.BATCH_PROBE,
        "unit_segments": [{"offset": o, "len": n} for (o, n) in segs],
        "tensors": [
            {"name": t.name, "shape": list(t.shape), "unit": t.unit,
             "offset": t.offset}
            for t in lay.tensors
        ],
        "head": {
            "w_offset": head_w.offset, "w_shape": list(head_w.shape),
            "b_offset": head_b.offset, "b_shape": list(head_b.shape),
        },
        "paper_units": paper_unit_costs(spec),
        "artifacts": artifacts,
    }


def build_model(spec: M.ModelSpec, outdir, quant: bool, ssl: bool, emitted):
    lay = M.layout(spec)
    th = shape((lay.total,))
    x_tr = shape((M.BATCH_TRAIN, spec.d))
    y_tr = shape((M.BATCH_TRAIN,), I32)
    x_inf = shape((M.BATCH_INFER, spec.d))
    x_probe = shape((M.BATCH_PROBE, spec.d))
    mask = shape((spec.units,))
    lr = shape(())

    arts = {"train": [], "train_q": []}

    def emit(name, fn, *args):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        text = lower(fn, *args)
        with open(path, "w") as f:
            f.write(text)
        emitted.append(name)
        print(f"  {name}: {len(text)} chars")
        return name

    arts["infer"] = emit(f"{spec.name}_infer",
                         M.infer_fn(spec, lay), th, x_inf)
    arts["features"] = emit(f"{spec.name}_features",
                            M.features_fn(spec, lay), th, x_probe)
    for k in range(spec.units):  # k = 0..blocks+1 prefix-frozen units
        arts["train"].append(
            emit(f"{spec.name}_train_{k}",
                 M.train_fn(spec, lay, k), th, x_tr, y_tr, mask, lr))
    if quant:
        for k in range(spec.units):
            arts["train_q"].append(
                emit(f"{spec.name}_train_q_{k}",
                     M.train_fn(spec, lay, k, fake_quant=True),
                     th, x_tr, y_tr, mask, lr))
    if ssl:
        slay = M.ssl_layout(spec)
        phi = shape((slay.total,))
        arts["ssl"] = emit(f"{spec.name}_ssl",
                           M.ssl_fn(spec, lay, slay),
                           th, phi, x_tr, x_tr, mask, lr)
        arts["ssl_phi_len"] = slay.total

    # deterministic initial parameters for the rust side
    theta0 = M.init_theta(lay, jax.random.PRNGKey(17))
    np.asarray(theta0, dtype="<f4").tofile(
        os.path.join(outdir, f"{spec.name}_theta0.bin"))
    if ssl:
        slay = M.ssl_layout(spec)
        phi0 = M.init_theta(slay, jax.random.PRNGKey(18))
        np.asarray(phi0, dtype="<f4").tofile(
            os.path.join(outdir, f"{spec.name}_phi0.bin"))

    return model_manifest(spec, lay, arts)


def build_cka(outdir, widths, emitted):
    out = {}
    for h in sorted(set(widths)):
        name = f"cka_{h}"
        path = os.path.join(outdir, f"{name}.hlo.txt")
        xs = shape((M.BATCH_PROBE, h))
        text = lower(lambda x, y: (cka_kernel.cka(x, y),), xs, xs)
        with open(path, "w") as f:
            f.write(text)
        emitted.append(name)
        print(f"  {name}: {len(text)} chars")
        out[str(h)] = name
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory")
    ap.add_argument("--models", default="res50,mbv2,deit,bert")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wanted = args.models.split(",")
    emitted = []
    manifest = {"version": 1, "models": {}, "cka": {}}
    for spec in M.specs():
        if spec.name not in wanted:
            continue
        print(f"[aot] {spec.name}")
        quant = spec.name == "res50"           # Table VIII is res50-only
        ssl = spec.name in ("res50", "mbv2", "deit")  # Table VI CV models
        manifest["models"][spec.name] = build_model(
            spec, args.out, quant, ssl, emitted)
    manifest["cka"] = build_cka(
        args.out, [s.h for s in M.specs() if s.name in wanted], emitted)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(emitted)} artifacts + manifest.json")


if __name__ == "__main__":
    main()
