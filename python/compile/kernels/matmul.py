"""Tiled Pallas dense kernel: ``act(x @ w + b)`` with a fused epilogue.

TPU-style mapping of the paper's GPU training hot loop (DESIGN.md
#hardware-adaptation):

* the grid tiles the output over ``(M/bm, N/bn)`` program instances — the
  role CUDA threadblocks play on the Jetson GPU;
* each instance keeps an ``x`` row-panel ``(bm, K)`` and a ``w`` column-panel
  ``(K, bn)`` resident in VMEM (the TPU scratchpad standing in for shared
  memory) and feeds the MXU with a single ``(bm,K)x(K,bn)`` contraction in
  fp32 — ``preferred_element_type`` pins the accumulator type;
* bias add and the activation run in the epilogue while the tile is still in
  VMEM, so the activation never round-trips to HBM (the paper's models pay
  that trip on GPU between the conv and the ReLU).

Training support: ``pallas_call`` has no automatic reverse-mode rule, so
``dense`` carries a ``jax.custom_vjp`` whose backward is built from the same
Pallas kernel — ``dz @ w^T`` and ``x^T @ dz`` are themselves tiled Pallas
matmuls, and the activation derivative is applied elementwise (ReLU from the
saved output mask; GELU by rematerializing the pre-activation with one extra
kernel call, the usual remat-vs-residency trade).

Block sizes default to 64x64: multiples of the 8x128 VPU lane shape at the
paper's layer widths, and small enough that ``bm*K + K*bn + bm*bn`` floats
stay well under the ~16 MiB VMEM budget for every layer in the four deployed
models.  ``interpret=True`` everywhere: the artifacts must execute on the CPU
PJRT client.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVATIONS = ("none", "relu", "gelu")


def _epilogue(acc, b, activation):
    acc = acc + b[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return acc


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """One (bm, bn) output tile: full-K MXU contraction + fused epilogue."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = _epilogue(acc, b_ref[...], activation)


def _pick_block(dim, cap):
    """Largest divisor of ``dim`` <= cap (the grid must tile exactly)."""
    for cand in range(min(cap, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _dense_impl(x, w, b, activation, bm, bn):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        partial(_dense_kernel, activation=activation),
        grid=grid,
        in_specs=[
            # x row-panel: varies along grid axis 0 only, full K resident.
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # w column-panel: varies along grid axis 1 only.
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            # bias slice for this column tile.
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def _matmul(a, b, bm=64, bn=64):
    """Plain a @ b through the same kernel (zero bias, no activation)."""
    zero_b = jnp.zeros((b.shape[1],), jnp.float32)
    return _dense_impl(a, b, zero_b, "none", bm, bn)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def dense(x, w, b, activation="none", bm=64, bn=64):
    """``act(x @ w + b)`` via the tiled Pallas kernel (differentiable).

    x: (M, K) f32, w: (K, N) f32, b: (N,) f32 -> (M, N) f32.
    """
    return _dense_impl(x, w, b, activation, bm, bn)


def _dense_fwd(x, w, b, activation, bm, bn):
    out = _dense_impl(x, w, b, activation, bm, bn)
    return out, (x, w, b, out)


def _dense_bwd(activation, bm, bn, res, dout):
    x, w, b, out = res
    if activation == "relu":
        dz = dout * (out > 0).astype(dout.dtype)
    elif activation == "gelu":
        # rematerialize the pre-activation (one extra kernel call) and push
        # the cotangent through gelu elementwise.
        z = _dense_impl(x, w, b, "none", bm, bn)
        _, gelu_vjp = jax.vjp(jax.nn.gelu, z)
        (dz,) = gelu_vjp(dout)
    else:
        dz = dout
    dx = _matmul(dz, w.T, bm, bn)        # (M, K)
    dw = _matmul(x.T, dz, bm, bn)        # (K, N)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def vmem_bytes(m, k, n, bm=64, bn=64):
    """Estimated VMEM residency per program instance, bytes (f32).

    Used by the structural perf audit (EXPERIMENTS.md §Perf L1) — interpret
    mode has no real VMEM, so the budget check is analytic.
    """
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return 4 * (bm * k + k * bn + bn + bm * bn)
