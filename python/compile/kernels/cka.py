"""Pallas CKA kernel — SimFreeze's similarity probe (paper Eq. 1).

    CKA(X, Y) = ||Y^T X||_F^2 / (||X^T X||_F * ||Y^T Y||_F)

X and Y are per-layer output feature maps (batch, features) from the model
being tuned and the initial reference model, on the same probe batch.  The
kernel computes the three Gram Frobenius norms in one pass: each grid step
loads a feature-column tile of X and Y into VMEM, forms the (bf, F) partial
cross/self products against the full feature panel, and accumulates their
squared Frobenius norms into a 3-vector in SMEM-like scratch (here: the
output ref, accumulated across sequential grid steps).

The batch dimension (16 for the probe batch) is small; the feature dimension
is the wide axis, so tiling is along features.  interpret=True for CPU-PJRT.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, y_ref, xf_ref, o_ref):
    """Grid step j: accumulate ||Y_j^T Xfull||_F^2, ||X_j^T Xfull||_F^2,
    ||Y_j^T Yfull||_F^2 into o_ref[0..3).

    ``x_ref/y_ref`` are (B, bf) column tiles; ``xf_ref`` carries the full
    (B, F) X and Y panels stacked as (2, B, F) so each step can contract a
    tile against the whole feature panel.  Because Frobenius norms decompose
    over column blocks of the Gram matrix, summing tile-level squared norms
    over the grid yields the exact full-matrix quantities.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xt = x_ref[...]          # (B, bf) tile of X
    yt = y_ref[...]          # (B, bf) tile of Y
    xf = xf_ref[0]           # (B, F) full X
    yf = xf_ref[1]           # (B, F) full Y
    # (bf, F) panels of the Gram matrices Y^T X, X^T X, Y^T Y.
    cross = jnp.dot(yt.T, xf, preferred_element_type=jnp.float32)
    selfx = jnp.dot(xt.T, xf, preferred_element_type=jnp.float32)
    selfy = jnp.dot(yt.T, yf, preferred_element_type=jnp.float32)
    o_ref[0] += jnp.sum(cross * cross)
    o_ref[1] += jnp.sum(selfx * selfx)
    o_ref[2] += jnp.sum(selfy * selfy)


def _pick_block(dim, cap):
    for cand in range(min(cap, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@partial(jax.jit, static_argnames=("bf",))
def cka(x, y, bf=64):
    """Linear CKA between feature maps ``x`` and ``y`` of shape (B, F)."""
    assert x.shape == y.shape, (x.shape, y.shape)
    b, f = x.shape
    bf = _pick_block(f, bf)
    stacked = jnp.stack([x, y])  # (2, B, F) — full panels for the kernel
    sums = pl.pallas_call(
        _gram_kernel,
        grid=(f // bf,),
        in_specs=[
            pl.BlockSpec((b, bf), lambda j: (0, j)),
            pl.BlockSpec((b, bf), lambda j: (0, j)),
            pl.BlockSpec((2, b, f), lambda j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((3,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        interpret=True,
    )(x, y, stacked)
    cross2, selfx2, selfy2 = sums[0], sums[1], sums[2]
    denom = jnp.sqrt(selfx2) * jnp.sqrt(selfy2)
    return cross2 / jnp.maximum(denom, 1e-12)
