"""Layer-1 Pallas kernels for the ETuner compute path.

Every dense contraction in the deployed models routes through
:func:`matmul.dense` (a tiled Pallas matmul with fused bias + activation
epilogue), and SimFreeze's CKA probe routes through :func:`cka.cka` (a
Pallas Gram-matrix kernel).  Pure-jnp oracles live in :mod:`ref` and the
pytest/hypothesis suites assert allclose between the two.

Kernels are lowered with ``interpret=True`` so the resulting HLO runs on the
CPU PJRT client that the rust coordinator uses (real-TPU lowering would emit
a Mosaic custom-call the CPU plugin cannot execute).  See
DESIGN.md#hardware-adaptation for the GPU->TPU mapping rationale.
"""

from . import matmul, cka, ref  # noqa: F401
