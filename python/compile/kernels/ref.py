"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every kernel in this package has a reference here with identical semantics;
``python/tests/test_kernels.py`` sweeps shapes/activations with hypothesis and
asserts allclose between kernel and oracle.
"""

import jax
import jax.numpy as jnp


def dense(x, w, b, activation="none"):
    """Reference for matmul.dense: act(x @ w + b) in plain jnp."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def cka(x, y):
    """Reference for cka.cka: paper Eq. 1, linear CKA on (B, F) features."""
    cross = jnp.linalg.norm(y.T @ x, "fro") ** 2
    denom = jnp.linalg.norm(x.T @ x, "fro") * jnp.linalg.norm(y.T @ y, "fro")
    return cross / jnp.maximum(denom, 1e-12)
