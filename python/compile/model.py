"""Layer-2: the deployed models' forward/backward in JAX, on Pallas kernels.

The paper fine-tunes ResNet50 / MobileNetV2 / DeiT-tiny (CV) and BERT-base
(NLP) on a Jetson Xavier NX.  Per DESIGN.md's substitution table we deploy
scaled-down proxies with the same *freezing-relevant structure* — a stack of
residual blocks between an embed layer and a classifier head — and carry each
role's paper-scale FLOPs/bytes in the manifest so the rust cost model charges
Jetson-scale time/energy:

  =========  ======================================  ====  ===  =======
  model      block kind                              H     L    classes
  =========  ======================================  ====  ===  =======
  res50      post-act residual ReLU MLP blocks       64    8    50
  mbv2       inverted-bottleneck (narrow-wide)       48    6    50
  deit       pre-LN GELU MLP blocks (ViT-style)      56    6    50
  bert       pre-LN GELU MLP blocks                  64    4    20
  =========  ======================================  ====  ===  =======

Freeze units are ``[embed, block_1..block_L, head]`` (L+2 units).  Two
freezing mechanisms mirror the paper's Figure 2 cases:

* **prefix truncation** (Case 3): ``train_step`` is specialized per ``k`` —
  a ``stop_gradient`` placed after unit ``k`` makes XLA dead-code-eliminate
  the whole backward graph below it, a *real* compute saving in the artifact;
* **lr mask** (Case 2): a per-unit multiplier zeroes the weight-update of
  interior frozen units (weight-grad skipped on the device is charged by the
  rust cost model; the artifact keeps one compiled shape per prefix).

All parameters live in ONE flat f32 vector ``theta`` so the rust coordinator
can hold model state as a single buffer, do CWR head surgery and RigL masking
by manifest segment offsets, and call any artifact with the same layout.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul

# ---------------------------------------------------------------------------
# Model specs
# ---------------------------------------------------------------------------

BATCH_TRAIN = 16   # paper: fixed to 16 to avoid OOM on the Jetson
BATCH_INFER = 64   # inference-request batch (one request = one test draw)
BATCH_PROBE = 16   # CKA probe batch (first training batch of the scenario)


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: Tuple[int, ...]
    unit: int          # freeze-unit index owning this tensor
    offset: int = 0    # filled by Layout

    @property
    def size(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


@dataclass(frozen=True)
class ModelSpec:
    name: str
    d: int               # input feature dim
    h: int               # hidden width
    blocks: int          # residual blocks (freeze units 1..blocks)
    classes: int
    kind: str            # relu_res | bottleneck | preln_gelu
    expansion: int       # bottleneck/MLP expansion factor
    # paper-scale cost anchors (per image / sequence, forward, GFLOPs; MB)
    paper_fwd_gflops: float = 4.1
    paper_params_mb: float = 97.8

    @property
    def units(self) -> int:
        return self.blocks + 2  # embed + blocks + head


def specs() -> List[ModelSpec]:
    return [
        ModelSpec("res50", 128, 64, 8, 50, "relu_res", 1,
                  paper_fwd_gflops=4.1, paper_params_mb=97.8),
        ModelSpec("mbv2", 128, 48, 6, 50, "bottleneck", 2,
                  paper_fwd_gflops=0.31, paper_params_mb=13.4),
        ModelSpec("deit", 128, 56, 6, 50, "preln_gelu", 2,
                  paper_fwd_gflops=1.26, paper_params_mb=21.8),
        ModelSpec("bert", 128, 64, 4, 20, "preln_gelu", 2,
                  paper_fwd_gflops=22.4, paper_params_mb=419.0),
    ]


def spec_by_name(name: str) -> ModelSpec:
    for s in specs():
        if s.name == name:
            return s
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------

@dataclass
class Layout:
    spec: ModelSpec
    tensors: List[TensorSpec] = field(default_factory=list)
    total: int = 0

    def _add(self, name, shape, unit):
        t = TensorSpec(name, tuple(shape), unit, self.total)
        self.tensors.append(t)
        self.total += t.size
        return t

    def by_name(self, name):
        for t in self.tensors:
            if t.name == name:
                return t
        raise KeyError(name)

    def unit_segments(self):
        """[(offset, len)] per freeze unit (contiguous by construction)."""
        segs = []
        for u in range(self.spec.units):
            ts = [t for t in self.tensors if t.unit == u]
            lo = min(t.offset for t in ts)
            hi = max(t.offset + t.size for t in ts)
            segs.append((lo, hi - lo))
        return segs


def layout(spec: ModelSpec) -> Layout:
    lay = Layout(spec)
    h, d, e = spec.h, spec.d, spec.h * spec.expansion
    lay._add("embed.w", (d, h), 0)
    lay._add("embed.b", (h,), 0)
    for i in range(1, spec.blocks + 1):
        p = f"block{i}."
        if spec.kind == "preln_gelu":
            lay._add(p + "ln_s", (h,), i)
            lay._add(p + "ln_b", (h,), i)
        lay._add(p + "w1", (h, e), i)
        lay._add(p + "b1", (e,), i)
        lay._add(p + "w2", (e, h), i)
        lay._add(p + "b2", (h,), i)
    head_unit = spec.blocks + 1
    lay._add("head.w", (h, spec.classes), head_unit)
    lay._add("head.b", (spec.classes,), head_unit)
    return lay


def unflatten(lay: Layout, theta):
    """Slice the flat vector into named arrays (static offsets -> free)."""
    out = {}
    for t in lay.tensors:
        out[t.name] = theta[t.offset:t.offset + t.size].reshape(t.shape)
    return out


def init_theta(lay: Layout, key) -> jnp.ndarray:
    """He/LeCun-style init, deterministic per (model, key).

    Written to ``artifacts/<model>_theta0.bin`` by aot.py; the rust
    coordinator loads it as the deployment-time initial model.
    """
    parts = []
    for t in lay.tensors:
        key, sub = jax.random.split(key)
        if t.name.endswith((".b", ".b1", ".b2", ".ln_b")):
            parts.append(jnp.zeros(t.size, jnp.float32))
        elif t.name.endswith(".ln_s"):
            parts.append(jnp.ones(t.size, jnp.float32))
        elif t.name.endswith(".w2"):
            # ReZero-style: residual branches start as identity so the
            # freshly deployed model is numerically tame at any depth.
            parts.append(jnp.zeros(t.size, jnp.float32))
        else:
            fan_in = t.shape[0]
            std = (2.0 / fan_in) ** 0.5
            parts.append(std * jax.random.normal(sub, (t.size,), jnp.float32))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _block(spec: ModelSpec, p, i, hcur, fake_quant=False):
    q = _fq if fake_quant else (lambda v: v)
    pre = f"block{i}."
    if spec.kind == "relu_res":
        mid = matmul.dense(q(hcur), q(p[pre + "w1"]), p[pre + "b1"], "relu")
        out = matmul.dense(q(mid), q(p[pre + "w2"]), p[pre + "b2"], "none")
        return jnp.maximum(hcur + out, 0.0)
    if spec.kind == "bottleneck":
        mid = matmul.dense(q(hcur), q(p[pre + "w1"]), p[pre + "b1"], "relu")
        out = matmul.dense(q(mid), q(p[pre + "w2"]), p[pre + "b2"], "none")
        return hcur + out
    if spec.kind == "preln_gelu":
        mu = jnp.mean(hcur, axis=-1, keepdims=True)
        var = jnp.var(hcur, axis=-1, keepdims=True)
        ln = (hcur - mu) / jnp.sqrt(var + 1e-5)
        ln = ln * p[pre + "ln_s"][None, :] + p[pre + "ln_b"][None, :]
        mid = matmul.dense(q(ln), q(p[pre + "w1"]), p[pre + "b1"], "gelu")
        out = matmul.dense(q(mid), q(p[pre + "w2"]), p[pre + "b2"], "none")
        return hcur + out
    raise ValueError(spec.kind)


def _fq(v, bits=8):
    """Fake-quantize (symmetric, per-tensor) with a straight-through grad.

    Simulated quantization-aware training as in the paper's Table VIII
    (weights + activations; the STE makes backward flow as fp32)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-8) / qmax
    q = jnp.round(v / scale).clip(-qmax, qmax) * scale
    return v + jax.lax.stop_gradient(q - v)


def forward(spec: ModelSpec, lay: Layout, theta, x,
            stop_after: int = -1, collect: bool = False, fake_quant=False):
    """Run the model.

    stop_after=k inserts stop_gradient after freeze unit k (k=-1: none) —
    the Case-3 backprop truncation.  collect=True returns per-unit features
    (embed output + each block output) for the CKA probe.
    """
    p = unflatten(lay, theta)
    q = _fq if fake_quant else (lambda v: v)
    feats = []
    h = matmul.dense(q(x), q(p["embed.w"]), p["embed.b"], "relu")
    if collect:
        feats.append(h)
    if stop_after >= 0:
        h = jax.lax.stop_gradient(h)
    for i in range(1, spec.blocks + 1):
        h = _block(spec, p, i, h, fake_quant)
        if collect:
            feats.append(h)
        if stop_after >= i:
            h = jax.lax.stop_gradient(h)
    logits = matmul.dense(q(h), q(p["head.w"]), p["head.b"], "none")
    if collect:
        return logits, jnp.stack(feats)  # (blocks+1, B, H)
    return logits


# ---------------------------------------------------------------------------
# Artifact entry points (what aot.py lowers)
# ---------------------------------------------------------------------------

def infer_fn(spec: ModelSpec, lay: Layout):
    def infer(theta, x):
        return (forward(spec, lay, theta, x),)
    return infer


def features_fn(spec: ModelSpec, lay: Layout):
    def features(theta, x):
        _, feats = forward(spec, lay, theta, x, collect=True)
        return (feats,)
    return features


def _lr_mask_vector(lay: Layout, lr_mask):
    """Expand the per-unit mask (units,) to a theta-length multiplier."""
    segs = []
    for t in lay.tensors:
        segs.append(jnp.broadcast_to(lr_mask[t.unit], (t.size,)))
    return jnp.concatenate(segs)


def _ce_loss(spec, lay, theta, x, y, stop_after, fake_quant=False):
    logits = forward(spec, lay, theta, x, stop_after=stop_after,
                     fake_quant=fake_quant)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, spec.classes, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


MAX_GRAD_NORM = 5.0


def _clip_global(g):
    """Clip-by-global-norm — the edge fine-tuning stream is bursty and
    correlated (whole batches of one class under one scenario transform),
    which raw SGD at a usable lr cannot survive; clipping is standard in
    the on-device training stacks the paper builds on."""
    norm = jnp.sqrt(jnp.sum(g * g))
    return g * jnp.minimum(1.0, MAX_GRAD_NORM / jnp.maximum(norm, 1e-12))


def train_fn(spec: ModelSpec, lay: Layout, k: int, fake_quant=False):
    """SGD step with the first ``k`` freeze units prefix-frozen.

    k=0 trains everything; k=j stops backprop after unit j-1's output (i.e.
    units 0..j-1 frozen).  Signature:
        (theta, x[16,D], y[16] i32, lr_mask[units], lr[]) -> (theta', loss)
    """
    stop_after = k - 1  # stop_gradient placed after unit (k-1)

    def step(theta, x, y, lr_mask, lr):
        loss, g = jax.value_and_grad(
            lambda th: _ce_loss(spec, lay, th, x, y, stop_after, fake_quant)
        )(theta)
        # mask BEFORE clipping so Case 2 (lr-mask) and Case 3 (prefix
        # truncation) freezing produce identical surviving updates.
        g = _clip_global(g * _lr_mask_vector(lay, lr_mask))
        theta_new = theta - lr * g
        return theta_new, loss

    return step


# --- SimSiam semi-supervised step (paper §IV-C) ----------------------------

SSL_PROJ = "proj"


def ssl_layout(spec: ModelSpec) -> Layout:
    """Projector (H->H) + predictor (H->H) params, separate flat vector."""
    lay = Layout(spec)
    h = spec.h
    lay._add("proj.w", (h, h), 0)
    lay._add("proj.b", (h,), 0)
    lay._add("pred.w", (h, h), 1)
    lay._add("pred.b", (h,), 1)
    return lay


def ssl_fn(spec: ModelSpec, lay: Layout, slay: Layout):
    """One SimSiam step on two augmented views.

        (theta, phi, x1[16,D], x2[16,D], lr_mask[units], lr[])
            -> (theta', phi', loss)

    loss = -(cos(p1, sg(z2)) + cos(p2, sg(z1))) / 2, z = proj(backbone(x)),
    p = pred(z).  Backbone freezing (SimFreeze) applies through lr_mask;
    the projector/predictor always train.
    """
    def backbone(theta, x):
        p = unflatten(lay, theta)
        h = matmul.dense(x, p["embed.w"], p["embed.b"], "relu")
        for i in range(1, spec.blocks + 1):
            h = _block(spec, p, i, h)
        return h

    def cos(a, b):
        a = a / jnp.maximum(jnp.linalg.norm(a, axis=-1, keepdims=True), 1e-8)
        b = b / jnp.maximum(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-8)
        return jnp.mean(jnp.sum(a * b, axis=-1))

    def loss_fn(theta, phi, x1, x2):
        sp = unflatten(slay, phi)
        z1 = matmul.dense(backbone(theta, x1), sp["proj.w"], sp["proj.b"], "none")
        z2 = matmul.dense(backbone(theta, x2), sp["proj.w"], sp["proj.b"], "none")
        p1 = matmul.dense(z1, sp["pred.w"], sp["pred.b"], "none")
        p2 = matmul.dense(z2, sp["pred.w"], sp["pred.b"], "none")
        sg = jax.lax.stop_gradient
        return -(cos(p1, sg(z2)) + cos(p2, sg(z1))) / 2.0

    def step(theta, phi, x1, x2, lr_mask, lr):
        loss, (gt, gp) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            theta, phi, x1, x2)
        gt = _clip_global(gt * _lr_mask_vector(lay, lr_mask))
        gp = _clip_global(gp)
        theta_new = theta - lr * gt
        phi_new = phi - lr * gp
        return theta_new, phi_new, loss

    return step
