"""L2 correctness: model forward/backward, freeze semantics, SSL, QAT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SPECS = M.specs()


def _data(spec, seed=0, batch=M.BATCH_TRAIN):
    x = jax.random.normal(jax.random.PRNGKey(seed), (batch, spec.d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (batch,), 0,
                           spec.classes)
    return x, y


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_layout_is_contiguous_and_complete(spec):
    lay = M.layout(spec)
    offset = 0
    for t in lay.tensors:
        assert t.offset == offset, t.name
        offset += t.size
    assert lay.total == offset
    segs = lay.unit_segments()
    assert len(segs) == spec.units
    assert segs[0][0] == 0
    assert segs[-1][0] + segs[-1][1] == lay.total
    # segments are contiguous and ordered
    for (o1, l1), (o2, _) in zip(segs, segs[1:]):
        assert o1 + l1 == o2


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_forward_shapes(spec):
    lay = M.layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(0))
    x, _ = _data(spec)
    logits = M.forward(spec, lay, th, x)
    assert logits.shape == (M.BATCH_TRAIN, spec.classes)
    logits2, feats = M.forward(spec, lay, th, x, collect=True)
    np.testing.assert_allclose(logits, logits2, rtol=1e-6)
    assert feats.shape == (spec.blocks + 1, M.BATCH_TRAIN, spec.h)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_train_step_reduces_loss(spec):
    lay = M.layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(1))
    x, y = _data(spec, seed=3)
    step = M.train_fn(spec, lay, 0)
    mask = jnp.ones((spec.units,))
    lr = jnp.float32(0.05)
    losses = []
    for _ in range(8):
        th, loss = step(th, x, y, mask, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
@pytest.mark.parametrize("k", [1, 2])
def test_prefix_freeze_keeps_prefix_constant(spec, k):
    lay = M.layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(2))
    x, y = _data(spec, seed=5)
    step = M.train_fn(spec, lay, k)
    mask = jnp.ones((spec.units,))
    th2, _ = step(th, x, y, mask, jnp.float32(0.1))
    segs = lay.unit_segments()
    for u, (o, n) in enumerate(segs):
        changed = bool(jnp.any(th2[o:o + n] != th[o:o + n]))
        if u < k:
            assert not changed, f"frozen unit {u} changed"
        else:
            assert changed, f"trainable unit {u} did not change"


def test_lr_mask_freezes_interior_unit():
    spec = M.spec_by_name("mbv2")
    lay = M.layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(4))
    x, y = _data(spec, seed=7)
    step = M.train_fn(spec, lay, 0)
    mask = jnp.ones((spec.units,)).at[3].set(0.0)
    th2, _ = step(th, x, y, mask, jnp.float32(0.1))
    segs = lay.unit_segments()
    o, n = segs[3]
    assert not bool(jnp.any(th2[o:o + n] != th[o:o + n]))
    o, n = segs[2]
    assert bool(jnp.any(th2[o:o + n] != th[o:o + n]))


def test_prefix_freeze_equals_mask_freeze_numerically():
    """Case 3 (stop_gradient) and Case 2 (lr mask) must agree on the
    surviving updates when the same prefix is frozen."""
    spec = M.spec_by_name("mbv2")
    lay = M.layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(6))
    x, y = _data(spec, seed=11)
    lr = jnp.float32(0.05)
    ones = jnp.ones((spec.units,))
    mask = ones.at[0].set(0.0).at[1].set(0.0)
    th_prefix, _ = M.train_fn(spec, lay, 2)(th, x, y, ones, lr)
    th_mask, _ = M.train_fn(spec, lay, 0)(th, x, y, mask, lr)
    np.testing.assert_allclose(th_prefix, th_mask, rtol=2e-4, atol=2e-5)


def test_quant_train_step_runs_and_learns():
    spec = M.spec_by_name("res50")
    lay = M.layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(8))
    x, y = _data(spec, seed=13)
    step = M.train_fn(spec, lay, 0, fake_quant=True)
    mask = jnp.ones((spec.units,))
    losses = []
    for _ in range(6):
        th, loss = step(th, x, y, mask, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_fake_quant_quantizes_forward():
    v = jnp.linspace(-1.0, 1.0, 1000)
    q = M._fq(v)
    # at most 255 distinct levels for 8-bit symmetric quantization
    assert len(np.unique(np.asarray(q))) <= 255
    # straight-through: d/dv sum(q^2) = 2*q (the STE passes the cotangent
    # through the rounding unchanged)
    g = jax.grad(lambda v: jnp.sum(M._fq(v) ** 2))(v)
    np.testing.assert_allclose(g, 2 * q, rtol=1e-5, atol=1e-6)


def test_ssl_step_improves_view_agreement():
    spec = M.spec_by_name("mbv2")
    lay = M.layout(spec)
    slay = M.ssl_layout(spec)
    th = M.init_theta(lay, jax.random.PRNGKey(9))
    phi = M.init_theta(slay, jax.random.PRNGKey(10))
    x, _ = _data(spec, seed=17)
    key = jax.random.PRNGKey(21)
    x1 = x + 0.1 * jax.random.normal(key, x.shape)
    x2 = x * 1.05
    step = M.ssl_fn(spec, lay, slay)
    mask = jnp.ones((spec.units,))
    losses = []
    for _ in range(6):
        th, phi, loss = step(th, phi, x1, x2, mask, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert losses[-1] >= -1.0 - 1e-5  # negative cosine is bounded below


def test_init_theta_deterministic_and_rezero():
    spec = M.spec_by_name("deit")
    lay = M.layout(spec)
    a = M.init_theta(lay, jax.random.PRNGKey(17))
    b = M.init_theta(lay, jax.random.PRNGKey(17))
    np.testing.assert_array_equal(a, b)
    w2 = lay.by_name("block1.w2")
    assert not bool(jnp.any(a[w2.offset:w2.offset + w2.size] != 0.0))
