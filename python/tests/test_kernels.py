"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and activations; every property asserts allclose
against ``kernels.ref``.  These tests are the build-time correctness bar for
everything the rust coordinator executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cka, matmul, ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 17, 32, 48, 64, 96, 128])
ACT = st.sampled_from(matmul.ACTIVATIONS)


def _rng(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, act=ACT, seed=st.integers(0, 2**16))
def test_dense_matches_ref(m, k, n, act, seed):
    x = _rng(seed, (m, k))
    w = _rng(seed + 1, (k, n))
    b = _rng(seed + 2, (n,))
    got = matmul.dense(x, w, b, act)
    want = ref.dense(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([4, 16, 32]), k=st.sampled_from([8, 48, 64]),
       n=st.sampled_from([8, 50, 96]), act=ACT,
       seed=st.integers(0, 2**16))
def test_dense_grads_match_ref(m, k, n, act, seed):
    """custom_vjp backward (Pallas) == autodiff through the jnp oracle."""
    x = _rng(seed, (m, k))
    w = _rng(seed + 1, (k, n))
    b = _rng(seed + 2, (n,))

    def loss_kernel(x, w, b):
        return jnp.sum(matmul.dense(x, w, b, act) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.dense(x, w, b, act) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)


def test_dense_block_cap_does_not_change_result():
    """Tiling is value-invariant: different block caps, same numbers."""
    x, w, b = _rng(0, (32, 64)), _rng(1, (64, 96)), _rng(2, (96,))
    base = matmul.dense(x, w, b, "relu", bm=64, bn=64)
    for bm, bn in [(8, 8), (16, 96), (32, 1)]:
        # tiling changes fp32 summation order; allow rounding-level drift
        np.testing.assert_allclose(
            matmul.dense(x, w, b, "relu", bm=bm, bn=bn), base,
            rtol=1e-4, atol=1e-5)


def test_dense_rejects_bad_activation():
    x, w, b = _rng(0, (4, 4)), _rng(1, (4, 4)), _rng(2, (4,))
    with pytest.raises(ValueError):
        matmul.dense(x, w, b, "swish")


def test_vmem_budget_all_model_layers():
    """Structural perf check: every deployed layer's tile set fits VMEM."""
    from compile import model as M
    budget = 2 * 1024 * 1024  # 2 MiB per-instance target (16 MiB VMEM / 8)
    for spec in M.specs():
        e = spec.h * spec.expansion
        shapes = [(M.BATCH_TRAIN, spec.d, spec.h),
                  (M.BATCH_TRAIN, spec.h, e),
                  (M.BATCH_TRAIN, e, spec.h),
                  (M.BATCH_INFER, spec.h, spec.classes)]
        for m, k, n in shapes:
            assert matmul.vmem_bytes(m, k, n) <= budget, (spec.name, m, k, n)


# ---------------------------------------------------------------------------
# CKA kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from([4, 8, 16]), f=st.sampled_from([8, 16, 48, 56, 64]),
       bf=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**16))
def test_cka_matches_ref(b, f, bf, seed):
    x = _rng(seed, (b, f))
    y = _rng(seed + 1, (b, f))
    got = cka.cka(x, y, bf=bf)
    want = ref.cka(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_cka_identity_is_one():
    x = _rng(7, (16, 64))
    assert abs(float(cka.cka(x, x)) - 1.0) < 1e-5


def test_cka_symmetric():
    x, y = _rng(1, (16, 48)), _rng(2, (16, 48))
    assert abs(float(cka.cka(x, y)) - float(cka.cka(y, x))) < 1e-5


def test_cka_bounded_unit_interval():
    for seed in range(5):
        x, y = _rng(seed, (16, 56)), _rng(seed + 100, (16, 56))
        v = float(cka.cka(x, y))
        assert -1e-6 <= v <= 1.0 + 1e-6


def test_cka_invariant_to_orthogonal_rotation():
    """Linear CKA is invariant to orthogonal transforms of features."""
    x, y = _rng(1, (16, 32)), _rng(2, (16, 32))
    q, _ = np.linalg.qr(np.asarray(_rng(3, (32, 32))))
    base = float(cka.cka(x, y))
    rot = float(cka.cka(x @ jnp.asarray(q), y))
    assert abs(base - rot) < 1e-4
