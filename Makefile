# ETuner / EdgeOL reproduction — build & perf-tracking entry points.
#
#   make artifacts   AOT-lower the JAX/Pallas programs to HLO text + θ0 bins
#   make build       release build of the rust coordinator
#   make test        tier-1 gate: release build + full test suite
#   make bench       hotpath microbenchmarks -> BENCH_hotpath.json
#                    (mean/min/max ms per benchmark; tracked across PRs)
#   make repro       regenerate every paper table/figure, all cores

ARTIFACTS ?= $(CURDIR)/rust/artifacts
JOBS ?= $(shell nproc 2>/dev/null || echo 1)

.PHONY: artifacts build test bench repro

artifacts:
	cd python/compile && python3 aot.py --out $(ARTIFACTS)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && ETUNER_BENCH_OUT=$(CURDIR)/BENCH_hotpath.json \
		cargo bench --bench hotpath

repro:
	cd rust && cargo run --release -- repro all --jobs $(JOBS)
