# ETuner / EdgeOL reproduction — build & perf-tracking entry points.
#
#   make artifacts   AOT-lower the JAX/Pallas programs to HLO text + θ0 bins
#   make build       release build of the rust coordinator
#   make test        tier-1 gate: release build + full test suite
#   make ci          stub-feature gate: build + tests + fmt + clippy -D warnings
#   make ci-faults   tier-1 suite again under a fixed nonzero fault plan
#   make ci-trace    short traced run -> validated Chrome trace JSON
#   make ci-fleet    fleet lane: --fleet 4 CLI smoke + the fleet test battery
#   make ci-crash    durability lane: crash-inject CLI smoke (exit 3 ->
#                    --resume) + the crash/recovery test battery
#   make ci-load     load lane: capacity-search CLI smoke + the load
#                    property battery (rate/ratio/zipf pins, sweep and
#                    knee bit-identity across --jobs)
#   make bench       hotpath microbenchmarks -> BENCH_hotpath.json
#                    (mean/min/max ms per benchmark; tracked across PRs)
#   make bench-gemm  isolated packed-vs-naive kernel series -> BENCH_gemm.json
#   make bench-load  isolated load-generator + open-loop-run series ->
#                    BENCH_load.json
#   make bench-snapshot PR=N   archive BENCH_hotpath.json under bench_history/
#   make repro       regenerate every paper table/figure, all cores

ARTIFACTS ?= $(CURDIR)/rust/artifacts
JOBS ?= $(shell nproc 2>/dev/null || echo 1)
PR ?= dev

.PHONY: artifacts build test ci ci-faults ci-trace ci-fleet ci-crash ci-load \
	bench bench-gemm bench-load bench-snapshot repro

artifacts:
	cd python/compile && python3 aot.py --out $(ARTIFACTS)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

# CI gate (no artifacts, no xla toolchain needed): everything must build,
# unit-test, stay rustfmt-clean and clippy-clean.  Since the Backend
# refactor `cargo test` includes the refcpu END-TO-END suite — full
# simulations that really execute models (tests/backend_parity.rs,
# tests/refcpu_kernels.rs, tests/refcpu_gemm.rs, the un-gated integration
# suites) — so CI verifies learning semantics, not just marshalling and
# caching.  The execution core is the repo's hot path, so the clippy
# `perf` lint group is explicitly warn-as-error (it is warn-by-default,
# which `-D warnings` already promotes; the explicit `-D clippy::perf`
# keeps it fatal even if the blanket deny is ever relaxed).
ci:
	cd rust && cargo build && cargo test -q
	cd rust && cargo fmt --check
	cd rust && cargo clippy --all-targets -- -D warnings -D clippy::perf

# Chaos lane (PR 6): the same tier-1 suite with ETUNER_FAULTS exporting a
# fixed seeded fault plan.  Every `RunConfig::quickstart` run in the suite
# then injects transient execute/marshal faults and latency spikes through
# the FaultyBackend decorator, so invariants (arrival conservation, N=1
# vs N=4 sweep bit-identity, theta rollback) are exercised under failure,
# not just on the happy path.  Golden-fingerprint tests pin
# `faults = FaultPlan::none()` explicitly and are unaffected.
ci-faults:
	cd rust && ETUNER_FAULTS="exec:0.05,marshal:0.01,spike:0.02x0.25,burst:2" \
		ETUNER_FAULT_SEED=6 cargo test -q

# Observability lane (PR 7): a short traced CLI run must emit a valid
# Chrome trace-event file with at least one span on every subsystem lane
# (serve-engine / rounds / sweep / backend).  The emitted file is then
# validated through the repo's own JSON parser by the
# `ci_trace_file_is_valid_chrome_json` test (tests/trace.rs), which is a
# no-op unless ETUNER_TRACE_FILE points at a file.
ci-trace:
	cd rust && cargo run --release -q -- run --model mbv2 \
		--benchmark scifar10 --tune lazytune --freeze simfreeze \
		--requests 80 --seed 1 --trace \
		--trace-out /tmp/etuner_trace.json --trace-summary
	cd rust && ETUNER_TRACE_FILE=/tmp/etuner_trace.json \
		cargo test -q --release --test trace

# Fleet lane (PR 8): a --fleet 4 CLI smoke run (scenario-affinity routing
# across four engines must keep the default-config scientific fingerprint,
# see tests/fleet.rs) followed by the fleet determinism battery — the
# fleet-of-1 transparency pin, sequential-vs-threaded pool bit-identity,
# arrival conservation with one engine's breaker open, and the merged
# per-(engine, lane) trace tracks.
ci-fleet:
	cd rust && cargo run --release -q -- run --model mbv2 \
		--benchmark scifar10 --tune lazytune --freeze simfreeze \
		--requests 80 --seed 1 --fleet 4
	cd rust && cargo test -q --release --test fleet --test trace \
		--test serving_engine

# Durability lane (PR 9): a CLI run with a deterministic crash point must
# die with exit code 3 after writing its checkpoint records, and the same
# command with --resume must complete from them; then the crash/recovery
# battery — bit-identical resume from a crash at every round boundary,
# checksum-detected corruption falling back to the previous record, the
# sweep-cell journal, and the zero-overhead-when-disabled pin.
ci-crash:
	cd rust && rm -rf /tmp/etuner_ci_crash && \
		{ cargo run --release -q -- run --model mbv2 \
			--benchmark scifar10 --tune lazytune --freeze simfreeze \
			--requests 80 --seed 1 --faults crash:after-round-2 \
			--checkpoint-dir /tmp/etuner_ci_crash; \
		  test $$? -eq 3 || { echo "expected exit code 3"; exit 1; } ; }
	cd rust && cargo run --release -q -- run --model mbv2 \
		--benchmark scifar10 --tune lazytune --freeze simfreeze \
		--requests 80 --seed 1 --faults crash:after-round-2 \
		--resume /tmp/etuner_ci_crash
	cd rust && cargo test -q --release --test crash_recovery

# Load lane (PR 10): an open-loop capacity-search CLI smoke on the
# refcpu backend (coarse bracket, short window — proves the whole
# generator -> sweep -> bisection -> knee pipeline end to end under
# --jobs 2) followed by the load property battery: pinned-seed
# rate/peak-trough/zipf-ranking checks, N=1 vs N=4 sweep bit-identity
# for open-loop configs, and probe-log bit-identity of the knee.
ci-load:
	cd rust && cargo run --release -q -- capacity --backend refcpu \
		--workload poisson --load-window 30 --slo-ms 2000 \
		--lo 0.2 --hi 2 --iters 1 --probes 1 --jobs 2
	cd rust && cargo test -q --release --test load

bench:
	cd rust && ETUNER_BENCH_OUT=$(CURDIR)/BENCH_hotpath.json \
		cargo bench --bench hotpath

# Only the packed-vs-naive kernel series (fast; separate output file so a
# partial run never clobbers the full hotpath trajectory).
bench-gemm:
	cd rust && ETUNER_BENCH_FILTER=gemm \
		ETUNER_BENCH_OUT=$(CURDIR)/BENCH_gemm.json \
		cargo bench --bench hotpath

# Only the load series (generator throughput per workload kind, zipf mix
# assignment, and one end-to-end open-loop refcpu run); separate output
# file for the same clobber-safety reason as bench-gemm.
bench-load:
	cd rust && ETUNER_BENCH_FILTER=load \
		ETUNER_BENCH_OUT=$(CURDIR)/BENCH_load.json \
		cargo bench --bench hotpath

# Archive the current bench run as this PR's snapshot so the perf
# trajectory is tracked mechanically (see bench_history/README.md).
# The snapshot now includes the refcpu serving-throughput and model
# series, which execute real models on any machine — so cross-PR numbers
# are comparable even in artifact-less environments.
bench-snapshot:
	@test -f BENCH_hotpath.json || { echo "run \`make bench\` first"; exit 1; }
	cp BENCH_hotpath.json bench_history/PR$(PR)_hotpath.json
	@echo "archived bench_history/PR$(PR)_hotpath.json"

repro:
	cd rust && cargo run --release -- repro all --jobs $(JOBS)
