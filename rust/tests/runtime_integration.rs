//! Integration tests over the real AOT artifacts + PJRT backend.
//! Skipped (with a notice) when `make artifacts` has not run or the crate
//! was built without the `xla` feature — the same behavioural contracts
//! are asserted unconditionally on the reference backend in
//! `tests/refcpu_kernels.rs`, so CI always executes them somewhere.
//!
//! NOTE: each test builds its own `PjrtBackend` (PJRT CPU client); they
//! are cheap.

use etuner::cost::flops::FreezeState;
use etuner::model::ModelSession;
use etuner::rng::Pcg32;
use etuner::runtime::Backend;
use etuner::testkit;

macro_rules! require_pjrt {
    () => {
        match testkit::pjrt_backend_if_available() {
            Some(be) => be,
            None => {
                eprintln!(
                    "skipping: pjrt backend unavailable \
                     (run `make artifacts` and build with --features xla)"
                );
                return;
            }
        }
    };
}

use etuner::testkit::two_class_batch;

#[test]
fn manifest_lists_all_models() {
    let rt = require_pjrt!();
    for m in ["res50", "mbv2", "deit", "bert"] {
        let mm = rt.manifest().model(m).unwrap();
        assert_eq!(mm.artifacts.train.len(), mm.units);
        assert!(rt.theta0(m).unwrap().len() == mm.theta_len);
    }
}

#[test]
fn infer_runs_and_is_deterministic() {
    let rt = require_pjrt!();
    let sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    let p = sess.theta0().unwrap();
    let x = vec![0.1f32; sess.m.batch_infer * sess.m.d];
    let a = sess.infer(&p, &x).unwrap();
    let b = sess.infer(&p, &x).unwrap();
    assert_eq!(a.shape, vec![sess.m.batch_infer, sess.m.classes]);
    assert_eq!(a.data, b.data);
    assert!(a.data.iter().all(|v| v.is_finite()));
}

#[test]
fn training_learns_two_classes() {
    let rt = require_pjrt!();
    let mut sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    sess.lr = 0.05;
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(7, 7);
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..40 {
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        let loss = sess.train_step(&mut p, &x, &y, &fs).unwrap();
        assert!(loss.is_finite(), "loss diverged");
        first_loss.get_or_insert(loss);
        last_loss = loss;
    }
    assert!(
        last_loss < first_loss.unwrap() * 0.6,
        "loss {first_loss:?} -> {last_loss}"
    );
    // accuracy on a fresh draw
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_infer, sess.m.d);
    let acc = sess.accuracy(&p, &x, &y).unwrap();
    assert!(acc > 0.8, "accuracy {acc}");
}

#[test]
fn prefix_frozen_units_do_not_move() {
    let rt = require_pjrt!();
    let sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let p0 = p.clone();
    let mut fs = FreezeState::none(sess.m.units);
    fs.frozen[0] = true;
    fs.frozen[1] = true;
    let mut rng = Pcg32::new(8, 8);
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    for u in 0..sess.m.units {
        let moved = p
            .unit(&sess.m, u)
            .iter()
            .zip(p0.unit(&sess.m, u))
            .any(|(a, b)| a != b);
        if u < 2 {
            assert!(!moved, "frozen unit {u} moved");
        } else {
            assert!(moved, "trainable unit {u} did not move");
        }
    }
}

#[test]
fn interior_lr_mask_freezes_unit() {
    let rt = require_pjrt!();
    let sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let p0 = p.clone();
    let mut fs = FreezeState::none(sess.m.units);
    fs.frozen[3] = true; // interior unit: lr-mask path (Case 2)
    let mut rng = Pcg32::new(9, 9);
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    let moved3 = p
        .unit(&sess.m, 3)
        .iter()
        .zip(p0.unit(&sess.m, 3))
        .any(|(a, b)| a != b);
    assert!(!moved3, "masked unit moved");
    let moved2 = p
        .unit(&sess.m, 2)
        .iter()
        .zip(p0.unit(&sess.m, 2))
        .any(|(a, b)| a != b);
    assert!(moved2);
}

#[test]
fn features_and_cka_probe_work() {
    let rt = require_pjrt!();
    let sess = ModelSession::new(rt.as_ref(), "res50").unwrap();
    let p = sess.theta0().unwrap();
    let x = {
        let mut rng = Pcg32::new(10, 10);
        (0..sess.m.batch_probe * sess.m.d)
            .map(|_| rng.normal())
            .collect::<Vec<f32>>()
    };
    let f = sess.features(&p, &x).unwrap();
    assert_eq!(
        f.shape,
        vec![sess.m.blocks + 1, sess.m.batch_probe, sess.m.h]
    );
    // identical models -> CKA == 1 for every layer
    for l in 0..sess.m.blocks + 1 {
        let cka = sess.cka_layer(&f, &f, l).unwrap();
        assert!((cka - 1.0).abs() < 1e-4, "layer {l}: {cka}");
    }
}

#[test]
fn cka_differs_after_training() {
    let rt = require_pjrt!();
    let mut sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    sess.lr = 0.1;
    let mut p = sess.theta0().unwrap();
    let p0 = p.clone();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(11, 11);
    let (probe, _) = two_class_batch(&mut rng, sess.m.batch_probe, sess.m.d);
    for _ in 0..20 {
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        sess.train_step(&mut p, &x, &y, &fs).unwrap();
    }
    let f0 = sess.features(&p0, &probe).unwrap();
    let f1 = sess.features(&p, &probe).unwrap();
    // at least one later layer must have drifted from the reference
    let mut min_cka = f32::INFINITY;
    for l in 0..sess.m.blocks + 1 {
        min_cka = min_cka.min(sess.cka_layer(&f1, &f0, l).unwrap());
    }
    assert!(min_cka < 0.9999, "nothing drifted: {min_cka}");
}

#[test]
fn ssl_step_runs_and_is_finite() {
    let rt = require_pjrt!();
    let sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let mut phi = rt.phi0("mbv2").unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(12, 12);
    let (x, _) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    let x2: Vec<f32> = x.iter().map(|v| v * 1.05).collect();
    let mut last = 0.0;
    for _ in 0..5 {
        last = sess.ssl_step(&mut p, &mut phi, &x, &x2, &fs).unwrap();
        assert!(last.is_finite());
    }
    assert!(last >= -1.0 - 1e-5, "cosine loss out of range: {last}");
}

#[test]
fn quant_train_artifact_runs() {
    let rt = require_pjrt!();
    let mut sess = ModelSession::new(rt.as_ref(), "res50").unwrap();
    sess.quant = true;
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(13, 13);
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    let loss = sess.train_step(&mut p, &x, &y, &fs).unwrap();
    assert!(loss.is_finite());
}

#[test]
fn energy_scores_are_finite_after_warmup_training() {
    let rt = require_pjrt!();
    let mut sess = ModelSession::new(rt.as_ref(), "mbv2").unwrap();
    sess.lr = 0.05;
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(14, 14);
    for _ in 0..60 {
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        let loss = sess.train_step(&mut p, &x, &y, &fs).unwrap();
        assert!(loss.is_finite(), "warmup diverged");
    }
    let (x, _) = two_class_batch(&mut rng, sess.m.batch_infer, sess.m.d);
    let scores = sess.energy_scores(&p, &x).unwrap();
    assert!(scores.iter().all(|s| s.is_finite()), "{scores:?}");
}
