//! Fault-injection & recovery integration tests (PR 6).
//!
//! Three contracts:
//!
//! * **Passthrough** — `FaultPlan::none()` (the default) is invisible:
//!   reports are bit-identical whether the fault layer is absent,
//!   bypassed by [`etuner::sim::run_config`], or present-but-empty as an
//!   explicitly constructed [`FaultyBackend`] decorator.
//! * **Conservation** — under a seeded chaos plan every arrival is either
//!   served or accounted as dropped (queue-full, SLO-infeasible, or
//!   backend-unavailable); no request is ever lost to a fault.
//! * **Determinism** — fault streams are seeded per run, so sweeps stay
//!   bit-identical across worker counts even while injecting.
//!
//! Golden tests pin `cfg.faults = FaultPlan::none()` explicitly so
//! `ETUNER_FAULTS` (the `make ci-faults` lane) cannot leak into them.

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::benchmarks::Benchmark;
use etuner::runtime::{FaultPlan, FaultyBackend};
use etuner::sim::{run_config, ParallelSweeper, RunConfig, Simulation};
use etuner::testkit;

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c.faults = FaultPlan::none(); // pinned: see module docs
    c
}

#[test]
fn disabled_fault_layer_is_bit_identical() {
    let be = testkit::execution_backend();

    let plain = Simulation::new(be.as_ref(), quick(42)).unwrap().run().unwrap();
    // run_config with the empty plan constructs no decorator at all
    let bypassed = run_config(be.as_ref(), quick(42)).unwrap();
    assert_eq!(
        plain.fingerprint(),
        bypassed.fingerprint(),
        "run_config with FaultPlan::none() diverged from a plain run"
    );
    // even an explicitly constructed decorator with the empty plan is a
    // pure passthrough
    let fb = FaultyBackend::new(be.as_ref(), FaultPlan::none(), 42);
    let wrapped = Simulation::new(&fb, quick(42)).unwrap().run().unwrap();
    assert_eq!(
        plain.fingerprint(),
        wrapped.fingerprint(),
        "an empty FaultyBackend decorator changed the report"
    );

    // nothing injected, nothing recovered
    for r in [&plain, &bypassed, &wrapped] {
        assert_eq!(r.faults_injected_exec, 0);
        assert_eq!(r.faults_injected_marshal, 0);
        assert_eq!(r.faults_injected_spikes, 0);
        assert_eq!(r.fault_delay_injected_s, 0.0);
        assert_eq!(r.serve_retries, 0);
        assert_eq!(r.serve_flush_failures, 0);
        assert_eq!(r.breaker_trips, 0);
        assert_eq!(r.degraded_serves, 0);
        assert_eq!(r.drops_backend_unavailable, 0);
        assert_eq!(r.round_rollbacks, 0);
        assert!(r.requests.iter().all(|q| !q.degraded));
    }
}

#[test]
fn arrival_conservation_under_chaos() {
    let be = testkit::execution_backend();
    let mut cfg = quick(7);
    cfg.serve.batch_window_s = 120.0;
    cfg.serve.slo_ms = 300_000.0;
    cfg.faults =
        FaultPlan::parse("exec:0.1,marshal:0.02,spike:0.05x0.5,burst:2,seed:9")
            .unwrap();
    let r = run_config(be.as_ref(), cfg).unwrap();

    assert!(
        r.faults_injected_exec + r.faults_injected_marshal > 0,
        "the chaos plan injected nothing — the decorator is not in the path"
    );
    // every arrival is served or accounted as dropped, never lost
    assert_eq!(
        r.requests.len() as u64 + r.requests_dropped,
        80,
        "requests lost under injected faults"
    );
    assert_eq!(
        r.requests_dropped,
        r.drops_queue_full + r.drops_slo_infeasible + r.drops_backend_unavailable,
        "drop-reason counters do not add up"
    );
    // injected spike latency is charged through virtual time
    if r.faults_injected_spikes > 0 {
        assert!(r.fault_delay_injected_s > 0.0);
    }
}

#[test]
fn heavy_faults_roll_rounds_back_and_still_conserve() {
    let be = testkit::execution_backend();
    let mut cfg = quick(3);
    cfg.faults = FaultPlan::parse("exec:0.4,burst:3,seed:2").unwrap();
    let r = run_config(be.as_ref(), cfg).unwrap();

    assert!(
        r.round_rollbacks > 0,
        "a 40% bursty exec-fault rate never failed a fine-tuning round"
    );
    assert_eq!(
        r.requests.len() as u64 + r.requests_dropped,
        80,
        "requests lost under heavy faults"
    );
    // recovery machinery visibly engaged
    assert!(r.serve_retries + r.serve_flush_failures + r.breaker_trips > 0);
}

#[test]
fn fault_sweeps_are_bit_identical_across_workers() {
    let seeds = [11u64, 12, 13];
    let mut cfg = quick(0);
    cfg.faults =
        FaultPlan::parse("exec:0.08,burst:2,spike:0.03x0.25,seed:5").unwrap();

    let sw1 = ParallelSweeper::new(testkit::refcpu_spec(), 1).unwrap();
    let (m1, all1) = sw1.run_averaged(&cfg, &seeds).unwrap();
    let sw4 = ParallelSweeper::new(testkit::refcpu_spec(), 4).unwrap();
    let (m4, all4) = sw4.run_averaged(&cfg, &seeds).unwrap();

    assert_eq!(all1.len(), all4.len());
    let mut injected = 0u64;
    for (i, (a, b)) in all1.iter().zip(&all4).enumerate() {
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "seed {}: N=1 vs N=4 sweep diverged under injected faults",
            seeds[i]
        );
        // fault bookkeeping is seeded per run: identical across workers
        assert_eq!(a.faults_injected_exec, b.faults_injected_exec);
        assert_eq!(a.faults_injected_marshal, b.faults_injected_marshal);
        assert_eq!(a.serve_retries, b.serve_retries);
        assert_eq!(a.round_rollbacks, b.round_rollbacks);
        injected += a.faults_injected_exec + a.faults_injected_marshal;
    }
    assert!(injected > 0, "no seed injected anything — plan inert");
    assert_eq!(m1.fingerprint(), m4.fingerprint());
}

#[test]
fn fault_seed_varies_the_fault_stream_only() {
    let be = testkit::execution_backend();
    let mut a = quick(5);
    a.faults = FaultPlan::parse("exec:0.15,seed:1").unwrap();
    let mut b = quick(5);
    b.faults = FaultPlan::parse("exec:0.15,seed:2").unwrap();
    let ra = run_config(be.as_ref(), a).unwrap();
    let rb = run_config(be.as_ref(), b).unwrap();
    // same run seed, different fault seed: both conserve arrivals
    for r in [&ra, &rb] {
        assert_eq!(r.requests.len() as u64 + r.requests_dropped, 80);
    }
    // and with the *same* fault seed the whole run is reproducible
    let mut c = quick(5);
    c.faults = FaultPlan::parse("exec:0.15,seed:1").unwrap();
    let rc = run_config(be.as_ref(), c).unwrap();
    assert_eq!(ra.fingerprint(), rc.fingerprint());
    assert_eq!(ra.faults_injected_exec, rc.faults_injected_exec);
}
