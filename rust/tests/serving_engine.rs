//! Serving-engine regression tests: the batcher's pad/scatter round-trip,
//! and the determinism contract — with a zero batch window the engine's
//! reports are bit-identical to the direct (pre-engine) request path,
//! while a real window actually coalesces requests.
//!
//! Since the Backend refactor every test here runs everywhere: the
//! end-to-end tests execute through
//! [`etuner::testkit::execution_backend`] (PJRT when available, the
//! reference executor otherwise), so batching correctness is asserted
//! against a *really executing* model in CI — not just host-side
//! literals.

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::benchmarks::Benchmark;
use etuner::model::ModelSession;
use etuner::serve::{batcher::span_rows, AdaptiveBatcher, QueuedRequest};
use etuner::sim::{RunConfig, Simulation};
use etuner::testkit;

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c
}

// ---------------------------------------------------------------------------
// host-side: pad/scatter round-trip (no artifacts needed)
// ---------------------------------------------------------------------------

/// A deterministic row-wise "model": logits[c] = sum_i x[i] * ((i + c) % 5).
fn fake_logits(x: &[f32], rows: usize, d: usize, classes: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * classes];
    for r in 0..rows {
        for c in 0..classes {
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += x[r * d + i] * ((i + c) % 5) as f32;
            }
            out[r * classes + c] = acc;
        }
    }
    out
}

fn argmax_rows(logits: &[f32], rows: usize, classes: usize) -> Vec<usize> {
    (0..rows)
        .map(|r| {
            let row = &logits[r * classes..(r + 1) * classes];
            let mut best = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

#[test]
fn padded_batch_predictions_match_single_executes() {
    let (d, classes, capacity) = (16, 7, 32);
    let b = AdaptiveBatcher::new(capacity, 10.0, d);
    let reqs: Vec<QueuedRequest> = (0..5)
        .map(|i| {
            let rows = 2 * i + 1; // 1+3+5+7+9 = 25 rows < 32
            QueuedRequest {
                arrival_t: i as f64,
                deadline_t: i as f64 + 1.0,
                scenario: 2,
                stale_batches: 0,
                x: (0..rows * d)
                    .map(|k| ((i * 31 + k * 17) % 13) as f32 - 6.0)
                    .collect(),
                y: vec![0; rows],
                rows,
            }
        })
        .collect();

    // one padded execute over all five requests
    let packed = b.pack(&reqs);
    assert_eq!(packed.rows_used, 25);
    let logits = fake_logits(&packed.x, capacity, d, classes);
    let preds = argmax_rows(&logits, capacity, classes);

    // vs. each request executed alone in its own padded batch
    for (req, span) in reqs.iter().zip(&packed.spans) {
        let alone = b.pack(std::slice::from_ref(req));
        let alone_logits = fake_logits(&alone.x, capacity, d, classes);
        let alone_preds = argmax_rows(&alone_logits, capacity, classes);
        assert_eq!(
            &preds[span.row0..span.row0 + span.rows],
            &alone_preds[..req.rows],
            "request {} predictions diverged in the shared batch",
            span.index
        );
        // scatter returns exactly the request's logit rows
        let got = span_rows(&logits, classes, span);
        let want = &alone_logits[..req.rows * classes];
        assert_eq!(got, want);
    }
}

// ---------------------------------------------------------------------------
// end-to-end (executing backend): determinism + real coalescing
// ---------------------------------------------------------------------------

#[test]
fn window_zero_is_bit_identical_to_direct_path() {
    let be = testkit::execution_backend();

    // engine path with a degenerate window (the default config)
    let mut engine_cfg = quick(21);
    engine_cfg.serve.batch_window_s = 0.0;
    let engine = Simulation::new(be.as_ref(), engine_cfg).unwrap().run().unwrap();

    // direct path: the pre-engine per-request serve, no queue/batcher
    let mut direct_cfg = quick(21);
    direct_cfg.serve_direct = true;
    let direct = Simulation::new(be.as_ref(), direct_cfg).unwrap().run().unwrap();

    assert_eq!(
        engine.fingerprint(),
        direct.fingerprint(),
        "batch-window-0 diverged from the unbatched path:\n  engine: {}\n  direct: {}",
        engine.summary(),
        direct.summary()
    );
    // both modes execute once per request and never coalesce
    for r in [&engine, &direct] {
        assert_eq!(r.serve_executes, r.requests.len() as u64);
        assert!((r.avg_batch_requests - 1.0).abs() < 1e-12);
        assert_eq!(r.rounds_deferred, 0, "empty queue must never defer");
        assert!(r.latency_p99_ms >= r.latency_p50_ms);
        assert!(r.requests.iter().all(|q| q.batch_requests == 1));
    }
}

#[test]
fn real_window_coalesces_requests_deterministically() {
    let be = testkit::execution_backend();
    let mut cfg = quick(5);
    cfg.serve.batch_window_s = 120.0;
    // SLO far beyond the window so the coalescing window (not the
    // deadline-aware early flush) decides when batches close
    cfg.serve.slo_ms = 300_000.0;

    let a = Simulation::new(be.as_ref(), cfg.clone()).unwrap().run().unwrap();
    let b = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert_eq!(
        a.fingerprint(),
        b.fingerprint(),
        "batched serving is not seed-deterministic"
    );

    // every request is served exactly once, in fewer executes
    assert_eq!(a.requests.len(), 80);
    assert!(
        a.serve_executes < a.requests.len() as u64,
        "no batching happened: {} executes for {} requests",
        a.serve_executes,
        a.requests.len()
    );
    assert!(a.avg_batch_requests > 1.0);
    assert!(a.requests.iter().any(|q| q.batch_requests > 1));
    // waiting for the window shows up as latency
    assert!(a.latency_p99_ms > 0.0);
    assert!(a.latency_max_ms >= a.latency_p99_ms);
}

#[test]
fn engine_batch_matches_single_requests_through_real_session() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let p = sess.theta0().unwrap();
    let d = sess.m.d;
    let rows = sess.m.batch_infer / 4;
    let b = AdaptiveBatcher::new(sess.m.batch_infer, 10.0, d);

    let reqs: Vec<QueuedRequest> = (0..3)
        .map(|i| QueuedRequest {
            arrival_t: i as f64,
            deadline_t: i as f64 + 1.0,
            scenario: 1,
            stale_batches: 0,
            x: (0..rows * d).map(|k| ((i + k) % 9) as f32 * 0.1 - 0.4).collect(),
            y: vec![0; rows],
            rows,
        })
        .collect();

    let packed = b.pack(&reqs);
    let logits = sess.infer(&p, &packed.x).unwrap();
    let preds = logits.argmax_rows();

    for (req, span) in reqs.iter().zip(&packed.spans) {
        let alone = b.pack(std::slice::from_ref(req));
        let alone_logits = sess.infer(&p, &alone.x).unwrap();
        let alone_preds = alone_logits.argmax_rows();
        assert_eq!(
            &preds[span.row0..span.row0 + span.rows],
            &alone_preds[..req.rows],
            "request {} predictions changed when batched through the model",
            span.index
        );
    }
}

/// Per-request predictions must not depend on *which* other requests
/// share the padded execute: every way of splitting the same request set
/// into batches yields identical per-request logits rows.
#[test]
fn predictions_are_independent_of_batch_composition() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let p = sess.theta0().unwrap();
    let d = sess.m.d;
    let c = sess.m.classes;
    let rows = sess.m.batch_infer / 8;
    let b = AdaptiveBatcher::new(sess.m.batch_infer, 10.0, d);

    let reqs: Vec<QueuedRequest> = (0..6)
        .map(|i| QueuedRequest {
            arrival_t: i as f64,
            deadline_t: i as f64 + 1.0,
            scenario: 2,
            stale_batches: 0,
            x: (0..rows * d)
                .map(|k| ((i * 13 + k * 7) % 11) as f32 * 0.15 - 0.7)
                .collect(),
            y: vec![0; rows],
            rows,
        })
        .collect();

    // reference: every request alone in its own padded batch
    let alone: Vec<Vec<f32>> = reqs
        .iter()
        .map(|r| {
            let packed = b.pack(std::slice::from_ref(r));
            let logits = sess.infer(&p, &packed.x).unwrap();
            logits.data[..r.rows * c].to_vec()
        })
        .collect();

    // three different compositions of the same six requests
    let groupings: [&[usize]; 3] = [&[6], &[2, 4], &[3, 1, 2]];
    for sizes in groupings {
        let mut i0 = 0;
        for &n in sizes {
            let group = &reqs[i0..i0 + n];
            let packed = b.pack(group);
            let logits = sess.infer(&p, &packed.x).unwrap();
            for (req, span) in group.iter().zip(&packed.spans) {
                let got = span_rows(&logits.data, c, span);
                assert_eq!(
                    got,
                    &alone[i0 + span.index][..],
                    "request {} logits changed in grouping {sizes:?}",
                    i0 + span.index
                );
            }
            i0 += n;
        }
    }
}

/// `--batch-window` sweep through a really executing backend: every
/// window serves all requests, is seed-deterministic, and wider windows
/// never reduce coalescing.
#[test]
fn batch_window_sweep_serves_everything_deterministically() {
    let be = testkit::execution_backend();
    let mut prev_avg = 0.0f64;
    for window in [0.0f64, 30.0, 120.0] {
        let mut cfg = quick(9);
        cfg.serve.batch_window_s = window;
        cfg.serve.slo_ms = 300_000.0;
        let a = Simulation::new(be.as_ref(), cfg.clone()).unwrap().run().unwrap();
        let b = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "window {window}: nondeterministic"
        );
        assert_eq!(a.requests.len(), 80, "window {window}: dropped requests");
        assert!(a.serve_executes > 0);
        assert!(
            a.avg_batch_requests >= prev_avg - 1e-9,
            "window {window}: coalescing regressed ({} < {prev_avg})",
            a.avg_batch_requests
        );
        prev_avg = a.avg_batch_requests;
    }
    assert!(prev_avg > 1.0, "the widest window never coalesced");
}
