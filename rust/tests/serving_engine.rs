//! Serving control-plane regression tests: the batcher's pad/scatter
//! round-trip, the determinism contract — with the default configuration
//! (FIFO, no shedding, zero batch window) the event-driven engine's
//! reports are bit-identical to the direct (pre-engine) request path and
//! across sweep worker counts — and the PR-5 control-plane semantics:
//! EDF ordering on deadline-inverted traces, drop accounting under a
//! tiny `--max-queue`, and BankSet residency (mixed-scenario bursts share
//! executes with zero serving rebuilds after warm-up).
//!
//! Every end-to-end test executes through
//! [`etuner::testkit::execution_backend`] (PJRT when available, the
//! reference executor otherwise), so batching correctness is asserted
//! against a *really executing* model in CI — not just host-side
//! literals.

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::cost::device::DeviceModel;
use etuner::data::benchmarks::{Benchmark, Scenario};
use etuner::model::{Cwr, ModelSession, Params};
use etuner::runtime::{FaultPlan, FaultyBackend};
use etuner::serve::{
    batcher::span_rows, AdaptiveBatcher, Admission, DropReason, QueuePolicyKind,
    QueuedRequest, ServeConfig, ServeCtx, ServeEngine, ServeEvent, ServedRequest,
};
use etuner::sim::{ParallelSweeper, RunConfig, Simulation};
use etuner::testkit;

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c
}

// ---------------------------------------------------------------------------
// host-side: pad/scatter round-trip (no artifacts needed)
// ---------------------------------------------------------------------------

/// A deterministic row-wise "model": logits[c] = sum_i x[i] * ((i + c) % 5).
fn fake_logits(x: &[f32], rows: usize, d: usize, classes: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * classes];
    for r in 0..rows {
        for c in 0..classes {
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += x[r * d + i] * ((i + c) % 5) as f32;
            }
            out[r * classes + c] = acc;
        }
    }
    out
}

fn argmax_rows(logits: &[f32], rows: usize, classes: usize) -> Vec<usize> {
    (0..rows)
        .map(|r| {
            let row = &logits[r * classes..(r + 1) * classes];
            let mut best = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

#[test]
fn padded_batch_predictions_match_single_executes() {
    let (d, classes, capacity) = (16, 7, 32);
    let b = AdaptiveBatcher::new(capacity, 10.0, d);
    let reqs: Vec<QueuedRequest> = (0..5)
        .map(|i| {
            let rows = 2 * i + 1; // 1+3+5+7+9 = 25 rows < 32
            QueuedRequest {
                arrival_t: i as f64,
                deadline_t: i as f64 + 1.0,
                scenario: 2,
                stale_batches: 0,
                x: (0..rows * d)
                    .map(|k| ((i * 31 + k * 17) % 13) as f32 - 6.0)
                    .collect(),
                y: vec![0; rows],
                rows,
            }
        })
        .collect();

    // one padded execute over all five requests
    let packed = b.pack(&reqs);
    assert_eq!(packed.rows_used, 25);
    let logits = fake_logits(&packed.x, capacity, d, classes);
    let preds = argmax_rows(&logits, capacity, classes);

    // vs. each request executed alone in its own padded batch
    for (req, span) in reqs.iter().zip(&packed.spans) {
        let alone = b.pack(std::slice::from_ref(req));
        let alone_logits = fake_logits(&alone.x, capacity, d, classes);
        let alone_preds = argmax_rows(&alone_logits, capacity, classes);
        assert_eq!(
            &preds[span.row0..span.row0 + span.rows],
            &alone_preds[..req.rows],
            "request {} predictions diverged in the shared batch",
            span.index
        );
        // scatter returns exactly the request's logit rows
        let got = span_rows(&logits, classes, span);
        let want = &alone_logits[..req.rows * classes];
        assert_eq!(got, want);
    }
}

// ---------------------------------------------------------------------------
// end-to-end (executing backend): determinism + real coalescing
// ---------------------------------------------------------------------------

#[test]
fn window_zero_is_bit_identical_to_direct_path() {
    let be = testkit::execution_backend();

    // control-plane path with a degenerate window (the default config)
    let mut engine_cfg = quick(21);
    engine_cfg.serve.batch_window_s = 0.0;
    let engine = Simulation::new(be.as_ref(), engine_cfg).unwrap().run().unwrap();

    // direct path: full-draw per-request serving, the pre-engine shape
    let mut direct_cfg = quick(21);
    direct_cfg.serve_direct = true;
    let direct = Simulation::new(be.as_ref(), direct_cfg).unwrap().run().unwrap();

    assert_eq!(
        engine.fingerprint(),
        direct.fingerprint(),
        "batch-window-0 diverged from the unbatched path:\n  engine: {}\n  direct: {}",
        engine.summary(),
        direct.summary()
    );
    // both modes execute once per request, never coalesce, never shed
    for r in [&engine, &direct] {
        assert_eq!(r.serve_executes, r.requests.len() as u64);
        assert!((r.avg_batch_requests - 1.0).abs() < 1e-12);
        assert_eq!(r.rounds_deferred, 0, "empty queue must never defer");
        assert_eq!(r.requests_dropped, 0, "default config must not shed");
        assert_eq!(r.queue_policy, "fifo");
        assert!(r.latency_p99_ms >= r.latency_p50_ms);
        assert!(r.requests.iter().all(|q| q.batch_requests == 1));
    }
}

#[test]
fn real_window_coalesces_requests_deterministically() {
    let be = testkit::execution_backend();
    let mut cfg = quick(5);
    cfg.serve.batch_window_s = 120.0;
    // SLO far beyond the window so the coalescing window (not the
    // deadline-aware early flush) decides when batches close
    cfg.serve.slo_ms = 300_000.0;

    let a = Simulation::new(be.as_ref(), cfg.clone()).unwrap().run().unwrap();
    let b = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert_eq!(
        a.fingerprint(),
        b.fingerprint(),
        "batched serving is not seed-deterministic"
    );

    // every request is served exactly once, in fewer executes
    assert_eq!(a.requests.len(), 80);
    assert!(
        a.serve_executes < a.requests.len() as u64,
        "no batching happened: {} executes for {} requests",
        a.serve_executes,
        a.requests.len()
    );
    assert!(a.avg_batch_requests > 1.0);
    assert!(a.requests.iter().any(|q| q.batch_requests > 1));
    // waiting for the window shows up as latency
    assert!(a.latency_p99_ms > 0.0);
    assert!(a.latency_max_ms >= a.latency_p99_ms);
    // per-scenario digests cover every served request exactly once
    let per: u64 = a.per_scenario_latency.iter().map(|s| s.requests).sum();
    assert_eq!(per, a.requests.len() as u64);
}

#[test]
fn engine_batch_matches_single_requests_through_real_session() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let p = sess.theta0().unwrap();
    let d = sess.m.d;
    let rows = sess.m.batch_infer / 4;
    let b = AdaptiveBatcher::new(sess.m.batch_infer, 10.0, d);

    let reqs: Vec<QueuedRequest> = (0..3)
        .map(|i| QueuedRequest {
            arrival_t: i as f64,
            deadline_t: i as f64 + 1.0,
            scenario: 1,
            stale_batches: 0,
            x: (0..rows * d).map(|k| ((i + k) % 9) as f32 * 0.1 - 0.4).collect(),
            y: vec![0; rows],
            rows,
        })
        .collect();

    let packed = b.pack(&reqs);
    let logits = sess.infer(&p, &packed.x).unwrap();
    let preds = logits.argmax_rows();

    for (req, span) in reqs.iter().zip(&packed.spans) {
        let alone = b.pack(std::slice::from_ref(req));
        let alone_logits = sess.infer(&p, &alone.x).unwrap();
        let alone_preds = alone_logits.argmax_rows();
        assert_eq!(
            &preds[span.row0..span.row0 + span.rows],
            &alone_preds[..req.rows],
            "request {} predictions changed when batched through the model",
            span.index
        );
    }
}

/// Per-request predictions must not depend on *which* other requests
/// share the padded execute: every way of splitting the same request set
/// into batches yields identical per-request logits rows.
#[test]
fn predictions_are_independent_of_batch_composition() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let p = sess.theta0().unwrap();
    let d = sess.m.d;
    let c = sess.m.classes;
    let rows = sess.m.batch_infer / 8;
    let b = AdaptiveBatcher::new(sess.m.batch_infer, 10.0, d);

    let reqs: Vec<QueuedRequest> = (0..6)
        .map(|i| QueuedRequest {
            arrival_t: i as f64,
            deadline_t: i as f64 + 1.0,
            scenario: 2,
            stale_batches: 0,
            x: (0..rows * d)
                .map(|k| ((i * 13 + k * 7) % 11) as f32 * 0.15 - 0.7)
                .collect(),
            y: vec![0; rows],
            rows,
        })
        .collect();

    // reference: every request alone in its own padded batch
    let alone: Vec<Vec<f32>> = reqs
        .iter()
        .map(|r| {
            let packed = b.pack(std::slice::from_ref(r));
            let logits = sess.infer(&p, &packed.x).unwrap();
            logits.data[..r.rows * c].to_vec()
        })
        .collect();

    // three different compositions of the same six requests
    let groupings: [&[usize]; 3] = [&[6], &[2, 4], &[3, 1, 2]];
    for sizes in groupings {
        let mut i0 = 0;
        for &n in sizes {
            let group = &reqs[i0..i0 + n];
            let packed = b.pack(group);
            let logits = sess.infer(&p, &packed.x).unwrap();
            for (req, span) in group.iter().zip(&packed.spans) {
                let got = span_rows(&logits.data, c, span);
                assert_eq!(
                    got,
                    &alone[i0 + span.index][..],
                    "request {} logits changed in grouping {sizes:?}",
                    i0 + span.index
                );
            }
            i0 += n;
        }
    }
}

/// `--batch-window` sweep through a really executing backend: every
/// window serves all requests, is seed-deterministic, and wider windows
/// never reduce coalescing.
#[test]
fn batch_window_sweep_serves_everything_deterministically() {
    let be = testkit::execution_backend();
    let mut prev_avg = 0.0f64;
    for window in [0.0f64, 30.0, 120.0] {
        let mut cfg = quick(9);
        cfg.serve.batch_window_s = window;
        cfg.serve.slo_ms = 300_000.0;
        let a = Simulation::new(be.as_ref(), cfg.clone()).unwrap().run().unwrap();
        let b = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "window {window}: nondeterministic"
        );
        assert_eq!(a.requests.len(), 80, "window {window}: dropped requests");
        assert!(a.serve_executes > 0);
        assert!(
            a.avg_batch_requests >= prev_avg - 1e-9,
            "window {window}: coalescing regressed ({} < {prev_avg})",
            a.avg_batch_requests
        );
        prev_avg = a.avg_batch_requests;
    }
    assert!(prev_avg > 1.0, "the widest window never coalesced");
}

// ---------------------------------------------------------------------------
// control plane (PR 5): admission, EDF, BankSet residency
// ---------------------------------------------------------------------------

/// Drive a bare engine (no simulation) against a really executing session.
struct Rig<'b> {
    sess: ModelSession<'b>,
    params: Params,
    cwr: Cwr,
    scenarios: Vec<Scenario>,
}

impl<'b> Rig<'b> {
    fn new(be: &'b dyn etuner::runtime::Backend) -> Rig<'b> {
        let sess = ModelSession::new(be, "mbv2").unwrap();
        let params = sess.theta0().unwrap();
        let mut cwr = Cwr::new(&sess.m);
        // consolidate classes 0 and 1 from a *diverged* θ so the bank
        // rows differ from the live head: each scenario's serving θ is
        // genuinely distinct, and scattering a request through the wrong
        // head would change its outputs.
        let mut donor = params.clone();
        let h = sess.m.head.w_offset;
        for v in donor.theta_mut()[h..].iter_mut() {
            *v += 0.5;
        }
        cwr.consolidate(&sess.m, &donor, &[0, 1]);
        let scenarios = vec![
            Scenario { id: 0, classes: vec![0], seen: vec![0], new_pattern: false },
            Scenario {
                id: 1,
                classes: vec![1],
                seen: vec![0, 1],
                new_pattern: false,
            },
        ];
        Rig { sess, params, cwr, scenarios }
    }

    fn ctx(&self) -> ServeCtx<'_, 'b> {
        ServeCtx {
            sess: &self.sess,
            params: &self.params,
            cwr: &self.cwr,
            scenarios: &self.scenarios,
        }
    }

    fn engine(&self, cfg: &ServeConfig) -> ServeEngine {
        ServeEngine::new(
            &self.sess.m,
            &DeviceModel::jetson_nx_15w(),
            cfg,
            false,
            false,
        )
    }

    fn request(&self, t: f64, scenario: usize, rows: usize, seed: usize) -> QueuedRequest {
        let d = self.sess.m.d;
        QueuedRequest {
            arrival_t: t,
            deadline_t: t + 1e9,
            scenario,
            stale_batches: 0,
            x: (0..rows * d)
                .map(|k| ((seed * 13 + k * 7) % 11) as f32 * 0.15 - 0.7)
                .collect(),
            y: vec![scenario as i32; rows],
            rows,
        }
    }
}

fn served(events: &[ServeEvent]) -> Vec<ServedRequest> {
    events
        .iter()
        .filter_map(|e| match e {
            ServeEvent::RequestServed(s) => Some(*s),
            _ => None,
        })
        .collect()
}

#[test]
fn edf_serves_deadline_inverted_trace_first() {
    let be = testkit::execution_backend();
    let rig = Rig::new(be.as_ref());
    let cap = rig.sess.m.batch_infer;
    let mut cfg = ServeConfig {
        batch_window_s: 1000.0,
        slo_ms: 1e12,
        rows_per_request: Some(cap), // every request fills its own execute
        ..ServeConfig::default()
    };

    // deadline-inverted trace: the later arrival is the more urgent one
    let trace = |rig: &Rig| -> Vec<QueuedRequest> {
        let mut r1 = rig.request(0.0, 0, cap, 1);
        r1.deadline_t = 1e9;
        let mut r2 = rig.request(1.0, 1, cap, 2);
        r2.deadline_t = 10.0;
        vec![r1, r2]
    };

    let mut orders = Vec::new();
    for policy in [QueuePolicyKind::Fifo, QueuePolicyKind::Edf] {
        cfg.queue_policy = policy;
        let mut eng = rig.engine(&cfg);
        for req in trace(&rig) {
            assert_eq!(eng.on_arrival(req), Admission::Accepted);
        }
        let events = eng.poll(2.0, &rig.ctx()).unwrap();
        let order: Vec<f64> =
            served(&events).iter().map(|s| s.arrival_t).collect();
        orders.push(order);
    }
    assert_eq!(orders[0], vec![0.0, 1.0], "fifo serves in arrival order");
    assert_eq!(
        orders[1],
        vec![1.0, 0.0],
        "edf must serve the earlier deadline first"
    );
}

#[test]
fn tiny_max_queue_drops_and_accounts() {
    let be = testkit::execution_backend();
    let rig = Rig::new(be.as_ref());
    let cfg = ServeConfig {
        batch_window_s: 1000.0,
        slo_ms: 1e12,
        rows_per_request: Some(1), // capacity never binds
        max_queue: 2,
        ..ServeConfig::default()
    };
    let mut eng = rig.engine(&cfg);

    assert_eq!(eng.on_arrival(rig.request(0.0, 0, 1, 1)), Admission::Accepted);
    assert_eq!(eng.on_arrival(rig.request(1.0, 1, 1, 2)), Admission::Accepted);
    assert_eq!(
        eng.on_arrival(rig.request(2.0, 0, 1, 3)),
        Admission::Dropped { reason: DropReason::QueueFull }
    );
    assert_eq!(eng.queue_depth(), 2);

    // the drop surfaces as an event on the next poll
    let events = eng.poll(3.0, &rig.ctx()).unwrap();
    assert!(events.iter().any(|e| matches!(
        e,
        ServeEvent::RequestDropped {
            arrival_t,
            reason: DropReason::QueueFull,
            ..
        } if *arrival_t == 2.0
    )));

    let events = eng.drain(5.0, &rig.ctx()).unwrap();
    assert_eq!(served(&events).len(), 2, "accepted requests still serve");
    assert_eq!(eng.requests_dropped(), 1);
    assert_eq!(eng.drops_queue_full(), 1);
    assert_eq!(eng.drops_slo_infeasible(), 0);
}

#[test]
fn tiny_max_queue_accounts_through_a_full_simulation() {
    let be = testkit::execution_backend();
    let mut cfg = quick(13);
    cfg.serve.batch_window_s = 120.0;
    cfg.serve.slo_ms = 300_000.0;
    cfg.serve.max_queue = 1;
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert!(r.requests_dropped > 0, "a 1-deep queue must shed under bursts");
    assert_eq!(r.drops_queue_full, r.requests_dropped);
    assert_eq!(
        r.requests.len() as u64 + r.requests_dropped,
        80,
        "every arrival is either served or dropped, never lost"
    );
}

#[test]
fn mixed_scenario_burst_shares_executes_without_rebuilds() {
    let be = testkit::execution_backend();
    let rig = Rig::new(be.as_ref());
    let cap = rig.sess.m.batch_infer;
    let rows = cap / 4;
    let cfg = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        bank_capacity: 4, // >= active scenarios: full residency
        ..ServeConfig::default()
    };

    // reference: each request served alone (per-request singleton drains)
    let mut alone = rig.engine(&cfg);
    let mut alone_served = Vec::new();
    for i in 0..8 {
        let req = rig.request(i as f64, i % 2, rows, i);
        assert_eq!(alone.on_arrival(req), Admission::Accepted);
        alone_served.extend(served(&alone.drain(i as f64, &rig.ctx()).unwrap()));
    }
    assert_eq!(alone_served.len(), 8);

    // the same scenario-interleaved burst through mixed batches
    let mut eng = rig.engine(&cfg);
    for i in 0..8 {
        assert_eq!(
            eng.on_arrival(rig.request(i as f64, i % 2, rows, i)),
            Admission::Accepted
        );
    }
    let mut burst = served(&eng.poll(100.0, &rig.ctx()).unwrap());
    assert_eq!(burst.len(), 8);
    // service order groups by scenario within a flush; compare per
    // request by re-sorting on arrival time
    burst.sort_by(|a, b| a.arrival_t.partial_cmp(&b.arrival_t).unwrap());

    // mixed-scenario bursts share executes...
    assert!(
        eng.avg_batch_requests() > 1.0,
        "interleaved scenarios no longer share executes: {} req/exec",
        eng.avg_batch_requests()
    );
    assert!(burst.iter().all(|s| s.batch_requests > 1));
    // ...with one bank install per scenario, zero rebuilds after warm-up
    assert_eq!(eng.serving_rebuilds(), 2, "one install per active scenario");
    assert_eq!(eng.banks_resident(), 2);
    assert_eq!(eng.bank_evictions(), 0);
    let rebuilds_warm = eng.serving_rebuilds();
    for i in 8..16 {
        eng.on_arrival(rig.request(i as f64 + 100.0, i % 2, rows, i));
    }
    let more = served(&eng.poll(300.0, &rig.ctx()).unwrap());
    assert_eq!(more.len(), 8);
    assert_eq!(
        eng.serving_rebuilds(),
        rebuilds_warm,
        "steady-state mixed bursts must not rebuild serving θ"
    );
    assert!(eng.serving_hits() > 0);

    // scatter-through-the-right-head: every mixed-batch request matches
    // its singleton-served twin bit for bit
    for (a, b) in alone_served.iter().zip(&burst) {
        assert_eq!(a.arrival_t, b.arrival_t);
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(
            a.accuracy, b.accuracy,
            "t={}: accuracy changed in the mixed batch",
            a.arrival_t
        );
        assert_eq!(
            a.energy_score, b.energy_score,
            "t={}: energy score changed in the mixed batch",
            a.arrival_t
        );
    }
}

#[test]
fn bank_capacity_one_still_serves_correctly_with_evictions() {
    let be = testkit::execution_backend();
    let rig = Rig::new(be.as_ref());
    let rows = rig.sess.m.batch_infer / 4;
    let mut cfg = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        bank_capacity: 4,
        ..ServeConfig::default()
    };

    let run = |cfg: &ServeConfig| -> (Vec<ServedRequest>, u64) {
        let mut eng = rig.engine(cfg);
        for i in 0..8 {
            eng.on_arrival(rig.request(i as f64, i % 2, rows, i));
        }
        let mut out = served(&eng.poll(100.0, &rig.ctx()).unwrap());
        out.sort_by(|a, b| a.arrival_t.partial_cmp(&b.arrival_t).unwrap());
        (out, eng.bank_evictions())
    };
    let (resident, ev_resident) = run(&cfg);
    cfg.bank_capacity = 1; // the old single-slot behaviour, forced
    let (thrash, ev_thrash) = run(&cfg);

    assert_eq!(ev_resident, 0);
    assert!(ev_thrash > 0, "capacity 1 must evict on every alternation");
    // residency is a pure cache: outputs are identical either way
    for (a, b) in resident.iter().zip(&thrash) {
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.energy_score, b.energy_score);
    }
}

/// PR-6 satellite: a deterministic failing backend exercises the
/// requeue path (`serve_flush` puts unserved groups back via
/// `RequestQueue::requeue_front`) and, once the transient faults clear,
/// the served order — and every served outcome — matches the fault-free
/// run exactly.  Retries are disabled and the breaker is effectively
/// unreachable, so *every* injected fault goes through requeue; a single
/// scenario keeps each batch a single group, so a failed batch requeues
/// whole and recomposes identically on the next take.
#[test]
fn requeue_preserves_service_order_once_faults_clear() {
    let be = testkit::execution_backend();
    let plan = FaultPlan::parse("exec:0.3,seed:4").unwrap();
    let faulty = FaultyBackend::new(be.as_ref(), plan, 1);
    let rig_faulty = Rig::new(&faulty);
    let rig_clean = Rig::new(be.as_ref());

    let rows = rig_clean.sess.m.batch_infer / 4;
    let mut cfg = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    cfg.recovery.max_attempts = 1; // no in-place retry: force requeue
    cfg.recovery.breaker_threshold = 1_000_000; // breaker never trips

    let run = |rig: &Rig| -> (Vec<ServedRequest>, u64, u64) {
        let mut eng = rig.engine(&cfg);
        for i in 0..12 {
            assert_eq!(
                eng.on_arrival(rig.request(i as f64, 0, rows, i)),
                Admission::Accepted
            );
        }
        let events = eng.drain(100.0, &rig.ctx()).unwrap();
        (served(&events), eng.flush_failures(), eng.requests_dropped())
    };

    let (clean, clean_failures, _) = run(&rig_clean);
    let (recovered, failures, dropped) = run(&rig_faulty);

    assert_eq!(clean_failures, 0);
    assert!(
        failures > 0,
        "a 30% exec-fault rate never failed a flush — requeue path untested"
    );
    assert_eq!(dropped, 0, "transient faults must never shed");
    assert_eq!(recovered.len(), clean.len(), "requests lost in requeue");
    for (a, b) in clean.iter().zip(&recovered) {
        assert_eq!(
            a.arrival_t, b.arrival_t,
            "service order changed across requeue/recovery"
        );
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.accuracy, b.accuracy, "t={}: outcome changed", a.arrival_t);
        assert_eq!(a.energy_score, b.energy_score);
        assert!(!b.degraded, "breaker never opened, nothing is degraded");
    }
}

/// PR-8 satellite: the requeue pin above held for FIFO only.  EDF keeps
/// a deadline side-index that `requeue_front` must re-thread; a
/// deadline-*inverted* single-scenario burst through a transiently
/// failing backend must still serve in pure EDF order — and every served
/// outcome must match the fault-free EDF run bit for bit.
#[test]
fn edf_requeue_preserves_deadline_order_once_faults_clear() {
    let be = testkit::execution_backend();
    let plan = FaultPlan::parse("exec:0.3,seed:4").unwrap();
    let faulty = FaultyBackend::new(be.as_ref(), plan, 1);
    let rig_faulty = Rig::new(&faulty);
    let rig_clean = Rig::new(be.as_ref());

    let rows = rig_clean.sess.m.batch_infer / 4;
    let mut cfg = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        queue_policy: QueuePolicyKind::Edf,
        ..ServeConfig::default()
    };
    cfg.recovery.max_attempts = 1; // no in-place retry: force requeue
    cfg.recovery.breaker_threshold = 1_000_000; // breaker never trips

    let run = |rig: &Rig| -> (Vec<ServedRequest>, u64, u64) {
        let mut eng = rig.engine(&cfg);
        for i in 0..12 {
            let mut req = rig.request(i as f64, 0, rows, i);
            req.deadline_t = 2000.0 - i as f64; // later arrival = more urgent
            assert_eq!(eng.on_arrival(req), Admission::Accepted);
        }
        let events = eng.drain(100.0, &rig.ctx()).unwrap();
        (served(&events), eng.flush_failures(), eng.requests_dropped())
    };

    let (clean, clean_failures, _) = run(&rig_clean);
    let (recovered, failures, dropped) = run(&rig_faulty);

    assert_eq!(clean_failures, 0);
    assert!(
        failures > 0,
        "a 30% exec-fault rate never failed a flush — EDF requeue untested"
    );
    assert_eq!(dropped, 0, "transient faults must never shed");
    // EDF genuinely re-ordered: the inverted burst serves in reverse
    let order: Vec<f64> = clean.iter().map(|s| s.arrival_t).collect();
    let want: Vec<f64> = (0..12).rev().map(|i| i as f64).collect();
    assert_eq!(order, want, "EDF did not serve the inverted burst in reverse");
    assert_eq!(recovered.len(), clean.len(), "requests lost in EDF requeue");
    for (a, b) in clean.iter().zip(&recovered) {
        assert_eq!(
            a.arrival_t, b.arrival_t,
            "EDF service order changed across requeue/recovery"
        );
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.accuracy, b.accuracy, "t={}: outcome changed", a.arrival_t);
        assert_eq!(a.energy_score, b.energy_score);
        assert!(!b.degraded, "breaker never opened, nothing is degraded");
    }
}

/// PR-8 satellite: breaker opens mid-burst under EDF, during a *total*
/// outage (`exec:1.0` — every execute faults, deterministically; session
/// setup and bank installs still work because `theta0`/marshal are
/// untouched).  The first capacity flush fails twice and trips the
/// breaker; the degraded-serve attempt faults too (it executes on the
/// same dead backend), so every arrival sheds `BackendUnavailable` — and
/// the shed order within each poll must still be the EDF pop order.
/// Half-open probes at later polls re-fail and re-open the breaker.
#[test]
fn edf_breaker_trips_mid_burst_and_conserves_the_backlog() {
    let be = testkit::execution_backend();
    let plan = FaultPlan::parse("exec:1.0,seed:6").unwrap();
    let faulty = FaultyBackend::new(be.as_ref(), plan, 2);
    let rig = Rig::new(&faulty);

    let rows = rig.sess.m.batch_infer / 4;
    let mut cfg = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        queue_policy: QueuePolicyKind::Edf,
        ..ServeConfig::default()
    };
    cfg.recovery.max_attempts = 1;
    cfg.recovery.breaker_threshold = 2; // two straight failures trip it
    cfg.recovery.breaker_cooldown_s = 5.0; // ... and it cools fast

    // events per poll: EDF order is a per-poll property (each capacity
    // flush pops the earliest deadlines *then queued*; a later poll's
    // arrivals may be more urgent than an earlier poll's survivors)
    let mut polls: Vec<Vec<ServeEvent>> = Vec::new();
    let mut eng = rig.engine(&cfg);
    for i in 0..16 {
        let mut req = rig.request(i as f64, 0, rows, i);
        req.deadline_t = 2000.0 - i as f64; // later arrival = more urgent
        assert_eq!(eng.on_arrival(req), Admission::Accepted);
        polls.push(eng.poll(i as f64, &rig.ctx()).unwrap());
    }
    // advance virtual time: cooldowns elapse, half-open probes fire (and
    // re-fail — the outage is total), the breaker re-opens each time
    let mut t = 60.0;
    while t <= 100.0 {
        polls.push(eng.poll(t, &rig.ctx()).unwrap());
        t += 10.0;
    }
    polls.push(eng.drain(1000.0, &rig.ctx()).unwrap());

    assert!(eng.flush_failures() > 0, "a total outage never failed a flush");
    assert!(
        eng.breaker_trips() > 0,
        "two consecutive failures with threshold 2 never opened the breaker"
    );
    // conservation through the shed path: nothing serves on a dead
    // backend (the degraded attempt executes there too), nothing is lost
    let served_n: usize = polls.iter().map(|evs| served(evs).len()).sum();
    assert_eq!(served_n, 0, "served through a total outage");
    assert_eq!(
        served_n as u64 + eng.requests_dropped(),
        16,
        "requests lost across breaker trips"
    );
    // every shed batch leaves in EDF (deadline) order: within one poll,
    // drop arrival times are non-increasing under the inverted mapping
    for evs in &polls {
        let dropped: Vec<f64> = evs
            .iter()
            .filter_map(|ev| match ev {
                ServeEvent::RequestDropped {
                    arrival_t,
                    reason: DropReason::BackendUnavailable,
                    ..
                } => Some(*arrival_t),
                _ => None,
            })
            .collect();
        for w in dropped.windows(2) {
            assert!(
                w[0] >= w[1],
                "EDF lost deadline order in a shed batch: {} before {}",
                w[0],
                w[1]
            );
        }
    }
}

#[test]
fn default_config_sweep_is_bit_identical_across_workers() {
    let seeds = [11u64, 12, 13, 14];
    let cfg = quick(0); // default control plane: fifo, no cap, window 0

    let sw1 = ParallelSweeper::new(testkit::refcpu_spec(), 1).unwrap();
    let (m1, all1) = sw1.run_averaged(&cfg, &seeds).unwrap();
    let sw4 = ParallelSweeper::new(testkit::refcpu_spec(), 4).unwrap();
    let (m4, all4) = sw4.run_averaged(&cfg, &seeds).unwrap();

    assert_eq!(all1.len(), all4.len());
    for (i, (a, b)) in all1.iter().zip(&all4).enumerate() {
        assert_eq!(a.seed, b.seed, "result order not deterministic");
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "seed {}: N=1 vs N=4 sweep diverged under the control plane",
            seeds[i]
        );
    }
    assert_eq!(m1.fingerprint(), m4.fingerprint());
}
