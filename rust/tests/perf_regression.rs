//! Regression tests for the zero-copy execute boundary and the parallel
//! sweep engine: the caches and the worker pool are pure plumbing, so every
//! scientific output must be bit-identical with them on, off, or sharded
//! across threads.
//!
//! These run on the reference backend, so they *execute real models in
//! every environment* — no artifacts or XLA toolchain required.  (When
//! artifacts are present the refcpu backend binds the same manifest/θ0,
//! so the numbers additionally line up with the PJRT path — see
//! `tests/backend_parity.rs`.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::cost::flops::FreezeState;
use etuner::data::benchmarks::Benchmark;
use etuner::model::ModelSession;
use etuner::runtime::Backend;
use etuner::sim::{run_averaged, ParallelSweeper, RunConfig, Simulation};
use etuner::testkit;
use etuner::trace::{Lane, Tracer};

// ---------------------------------------------------------------------------
// per-thread allocation counter: the regression canary for hidden copies
// in the execution core (a reintroduced `to_vec()` in `dense_train` adds
// ~2 allocations per dense layer per step, far above the bound below).
// Thread-local so parallel test threads can't inflate each other's
// windows.
// ---------------------------------------------------------------------------

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

fn bump_thread_allocs() {
    // try_with: TLS may be gone during thread teardown
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump_thread_allocs();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump_thread_allocs();
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c
}

#[test]
fn infer_skips_theta_marshal_while_generation_unchanged() {
    let be = testkit::refcpu_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let x = vec![0.1f32; sess.m.batch_infer * sess.m.d];

    let a = sess.infer(&p, &x).unwrap();
    assert_eq!(sess.theta_marshal_count(), 1);
    assert_eq!(sess.theta_cache_hit_count(), 0);

    let b = sess.infer(&p, &x).unwrap();
    let c = sess.infer(&p, &x).unwrap();
    assert_eq!(sess.theta_marshal_count(), 1, "unchanged θ re-marshalled");
    assert_eq!(sess.theta_cache_hit_count(), 2);
    assert_eq!(a, b, "cache-hit logits differ from cold logits");
    assert_eq!(a, c);

    // any mutable touch bumps the generation and invalidates the buffer
    p.theta_mut();
    let d = sess.infer(&p, &x).unwrap();
    assert_eq!(sess.theta_marshal_count(), 2);
    assert_eq!(a, d, "identical content must give identical logits");
}

#[test]
fn train_step_reuses_output_buffer_without_remarshal() {
    let be = testkit::refcpu_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let x = vec![0.05f32; sess.m.batch_train * sess.m.d];
    let y: Vec<i32> = (0..sess.m.batch_train).map(|i| (i % 2) as i32).collect();

    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    assert_eq!(sess.theta_marshal_count(), 1);
    // consecutive steps feed the previous step's *output* buffer back in:
    // θ never crosses host → backend buffer again.
    for _ in 0..4 {
        sess.train_step(&mut p, &x, &y, &fs).unwrap();
    }
    assert_eq!(
        sess.theta_marshal_count(),
        1,
        "train chain re-marshalled θ despite output-buffer adoption"
    );
    assert_eq!(sess.theta_cache_hit_count(), 4);
    // inference right after training reuses the adopted buffer too
    let xi = vec![0.1f32; sess.m.batch_infer * sess.m.d];
    sess.infer(&p, &xi).unwrap();
    assert_eq!(sess.theta_marshal_count(), 1);
}

#[test]
fn serving_cache_is_bit_identical_to_forced_invalidation() {
    let be = testkit::refcpu_backend();

    let cached = Simulation::new(be.as_ref(), quick(33)).unwrap().run().unwrap();
    let mut cfg = quick(33);
    cfg.disable_serving_cache = true;
    let forced = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();

    assert_eq!(
        cached.fingerprint(),
        forced.fingerprint(),
        "serving cache changed the scientific output:\n  cached: {}\n  forced: {}",
        cached.summary(),
        forced.summary()
    );
    // the cache actually engaged: every request is either a hit or a rebuild,
    // and the forced path rebuilt on every single request.
    assert_eq!(
        cached.serving_hits + cached.serving_rebuilds,
        cached.requests.len() as u64
    );
    assert_eq!(forced.serving_hits, 0);
    assert_eq!(forced.serving_rebuilds, forced.requests.len() as u64);
    assert!(
        cached.serving_hits > 0,
        "no request ever hit the serving cache (rebuilds {})",
        cached.serving_rebuilds
    );
    // zero-copy proof: cache hits skip the full-θ copy *and* the marshal,
    // so the cached run must marshal θ strictly fewer times.
    assert!(
        cached.theta_marshals < forced.theta_marshals,
        "cached {} !< forced {}",
        cached.theta_marshals,
        forced.theta_marshals
    );
}

#[test]
fn parallel_sweep_matches_sequential_bit_for_bit() {
    let seeds = [1u64, 2, 3, 4];
    let cfg = quick(0);

    let be = testkit::refcpu_backend();
    let (seq_mean, seq_all) = run_averaged(be.as_ref(), &cfg, &seeds).unwrap();

    let sw = ParallelSweeper::new(testkit::refcpu_spec(), 4).unwrap();
    assert_eq!(sw.jobs(), 4);
    let (par_mean, par_all) = sw.run_averaged(&cfg, &seeds).unwrap();

    assert_eq!(seq_all.len(), par_all.len());
    for (i, (s, p)) in seq_all.iter().zip(&par_all).enumerate() {
        assert_eq!(s.seed, p.seed, "result order not deterministic");
        assert_eq!(
            s.fingerprint(),
            p.fingerprint(),
            "seed {} diverged across workers",
            seeds[i]
        );
    }
    assert_eq!(seq_mean.fingerprint(), par_mean.fingerprint());
}

#[test]
fn serving_steady_state_never_repacks() {
    let be = testkit::refcpu_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let p = sess.theta0().unwrap();
    // the serving engine's install hook: marshal + pre-pack
    sess.warm_infer(&p).unwrap();
    let warmed = be.perf();
    assert!(warmed.gemm_packs > 0, "warm built no packs");

    let x = vec![0.1f32; sess.m.batch_infer * sess.m.d];
    let first = sess.infer(&p, &x).unwrap();
    for _ in 0..5 {
        let again = sess.infer(&p, &x).unwrap();
        assert_eq!(first, again);
    }
    let after = be.perf();
    assert_eq!(
        after.gemm_packs, warmed.gemm_packs,
        "steady-state serving re-packed after warm-up"
    );
    assert!(
        after.gemm_pack_hits > warmed.gemm_pack_hits,
        "packed panels never reused"
    );
}

#[test]
fn train_loop_packs_once_per_generation_bump() {
    let be = testkit::refcpu_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let x = vec![0.05f32; sess.m.batch_train * sess.m.d];
    let y: Vec<i32> = (0..sess.m.batch_train).map(|i| (i % 2) as i32).collect();

    // warm-up: prime the scratch arena and the first θ generation's packs
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    let a = be.perf();
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    let b = be.perf();
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    let c = be.perf();

    // each step adopts a fresh θ output value (a new generation), so each
    // step packs exactly one pack set — no more, no less.
    let per_step = b.gemm_packs - a.gemm_packs;
    assert!(per_step > 0, "train step packed nothing");
    assert_eq!(
        c.gemm_packs - b.gemm_packs,
        per_step,
        "packs per generation bump drifted"
    );
    // ... and the scratch arena reaches steady state: zero fresh
    // allocations per step, with every intermediate served from the pool.
    assert_eq!(
        c.scratch_allocs, b.scratch_allocs,
        "steady-state train step allocated fresh scratch"
    );
    assert!(c.scratch_reuses > b.scratch_reuses);
    assert!(c.scratch_bytes_reused > b.scratch_bytes_reused);
}

#[test]
fn train_step_makes_no_hidden_copies() {
    // The alloc-counter canary for the dense_train copy fix: when
    // `quant == false` the tape borrows/moves inputs instead of
    // `to_vec()`-ing them.  A reintroduced copy pair costs ~2 allocs per
    // dense layer per step (mbv2: 14 dense layers → +28), far above the
    // headroom in the bound below.  The counter is thread-local, so the
    // window is exact regardless of parallel test threads.
    let be = testkit::refcpu_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let x = vec![0.05f32; sess.m.batch_train * sess.m.d];
    let y: Vec<i32> = (0..sess.m.batch_train).map(|i| (i % 2) as i32).collect();
    for _ in 0..3 {
        sess.train_step(&mut p, &x, &y, &fs).unwrap();
    }
    let per_step: Vec<u64> = (0..8)
        .map(|_| {
            let before = thread_allocs();
            sess.train_step(&mut p, &x, &y, &fs).unwrap();
            thread_allocs() - before
        })
        .collect();
    let min = *per_step.iter().min().unwrap();
    assert!(
        min <= 48,
        "steady-state train step performed {min} allocations \
         (windows: {per_step:?}) — did a hidden copy sneak back into \
         the execution core?"
    );
}

#[test]
fn disabled_tracer_is_allocation_free() {
    // The default `Tracer::disabled()` is threaded through every serving
    // hot-path record site (arrival, queue counter, flush begin/end,
    // execute span, backend boundary).  This canary drives exactly that
    // per-request call mix for a steady-state burst and demands ZERO
    // allocations — one reintroduced `Vec`/`Rc` in a disabled path shows
    // up immediately.  The counter is thread-local, so the window is
    // exact regardless of parallel test threads.
    let t = Tracer::disabled();
    // warm-up: initialize the process-wide ETUNER_DEBUG OnceLock outside
    // the measured window (its env lookup is one-time setup cost).
    t.instant(Lane::Engine, "arrival", 0.0, &[("scenario", 0.0)]);
    t.debug(Lane::Engine, "warmup", 0.0, &[], format_args!("[dbg] warmup"));
    let before = thread_allocs();
    for i in 0..4096u32 {
        let now = i as f64;
        t.set_now(now);
        t.instant(Lane::Engine, "arrival", now, &[("scenario", 1.0)]);
        t.counter(Lane::Engine, "queue_depth", now, 3.0);
        t.begin(Lane::Engine, "flush", now);
        t.span(
            Lane::Engine,
            "execute",
            now,
            now + 0.5,
            &[("scenario", 1.0), ("requests", 4.0), ("rows", 64.0)],
        );
        t.span(Lane::Backend, "execute", now, now, &[("ok", 1.0)]);
        t.end(Lane::Engine, now + 0.5, &[("groups", 1.0)]);
        t.debug(
            Lane::Engine,
            "served",
            now,
            &[("scenario", 1.0)],
            format_args!("[dbg] t={now:.0}"),
        );
        let clone = t.clone(); // engines/backends clone the handle freely
        std::hint::black_box(&clone);
    }
    let grew = thread_allocs() - before;
    assert_eq!(
        grew, 0,
        "Tracer::disabled() allocated {grew} times across a 4096-request \
         serving burst — the disabled path must be free"
    );
}

#[test]
fn simulation_reports_execution_core_counters() {
    let be = testkit::refcpu_backend();
    let r = Simulation::new(be.as_ref(), quick(44)).unwrap().run().unwrap();
    // e2e plumbing: a full run must show the pack cache and arena working.
    // (Train steps rebuild packs every θ generation by design, so hits
    // are not compared against builds — steady-state serving hits are
    // asserted precisely in `serving_steady_state_never_repacks`.)
    assert!(r.gemm_packs > 0, "no packs in a full simulation");
    assert!(r.gemm_pack_hits > 0, "no pack hits in a full simulation");
    assert!(
        r.scratch_reuses > r.scratch_allocs,
        "arena misses ({}) outnumber reuses ({})",
        r.scratch_allocs,
        r.scratch_reuses
    );
    assert!(r.scratch_bytes_reused > 0);
}

#[test]
fn run_averaged_many_preserves_config_order() {
    let seeds = [5u64, 6];
    let cfgs = vec![
        quick(0).with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None),
        quick(0).with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ];

    let one = ParallelSweeper::new(testkit::refcpu_spec(), 1).unwrap();
    let four = ParallelSweeper::new(testkit::refcpu_spec(), 4).unwrap();
    let a = one.run_averaged_many(&cfgs, &seeds).unwrap();
    let b = four.run_averaged_many(&cfgs, &seeds).unwrap();
    assert_eq!(a.len(), 2);
    assert_eq!(b.len(), 2);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tune_policy, y.tune_policy);
        assert_eq!(x.fingerprint(), y.fingerprint());
    }
    // the two configs are genuinely different experiments
    assert_ne!(a[0].fingerprint(), a[1].fingerprint());
}
