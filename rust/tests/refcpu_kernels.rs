//! Property tests for the reference executor, driven through the public
//! `Backend`/`ModelSession` surface (hand-rolled generators — proptest is
//! not available offline).  These are the behavioural contracts the PJRT
//! artifacts satisfy, now asserted on every machine:
//!
//! * a train step decreases loss on a fixed batch (the model learns);
//! * inference is permutation-equivariant over batch rows (row
//!   independence — the property batched serving relies on);
//! * θ round-trip through marshal/read-back is bit-lossless;
//! * prefix-frozen and lr-masked units do not move, trainable ones do;
//! * CKA(x, x) = 1 and drifts below 1 after training;
//! * the SimSiam step is finite and in the cosine-loss range.

use etuner::cost::flops::FreezeState;
use etuner::model::ModelSession;
use etuner::rng::Pcg32;
use etuner::runtime::{Backend, RefCpuBackend};
use etuner::testkit::two_class_batch;

fn backend() -> RefCpuBackend {
    RefCpuBackend::builtin().unwrap()
}

#[test]
fn train_step_decreases_loss_on_fixed_batch() {
    for model in ["res50", "mbv2", "deit", "bert"] {
        let be = backend();
        let mut sess = ModelSession::new(&be, model).unwrap();
        sess.lr = 0.05;
        let mut p = sess.theta0().unwrap();
        let fs = FreezeState::none(sess.m.units);
        let mut rng = Pcg32::new(7, 7);
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        let first = sess.train_step(&mut p, &x, &y, &fs).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = sess.train_step(&mut p, &x, &y, &fs).unwrap();
            assert!(last.is_finite(), "{model}: loss diverged");
        }
        assert!(
            last < first * 0.5,
            "{model}: loss did not decrease ({first} -> {last})"
        );
    }
}

#[test]
fn training_generalizes_to_fresh_draws() {
    let be = backend();
    let mut sess = ModelSession::new(&be, "mbv2").unwrap();
    sess.lr = 0.05;
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(17, 3);
    for _ in 0..40 {
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        sess.train_step(&mut p, &x, &y, &fs).unwrap();
    }
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_infer, sess.m.d);
    let acc = sess.accuracy(&p, &x, &y).unwrap();
    assert!(acc > 0.8, "held-out accuracy {acc}");
}

#[test]
fn infer_is_permutation_equivariant_over_rows() {
    let be = backend();
    let sess = ModelSession::new(&be, "deit").unwrap();
    let p = sess.theta0().unwrap();
    let (b, d, c) = (sess.m.batch_infer, sess.m.d, sess.m.classes);
    let mut rng = Pcg32::new(23, 5);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal()).collect();
    let logits = sess.infer(&p, &x).unwrap();

    // reverse the rows: logits must reverse identically (bit-exact — row
    // computations are independent in every kernel).
    let mut xr = vec![0.0f32; b * d];
    for i in 0..b {
        xr[i * d..(i + 1) * d].copy_from_slice(&x[(b - 1 - i) * d..(b - i) * d]);
    }
    let logits_r = sess.infer(&p, &xr).unwrap();
    for i in 0..b {
        assert_eq!(
            &logits.data[i * c..(i + 1) * c],
            &logits_r.data[(b - 1 - i) * c..(b - i) * c],
            "row {i} changed under permutation"
        );
    }
}

#[test]
fn theta_roundtrip_through_marshal_is_lossless() {
    let be = backend();
    for model in ["res50", "bert"] {
        let theta = be.theta0(model).unwrap();
        let v = be.marshal_f32(&theta, &[theta.len()]).unwrap();
        let back = v.read_f32().unwrap();
        assert_eq!(theta.len(), back.len());
        for (i, (a, b)) in theta.iter().zip(&back).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{model}: θ[{i}] changed bits in the marshal round-trip"
            );
        }
    }
}

#[test]
fn prefix_frozen_units_do_not_move() {
    let be = backend();
    let sess = ModelSession::new(&be, "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let p0 = p.clone();
    let mut fs = FreezeState::none(sess.m.units);
    fs.frozen[0] = true;
    fs.frozen[1] = true;
    let mut rng = Pcg32::new(8, 8);
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    for u in 0..sess.m.units {
        let moved = p
            .unit(&sess.m, u)
            .iter()
            .zip(p0.unit(&sess.m, u))
            .any(|(a, b)| a != b);
        if u < 2 {
            assert!(!moved, "frozen unit {u} moved");
        } else {
            assert!(moved, "trainable unit {u} did not move");
        }
    }
}

#[test]
fn interior_lr_mask_freezes_unit() {
    let be = backend();
    let sess = ModelSession::new(&be, "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let p0 = p.clone();
    let mut fs = FreezeState::none(sess.m.units);
    fs.frozen[3] = true; // interior unit: lr-mask path (Case 2)
    let mut rng = Pcg32::new(9, 9);
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    sess.train_step(&mut p, &x, &y, &fs).unwrap();
    let moved3 = p
        .unit(&sess.m, 3)
        .iter()
        .zip(p0.unit(&sess.m, 3))
        .any(|(a, b)| a != b);
    assert!(!moved3, "masked unit moved");
    let moved2 = p
        .unit(&sess.m, 2)
        .iter()
        .zip(p0.unit(&sess.m, 2))
        .any(|(a, b)| a != b);
    assert!(moved2);
}

#[test]
fn features_and_cka_probe_work() {
    let be = backend();
    let sess = ModelSession::new(&be, "res50").unwrap();
    let p = sess.theta0().unwrap();
    let x = {
        let mut rng = Pcg32::new(10, 10);
        (0..sess.m.batch_probe * sess.m.d)
            .map(|_| rng.normal())
            .collect::<Vec<f32>>()
    };
    let f = sess.features(&p, &x).unwrap();
    assert_eq!(f.shape, vec![sess.m.blocks + 1, sess.m.batch_probe, sess.m.h]);
    // identical models -> CKA == 1 for every layer
    for l in 0..sess.m.blocks + 1 {
        let cka = sess.cka_layer(&f, &f, l).unwrap();
        assert!((cka - 1.0).abs() < 1e-4, "layer {l}: {cka}");
    }
}

#[test]
fn cka_drifts_after_training() {
    let be = backend();
    let mut sess = ModelSession::new(&be, "mbv2").unwrap();
    sess.lr = 0.1;
    let mut p = sess.theta0().unwrap();
    let p0 = p.clone();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(11, 11);
    let (probe, _) = two_class_batch(&mut rng, sess.m.batch_probe, sess.m.d);
    for _ in 0..20 {
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        sess.train_step(&mut p, &x, &y, &fs).unwrap();
    }
    let f0 = sess.features(&p0, &probe).unwrap();
    let f1 = sess.features(&p, &probe).unwrap();
    let mut min_cka = f32::INFINITY;
    for l in 0..sess.m.blocks + 1 {
        min_cka = min_cka.min(sess.cka_layer(&f1, &f0, l).unwrap());
    }
    assert!(min_cka < 0.9999, "nothing drifted: {min_cka}");
}

#[test]
fn ssl_step_runs_and_is_in_cosine_range() {
    let be = backend();
    let sess = ModelSession::new(&be, "mbv2").unwrap();
    let mut p = sess.theta0().unwrap();
    let mut phi = be.phi0("mbv2").unwrap();
    assert_eq!(phi.len(), sess.m.artifacts.ssl_phi_len);
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(12, 12);
    let (x, _) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    let x2: Vec<f32> = x.iter().map(|v| v * 1.05).collect();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..5 {
        last = sess.ssl_step(&mut p, &mut phi, &x, &x2, &fs).unwrap();
        assert!(last.is_finite());
        assert!((-1.0 - 1e-5..=1.0 + 1e-5).contains(&last), "cosine loss {last}");
        first.get_or_insert(last);
    }
    // full-batch descent on a fixed view pair must not move away from
    // alignment
    assert!(
        last <= first.unwrap() + 1e-4,
        "ssl loss rose: {:?} -> {last}",
        first
    );
}

#[test]
fn quant_train_step_runs_and_learns() {
    let be = backend();
    let mut sess = ModelSession::new(&be, "res50").unwrap();
    sess.quant = true;
    sess.lr = 0.05;
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(13, 13);
    let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
    let first = sess.train_step(&mut p, &x, &y, &fs).unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = sess.train_step(&mut p, &x, &y, &fs).unwrap();
        assert!(last.is_finite());
    }
    assert!(last < first, "QAT loss did not decrease ({first} -> {last})");
}

#[test]
fn energy_scores_are_finite_after_training() {
    let be = backend();
    let mut sess = ModelSession::new(&be, "mbv2").unwrap();
    sess.lr = 0.05;
    let mut p = sess.theta0().unwrap();
    let fs = FreezeState::none(sess.m.units);
    let mut rng = Pcg32::new(14, 14);
    for _ in 0..60 {
        let (x, y) = two_class_batch(&mut rng, sess.m.batch_train, sess.m.d);
        let loss = sess.train_step(&mut p, &x, &y, &fs).unwrap();
        assert!(loss.is_finite(), "warmup diverged");
    }
    let (x, _) = two_class_batch(&mut rng, sess.m.batch_infer, sess.m.d);
    let scores = sess.energy_scores(&p, &x).unwrap();
    assert!(scores.iter().all(|s| s.is_finite()), "{scores:?}");
}
