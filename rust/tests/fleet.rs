//! Fleet-router determinism battery (PR 8).
//!
//! Three contracts, mirroring `tests/faults.rs`:
//!
//! * **Transparency** — a fleet of one is a pure wrapper: the simulation
//!   path keeps the scientific fingerprint bit-identical to the
//!   engine-only control plane, and the library pool path reproduces a
//!   bare [`ServeEngine`] drive event-for-event, histogram-for-histogram.
//! * **Conservation** — under any routing (affinity, least-loaded
//!   fallback, cross-engine queue-full retries, rebalance installs) every
//!   arrival is served or accounted as dropped — including with a fault
//!   plan degrading engine 0 until its breaker opens.
//! * **Worker-count independence** — `run_pool` merges per-engine
//!   events, histograms, counters, and trace batches in engine-id order,
//!   so the sequential and threaded pools yield bit-identical results.

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::cost::device::DeviceModel;
use etuner::data::benchmarks::{Benchmark, Scenario};
use etuner::metrics::hist::HistRegistry;
use etuner::model::{Cwr, ModelSession};
use etuner::runtime::FaultPlan;
use etuner::serve::{
    run_pool, FaultScope, FleetConfig, FleetPoolSpec, FleetYield,
    QueuedRequest, ServeConfig, ServeCtx, ServeEvent,
};
use etuner::sim::{RunConfig, Simulation};
use etuner::testkit;

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c.faults = FaultPlan::none(); // pinned: see tests/faults.rs module docs
    c
}

/// Scenario table shared by the pool spec and the bare-engine control
/// (unconsolidated CWR, exactly like the pool's per-worker stack).
fn scenarios(n: usize) -> Vec<Scenario> {
    (0..n)
        .map(|s| Scenario {
            id: s,
            classes: vec![s],
            seen: (0..=s).collect(),
            new_pattern: false,
        })
        .collect()
}

/// Deterministic ascending-arrival workload over `n_scenarios` scenarios.
fn workload(
    d: usize,
    rows: usize,
    n: usize,
    n_scenarios: usize,
) -> Vec<QueuedRequest> {
    (0..n)
        .map(|i| {
            let scenario = i % n_scenarios;
            QueuedRequest {
                arrival_t: i as f64 * 2.0,
                deadline_t: i as f64 * 2.0 + 1e9,
                scenario,
                stale_batches: 0,
                x: (0..rows * d)
                    .map(|k| ((i * 13 + k * 7) % 11) as f32 * 0.15 - 0.7)
                    .collect(),
                y: vec![scenario as i32; rows],
                rows,
            }
        })
        .collect()
}

fn spec(
    serve: ServeConfig,
    fleet: FleetConfig,
    n_scenarios: usize,
    trace: bool,
) -> FleetPoolSpec {
    FleetPoolSpec {
        backend: testkit::refcpu_spec(),
        model: "mbv2".into(),
        device: DeviceModel::jetson_nx_15w(),
        scenarios: scenarios(n_scenarios),
        serve,
        fleet,
        trace,
        faults: FaultPlan::none(),
        fault_seed: 0,
    }
}

/// Events (and trace batches) carry `f64`s and `&'static str`s but no
/// `PartialEq`; their derived `Debug` output round-trips every float
/// exactly, so string equality is bit equality.
fn rendered(events: &[(usize, ServeEvent)]) -> Vec<String> {
    events.iter().map(|(e, ev)| format!("e{e} {ev:?}")).collect()
}

// ---------------------------------------------------------------------------
// transparency: a fleet of one is a pure wrapper
// ---------------------------------------------------------------------------

/// Library-level half of the fleet-of-1 contract: `run_pool` with one
/// engine reproduces a hand-driven bare [`ServeEngine`] — same events in
/// the same order, same merged histograms, same counters.
#[test]
fn fleet_of_one_pool_matches_a_bare_engine_drive() {
    let serve = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        ..ServeConfig::default()
    };
    // same backend kind the pool spec names, so outputs match bit for bit
    let be = testkit::refcpu_spec().create().unwrap();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let mut cfg = spec(serve, FleetConfig::default(), 3, false);
    cfg.serve.rows_per_request = Some(rows);
    let wl = workload(sess.m.d, rows, 12, 3);
    let drain_t = 500.0;

    // bare engine, driven exactly like the pool coordinator: arrive, poll
    // at the arrival instant, final drain
    let params = sess.theta0().unwrap();
    let cwr = Cwr::new(&sess.m);
    let scen = scenarios(3);
    let ctx = ServeCtx { sess: &sess, params: &params, cwr: &cwr, scenarios: &scen };
    let mut eng = etuner::serve::ServeEngine::new(
        &sess.m,
        &cfg.device,
        &cfg.serve,
        false,
        false,
    );
    let mut bare: Vec<(usize, ServeEvent)> = Vec::new();
    for req in &wl {
        let t = req.arrival_t;
        eng.on_arrival(req.clone());
        bare.extend(eng.poll(t, &ctx).unwrap().into_iter().map(|ev| (0, ev)));
    }
    bare.extend(eng.drain(drain_t, &ctx).unwrap().into_iter().map(|ev| (0, ev)));
    let mut bare_hists = HistRegistry::new();
    eng.fill_hists(&mut bare_hists);

    let y: FleetYield = run_pool(&cfg, &wl, drain_t, false).unwrap();

    assert_eq!(
        rendered(&y.events),
        rendered(&bare),
        "fleet-of-1 event stream diverged from the bare engine"
    );
    assert_eq!(y.hists, bare_hists, "merged registry is not the engine's own");
    assert_eq!(y.counters.served, eng.served());
    assert_eq!(y.counters.executes, eng.executes());
    assert_eq!(y.counters.serving_rebuilds, eng.serving_rebuilds());
    assert_eq!(y.counters.requests_dropped(), eng.requests_dropped());
    assert_eq!(y.counters.router.cross_engine_retries, 0);
    assert_eq!(y.counters.router.rebalances, 0, "n=1 never rebalances");
}

/// Simulation-level half: under the default serve config (window 0,
/// FIFO, no shedding) every request serves alone at its own arrival poll
/// on whichever engine it routed to, so the scientific fingerprint is
/// bit-identical for a fleet of 1 and a fleet of 4 — and the served
/// sequence matches request-for-request.
#[test]
fn fleet_of_four_keeps_the_scientific_fingerprint() {
    let be = testkit::execution_backend();

    let one = Simulation::new(be.as_ref(), quick(17)).unwrap().run().unwrap();
    let mut cfg = quick(17);
    cfg.fleet.engines = 4;
    let four = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();

    assert_eq!(
        one.fingerprint(),
        four.fingerprint(),
        "--fleet 4 changed the scientific fields:\n  one:  {}\n  four: {}",
        one.summary(),
        four.summary()
    );
    assert_eq!(one.requests.len(), four.requests.len());
    for (a, b) in one.requests.iter().zip(&four.requests) {
        assert_eq!(a.t, b.t, "served order changed under fleet routing");
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }

    // observability tells the two runs apart
    assert_eq!(one.fleet_engines, 1);
    assert_eq!(four.fleet_engines, 4);
    // every arrival routed exactly once
    assert_eq!(
        four.fleet_routed_affinity + four.fleet_routed_least_loaded,
        80,
        "routing decisions do not cover the arrivals"
    );
    // the fleet budget is N device-horizons, so all four engines' idle
    // time is accounted: busy + idle == 4 x (busy_1 + idle_1)
    let sum1 = one.time_serving_s + one.time_tuning_s + one.time_idle_s;
    let sum4 = four.time_serving_s + four.time_tuning_s + four.time_idle_s;
    assert!(
        (sum4 - 4.0 * sum1).abs() <= 1e-6 * sum1.max(1.0),
        "fleet time-in-state budget is not 4 device-horizons: {sum4} vs 4x{sum1}"
    );
}

// ---------------------------------------------------------------------------
// worker-count independence: sequential == threaded, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn sequential_and_threaded_pools_are_bit_identical() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let serve = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    let fleet = FleetConfig { engines: 4, ..FleetConfig::default() };
    let cfg = spec(serve, fleet, 3, true);
    let wl = workload(sess.m.d, rows, 24, 3);

    let seq = run_pool(&cfg, &wl, 1000.0, false).unwrap();
    let thr = run_pool(&cfg, &wl, 1000.0, true).unwrap();

    assert_eq!(
        rendered(&seq.events),
        rendered(&thr.events),
        "merged event stream depends on the pool mode"
    );
    assert_eq!(seq.hists, thr.hists, "merged histograms diverged");
    assert_eq!(seq.counters, thr.counters, "fleet counters diverged");
    assert_eq!(seq.trace.len(), 4);
    assert_eq!(
        format!("{:?}", seq.trace),
        format!("{:?}", thr.trace),
        "per-engine trace batches diverged"
    );

    // the run actually exercised the fleet: everything served, spread
    // across engines, with affinity doing the routing after warm-up
    assert_eq!(seq.counters.served + seq.counters.requests_dropped(), 24);
    assert_eq!(seq.counters.requests_dropped(), 0, "nothing sheds here");
    assert_eq!(
        seq.counters.router.routed_by_affinity
            + seq.counters.router.routed_least_loaded,
        24
    );
    assert!(
        seq.counters.router.routed_by_affinity > 0,
        "repeated scenarios never hit the affinity path"
    );
    // each engine's tracer recorded its own lane activity
    assert!(seq.trace.iter().filter(|t| !t.is_empty()).count() > 1);
}

// ---------------------------------------------------------------------------
// conservation under routing, retries, rebalancing, and faults
// ---------------------------------------------------------------------------

/// A 1-deep queue forces the affinity target to answer queue-full, so
/// arrivals take the probe -> retry-least-loaded path before shedding.
#[test]
fn queue_full_retries_cross_engines_and_conserve_arrivals() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let serve = ServeConfig {
        batch_window_s: 1000.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        max_queue: 1,
        ..ServeConfig::default()
    };
    let fleet = FleetConfig { engines: 2, ..FleetConfig::default() };
    let cfg = spec(serve, fleet, 1, false); // one scenario: pure affinity
    let wl = workload(sess.m.d, rows, 6, 1);

    let y = run_pool(&cfg, &wl, 5000.0, false).unwrap();
    assert!(
        y.counters.router.cross_engine_retries > 0,
        "queue-full hints never redirected a request"
    );
    assert_eq!(
        y.counters.served + y.counters.requests_dropped(),
        6,
        "requests lost across the retry path"
    );
    assert_eq!(
        y.counters.requests_dropped(),
        y.counters.drops_queue_full
            + y.counters.drops_slo_infeasible
            + y.counters.drops_backend_unavailable,
        "drop-reason counters do not add up"
    );
}

/// A hot scenario (every arrival, one engine) crosses the rebalance
/// threshold; the router installs a second bank and later arrivals
/// spread — while arrivals stay conserved.
#[test]
fn hot_scenario_rebalances_onto_a_second_engine() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let serve = ServeConfig {
        batch_window_s: 1000.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    let fleet = FleetConfig {
        engines: 2,
        rebalance_threshold: 0.3,
        ..FleetConfig::default()
    };
    let cfg = spec(serve, fleet, 1, false);
    let wl = workload(sess.m.d, rows, 8, 1);

    let y = run_pool(&cfg, &wl, 5000.0, false).unwrap();
    assert!(
        y.counters.router.rebalances >= 1,
        "an all-one-scenario burst never tripped the rebalance threshold"
    );
    assert_eq!(y.counters.served + y.counters.requests_dropped(), 8);
    // the install itself shows up as a serving rebuild on the target
    assert!(y.counters.serving_rebuilds >= 2);
}

/// One engine behind a seeded fault plan, breaker tuned to open after
/// two consecutive flush failures: the fleet still accounts for every
/// arrival, and the sequential/threaded pools agree even mid-outage.
#[test]
fn arrival_conservation_holds_with_one_engine_degraded() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let mut serve = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    serve.recovery.max_attempts = 1; // every fault is a flush failure
    serve.recovery.breaker_threshold = 2; // ... and two of them trip it
    serve.recovery.breaker_cooldown_s = 1e9; // stays open through drain
    let fleet = FleetConfig { engines: 2, ..FleetConfig::default() };
    let mut cfg = spec(serve, fleet, 2, false);
    // rate 1.0: engine 0's executor is deterministically down for the
    // whole run (theta0/manifest are passthrough, so setup still works)
    cfg.faults = FaultPlan::parse("exec:1.0,seed:3").unwrap();
    cfg.fault_seed = 9;
    let wl = workload(sess.m.d, rows, 16, 2);

    let seq = run_pool(&cfg, &wl, 1000.0, false).unwrap();
    let thr = run_pool(&cfg, &wl, 1000.0, true).unwrap();

    assert!(
        seq.counters.flush_failures > 0,
        "the chaos plan injected nothing — the decorator is not in the path"
    );
    assert!(
        seq.counters.breaker_trips > 0,
        "engine 0's breaker never opened with its executor down"
    );
    // every arrival is served or accounted as dropped, never lost —
    // including requests that crossed engines chasing capacity
    assert_eq!(
        seq.counters.served + seq.counters.requests_dropped(),
        16,
        "requests lost with one engine degraded"
    );
    assert_eq!(
        seq.counters.requests_dropped(),
        seq.counters.drops_queue_full
            + seq.counters.drops_slo_infeasible
            + seq.counters.drops_backend_unavailable
    );
    // fault streams are seeded per engine id, so the outage replays
    // bit-identically across pool modes
    assert_eq!(seq.counters, thr.counters, "fault replay diverged");
    assert_eq!(rendered(&seq.events), rendered(&thr.events));
    assert_eq!(seq.hists, thr.hists);
}

/// `--fault-scope all` puts every engine behind its own fault decorator
/// (per-engine salted seeds).  With every executor deterministically down,
/// no engine can serve or even install a bank — yet every arrival is still
/// accounted, multiple breakers trip, and the sequential/threaded pools
/// agree bit for bit.  The default `engine0` scope on the same plan keeps
/// engines 1..N healthy, so requests still get served — the two scopes are
/// observably different.
#[test]
fn fault_scope_all_degrades_every_engine_and_conserves_arrivals() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let mut serve = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    serve.recovery.max_attempts = 1; // every fault is a flush failure
    serve.recovery.breaker_threshold = 2; // ... and two of them trip it
    serve.recovery.breaker_cooldown_s = 1e9; // stays open through drain
    let fleet = FleetConfig { engines: 4, ..FleetConfig::default() };
    let mut cfg = spec(serve, fleet, 2, false);
    cfg.faults = FaultPlan::parse("exec:1.0,seed:3").unwrap();
    cfg.fault_seed = 9;
    let wl = workload(sess.m.d, rows, 24, 2);

    // default scope: only engine 0 is down, the rest of the fleet serves
    let one = run_pool(&cfg, &wl, 1000.0, false).unwrap();
    assert!(
        one.counters.served > 0,
        "healthy engines stopped serving under an engine0-scoped outage"
    );
    assert_eq!(one.counters.served + one.counters.requests_dropped(), 24);

    // all scope: every engine is down — nothing serves, nothing is lost
    cfg.fleet.fault_scope = FaultScope::All;
    let seq = run_pool(&cfg, &wl, 1000.0, false).unwrap();
    let thr = run_pool(&cfg, &wl, 1000.0, true).unwrap();
    assert_eq!(seq.counters, thr.counters, "all-scope fault replay diverged");
    assert_eq!(rendered(&seq.events), rendered(&thr.events));
    assert_eq!(seq.hists, thr.hists);
    assert_eq!(
        seq.counters.served, 0,
        "a fully degraded fleet somehow served a request"
    );
    assert_eq!(
        seq.counters.served + seq.counters.requests_dropped(),
        24,
        "requests lost with the whole fleet degraded"
    );
    assert!(
        seq.counters.breaker_trips >= 2,
        "only one breaker tripped — the fault scope did not reach the \
         other engines"
    );
}

/// Load-layer satellite (PR 10): a Zipf-skewed scenario mix at the
/// ISSUE's s=1.2 concentrates enough arrivals on the hot scenario
/// (seed-pinned: 17 of 24 land on scenario 1, ~59% in expectation) that
/// the *default* rebalance threshold (0.5) trips — no hand-tuned
/// threshold like the all-one-scenario test above — while arrivals stay
/// conserved.
#[test]
fn zipf_skewed_mix_trips_the_default_rebalance_threshold() {
    use etuner::load::{MixSampler, MixSpec};
    use etuner::rng::Pcg32;

    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let serve = ServeConfig {
        batch_window_s: 1000.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    let fleet = FleetConfig { engines: 2, ..FleetConfig::default() };
    assert!(
        (fleet.rebalance_threshold - 0.5).abs() < 1e-12,
        "test exercises the default threshold; update if the default moves"
    );
    let cfg = spec(serve, fleet, 4, false);

    let mix = MixSpec::parse("zipf:s=1.2,k=3").unwrap();
    let sampler = MixSampler::new(&mix, 4, 1000.0);
    let mut rng = Pcg32::new(9, 13);
    let mut wl = workload(sess.m.d, rows, 24, 4);
    let mut hot = 0usize;
    for req in &mut wl {
        let s = sampler.scenario_at(req.arrival_t, &mut rng);
        req.scenario = s;
        req.y = vec![s as i32; rows];
        hot += (s == 1) as usize;
    }
    assert!(
        hot * 2 > 24,
        "seed-pinned draw lost its majority hot scenario ({hot}/24)"
    );

    let y = run_pool(&cfg, &wl, 5000.0, false).unwrap();
    assert!(
        y.counters.router.rebalances >= 1,
        "a majority-hot Zipf mix never tripped the default 0.5 threshold"
    );
    assert_eq!(
        y.counters.served + y.counters.requests_dropped(),
        24,
        "requests lost under the skewed mix"
    );
}

/// The ablation arm: affinity off routes purely least-loaded.
#[test]
fn affinity_off_never_routes_by_affinity() {
    let be = testkit::execution_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let rows = sess.m.batch_infer / 4;
    let serve = ServeConfig {
        batch_window_s: 50.0,
        slo_ms: 1e12,
        rows_per_request: Some(rows),
        ..ServeConfig::default()
    };
    let fleet =
        FleetConfig { engines: 2, affinity: false, ..FleetConfig::default() };
    let cfg = spec(serve, fleet, 2, false);
    let wl = workload(sess.m.d, rows, 10, 2);

    let y = run_pool(&cfg, &wl, 1000.0, false).unwrap();
    assert_eq!(y.counters.router.routed_by_affinity, 0);
    assert_eq!(y.counters.router.routed_least_loaded, 10);
    assert_eq!(y.counters.served + y.counters.requests_dropped(), 10);
}
