//! Property-based tests on coordinator invariants (hand-rolled generators —
//! proptest is not available offline).  Each property runs over hundreds of
//! randomized cases seeded from a PCG stream, so failures are reproducible.

use etuner::coordinator::policy::{TunePolicy, TunePolicyKind};
use etuner::coordinator::{curve, EnergyOod, LazyTune};
use etuner::cost::flops::FreezeState;
use etuner::nnls::{nnls, Mat};
use etuner::rng::Pcg32;

/// Property: whatever signal sequence LazyTune sees, `batches_needed`
/// stays within [1, cap] and triggering is monotone in buffered batches.
#[test]
fn prop_lazytune_threshold_always_in_bounds() {
    let mut rng = Pcg32::new(101, 1);
    for case in 0..300 {
        let cap = 1 + rng.below(40);
        let mut lt = LazyTune::new(cap);
        let mut iters = 0u64;
        for _ in 0..rng.below(60) {
            match rng.below(4) {
                0 => {
                    iters += 1 + rng.below(10) as u64;
                    lt.on_round_end(iters, rng.f64());
                }
                1 => lt.on_inference(),
                2 => lt.on_scenario_change(),
                _ => {}
            }
            let n = lt.batches_needed();
            assert!(
                (1..=cap).contains(&n),
                "case {case}: batches_needed {n} not in [1, {cap}]"
            );
            // monotone triggering
            if lt.should_trigger(3) {
                assert!(lt.should_trigger(4));
            }
            if !lt.should_trigger(5) {
                assert!(!lt.should_trigger(4));
            }
        }
    }
}

/// Property: the log-decay from any starting point reaches 1 within a
/// bounded number of inference arrivals and never increases.
#[test]
fn prop_inference_decay_monotone_and_convergent() {
    let mut rng = Pcg32::new(102, 2);
    for _ in 0..200 {
        let mut lt = LazyTune::new(64);
        // drive threshold up with a saturating history
        let mut iters = 0;
        for r in 0..(3 + rng.below(20)) {
            iters += 1;
            lt.on_round_end(iters, 0.9 - 0.5 / (r + 1) as f64);
        }
        let mut prev = lt.batches_needed();
        let mut steps = 0;
        while lt.batches_needed() > 1 {
            lt.on_inference();
            let cur = lt.batches_needed();
            assert!(cur <= prev, "decay increased: {prev} -> {cur}");
            prev = cur;
            steps += 1;
            assert!(steps < 500, "decay did not converge");
        }
    }
}

/// Property: NNLS curve fits on monotone-increasing histories are
/// monotone non-decreasing everywhere (non-negative coefficients).
#[test]
fn prop_fitted_curves_are_monotone() {
    let mut rng = Pcg32::new(103, 3);
    for case in 0..200 {
        let n = 3 + rng.below(20);
        let mut pts = Vec::new();
        let mut acc: f64 = 0.2 + 0.3 * rng.f64();
        let mut k = 0.0;
        for _ in 0..n {
            k += 1.0 + rng.below(5) as f64;
            acc += (1.0 - acc) * 0.3 * rng.f64(); // saturating growth
            pts.push((k, acc));
        }
        let Some(c) = curve::fit(&pts) else {
            panic!("fit failed with {n} points")
        };
        let mut prev = f64::NEG_INFINITY;
        for kk in 1..100 {
            let v = c.eval(kk as f64);
            assert!(v >= prev - 1e-9, "case {case}: curve decreases");
            prev = v;
        }
    }
}

/// Property: iterations_for_next_gain is in [1, cap] and weakly decreasing
/// in the requested gain's achievability (steeper curve -> fewer iters).
#[test]
fn prop_iterations_estimate_bounded() {
    let mut rng = Pcg32::new(104, 4);
    for _ in 0..300 {
        let c = curve::Curve {
            c0: rng.f64(),
            c1: rng.f64() * 2.0,
            c2: rng.f64(),
        };
        let cap = 1 + rng.below(50);
        let n = curve::iterations_for_next_gain(
            &c,
            1.0 + rng.below(100) as f64,
            rng.f64() * 0.2,
            cap,
        );
        assert!((1..=cap).contains(&n));
    }
}

/// Property: NNLS never returns negative components and never increases
/// the residual relative to the zero vector (random rectangular systems).
#[test]
fn prop_nnls_feasible_and_no_worse_than_zero() {
    let mut rng = Pcg32::new(105, 5);
    for case in 0..200 {
        let rows = 2 + rng.below(10);
        let cols = 1 + rng.below(6);
        let mut rv = Vec::new();
        for _ in 0..rows {
            rv.push((0..cols).map(|_| rng.normal() as f64).collect::<Vec<_>>());
        }
        let a = Mat::from_rows(&rv);
        let b: Vec<f64> = (0..rows).map(|_| rng.normal() as f64).collect();
        let x = nnls(&a, &b);
        assert_eq!(x.len(), cols);
        assert!(x.iter().all(|&v| v >= 0.0), "case {case}: negative x");
        let resid = |x: &[f64]| -> f64 {
            (0..rows)
                .map(|i| {
                    let ax: f64 =
                        (0..cols).map(|j| a.at(i, j) * x[j]).sum();
                    (ax - b[i]).powi(2)
                })
                .sum()
        };
        assert!(
            resid(&x) <= resid(&vec![0.0; cols]) + 1e-9,
            "case {case}: worse than zero"
        );
    }
}

/// Property: FreezeState invariants — lr_mask matches frozen flags,
/// frozen_prefix is the longest prefix, counts are consistent.
#[test]
fn prop_freeze_state_consistency() {
    let mut rng = Pcg32::new(106, 6);
    for _ in 0..500 {
        let units = 2 + rng.below(12);
        let mut fs = FreezeState::none(units);
        for f in fs.frozen.iter_mut() {
            *f = rng.f32() < 0.5;
        }
        let mask = fs.lr_mask();
        assert_eq!(mask.len(), units);
        for (u, (&f, &m)) in fs.frozen.iter().zip(mask.iter()).enumerate() {
            assert_eq!(m == 0.0, f, "unit {u}");
        }
        let p = fs.frozen_prefix();
        assert!(fs.frozen[..p].iter().all(|&f| f));
        assert!(p == units || !fs.frozen[p]);
        assert_eq!(
            fs.trainable_count(),
            fs.frozen.iter().filter(|&&f| !f).count()
        );
    }
}

/// Property: the OOD detector never fires on a constant stream, and the
/// false-positive rate on pure noise stays tiny.
#[test]
fn prop_ood_quiet_on_stationary_streams() {
    let mut rng = Pcg32::new(107, 7);
    let mut false_positives = 0;
    let mut total = 0;
    for _ in 0..50 {
        let level = -20.0 + 30.0 * rng.f64();
        let noise = 0.05 + 0.3 * rng.f64();
        let mut d = EnergyOod::new();
        for _ in 0..120 {
            total += 1;
            if d.observe(level + noise * rng.normal() as f64) {
                false_positives += 1;
            }
        }
    }
    assert!(
        (false_positives as f64) < 0.01 * total as f64,
        "{false_positives}/{total} false positives"
    );
}

/// Property: a tune policy's trigger decision equals `batches_needed()`
/// comparison for every policy kind.
#[test]
fn prop_trigger_consistent_with_threshold() {
    let mut rng = Pcg32::new(108, 8);
    for _ in 0..200 {
        let kind = match rng.below(3) {
            0 => TunePolicyKind::Immediate,
            1 => TunePolicyKind::Static(1 + rng.below(30)),
            _ => TunePolicyKind::LazyTune,
        };
        let mut p: TunePolicy = kind.build();
        // random signal soup
        for _ in 0..rng.below(30) {
            match rng.below(3) {
                0 => p.on_round_end(rng.below(100) as u64 + 1, rng.f64()),
                1 => p.on_inference(),
                _ => p.on_scenario_change(),
            }
        }
        let need = p.batches_needed();
        for ava in 0..need + 3 {
            assert_eq!(p.should_trigger(ava), ava >= need);
        }
    }
}
