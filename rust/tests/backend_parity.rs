//! End-to-end tests that **actually execute models in CI**: full
//! continual-learning simulations on the pure-Rust reference backend —
//! no artifacts, no XLA toolchain — plus the refcpu↔pjrt parity contract
//! when the artifacts are available.
//!
//! Determinism ladder:
//! 1. a run is reproducible in-process (same seed → identical
//!    fingerprint);
//! 2. sweeps are **bit-identical** for any `--jobs` worker count;
//! 3. on the built-in model family the fingerprint is stable across
//!    processes *on the same platform* — pinned by a per-architecture
//!    golden file that the first toolchain-equipped run seals into
//!    `tests/golden/` (committed, then asserted against forever after).
//!    Goldens are scoped per target arch because the kernels use libm
//!    transcendentals (tanh/exp/ln) whose f32 results may differ in the
//!    last ulp across platforms.

use std::path::PathBuf;

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::benchmarks::Benchmark;
use etuner::runtime::{Backend, RefCpuBackend};
use etuner::sim::{ParallelSweeper, RunConfig, Simulation};
use etuner::testkit;

fn quick(model: &str, b: Benchmark, seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart(model, b).with_seed(seed);
    c.n_requests = 80;
    c
}

// ---------------------------------------------------------------------------
// the model learns, end to end, on a machine with nothing installed
// ---------------------------------------------------------------------------

#[test]
fn refcpu_end_to_end_simulation_learns() {
    // Immediate + no freezing = maximum training signal: the strongest
    // form of "the executor implements real learning semantics".
    let be = RefCpuBackend::builtin().unwrap();
    let cfg = quick("mbv2", Benchmark::SCifar10, 1)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
    let r = Simulation::new(&be, cfg).unwrap().run().unwrap();
    assert_eq!(r.requests.len(), 80, "requests were dropped");
    assert!(r.serve_executes > 0, "nothing executed");
    assert!(be.executions() > 0, "backend never executed a segment");
    let batches = Benchmark::SCifar10.batches_per_scenario()
        * (Benchmark::SCifar10.scenario_count() - 1);
    assert_eq!(r.train_iterations as usize, batches);
    // the synth stream is linearly separable (nearest-proto acc > 85%);
    // a *learning* model must clear this floor comfortably.
    assert!(
        r.avg_inference_accuracy > 0.2,
        "model did not learn: {}",
        r.summary()
    );
    assert!(r.round_log.iter().any(|rr| rr.val_acc > 0.3),
        "validation accuracy never rose");
}

#[test]
fn refcpu_run_is_reproducible_in_process() {
    let be = RefCpuBackend::builtin().unwrap();
    let mk = || {
        quick("mbv2", Benchmark::SCifar10, 33)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
    };
    let a = Simulation::new(&be, mk()).unwrap().run().unwrap();
    let b = Simulation::new(&be, mk()).unwrap().run().unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint(), "refcpu is nondeterministic");
}

// ---------------------------------------------------------------------------
// sweep bit-identity: N=1 vs N=4 workers
// ---------------------------------------------------------------------------

#[test]
fn refcpu_sweep_is_bit_identical_across_worker_counts() {
    let seeds = [1u64, 2, 3, 4];
    let cfg = quick("mbv2", Benchmark::SCifar10, 0)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);

    let one = ParallelSweeper::new(testkit::refcpu_spec(), 1).unwrap();
    let four = ParallelSweeper::new(testkit::refcpu_spec(), 4).unwrap();
    assert_eq!(four.jobs(), 4);
    let (mean1, all1) = one.run_averaged(&cfg, &seeds).unwrap();
    let (mean4, all4) = four.run_averaged(&cfg, &seeds).unwrap();

    assert_eq!(all1.len(), all4.len());
    for (i, (s, p)) in all1.iter().zip(&all4).enumerate() {
        assert_eq!(s.seed, p.seed, "result order not deterministic");
        assert_eq!(
            s.fingerprint(),
            p.fingerprint(),
            "seed {} diverged across worker counts",
            seeds[i]
        );
    }
    assert_eq!(mean1.fingerprint(), mean4.fingerprint());
}

// ---------------------------------------------------------------------------
// golden fingerprint (built-in family: stable across processes/machines)
// ---------------------------------------------------------------------------

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name)
}

#[test]
fn refcpu_builtin_fingerprint_matches_golden() {
    let be = RefCpuBackend::builtin().unwrap();
    let cfg = quick("mbv2", Benchmark::SCifar10, 1)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
    let r = Simulation::new(&be, cfg).unwrap().run().unwrap();
    let got = format!("{:016x}", r.fingerprint());

    let path = golden_path(&format!(
        "refcpu_mbv2_scifar10_seed1.{}.fingerprint",
        std::env::consts::ARCH
    ));
    match std::fs::read_to_string(&path) {
        Ok(want) => {
            assert_eq!(
                got,
                want.trim(),
                "refcpu builtin fingerprint drifted from the sealed golden \
                 ({}); if the semantics change was intentional, re-seal with \
                 ETUNER_SEAL_GOLDEN=1 after deleting the stale file",
                path.display()
            );
        }
        Err(_) if std::env::var_os("ETUNER_SEAL_GOLDEN").is_some() => {
            // explicit sealing run (a maintainer commits the result; see
            // tests/golden/README.md).  Never seals implicitly: an
            // ephemeral CI runner without the committed golden must not
            // write-and-pass vacuously.
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, format!("{got}\n")).unwrap();
            eprintln!("sealed golden fingerprint {got} -> {}", path.display());
        }
        Err(_) => {
            eprintln!(
                "golden fingerprint for arch {} not sealed yet (observed \
                 {got}); run ETUNER_SEAL_GOLDEN=1 cargo test and commit {}",
                std::env::consts::ARCH,
                path.display()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// refcpu ↔ pjrt parity (needs artifacts + a working PJRT client)
// ---------------------------------------------------------------------------

#[test]
fn refcpu_matches_pjrt_predictions_on_shared_theta0() {
    let Some(pjrt) = testkit::pjrt_backend_if_available() else {
        eprintln!("skipping: pjrt backend unavailable (make artifacts + --features xla)");
        return;
    };
    // the refcpu backend binds the SAME artifact dir -> same manifest, θ0
    let refcpu = testkit::refcpu_spec().create().unwrap();

    use etuner::model::ModelSession;
    for model in ["mbv2", "res50"] {
        let sp = ModelSession::new(pjrt.as_ref(), model).unwrap();
        let sr = ModelSession::new(refcpu.as_ref(), model).unwrap();
        let p0p = sp.theta0().unwrap();
        let p0r = sr.theta0().unwrap();
        assert_eq!(p0p.theta(), p0r.theta(), "{model}: θ0 sources differ");

        let d = sp.m.d;
        let b = sp.m.batch_infer;
        let x: Vec<f32> = (0..b * d)
            .map(|k| ((k * 37 + 11) % 17) as f32 * 0.11 - 0.9)
            .collect();
        let lp = sp.infer(&p0p, &x).unwrap();
        let lr = sr.infer(&p0r, &x).unwrap();
        assert_eq!(lp.shape, lr.shape);
        // fp tolerance: identical math, different accumulation order
        let max_abs = lp
            .data
            .iter()
            .zip(&lr.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_abs < 1e-3, "{model}: logits diverge by {max_abs}");
        // predictions must agree exactly wherever the margin is real
        let pp = lp.argmax_rows();
        let pr = lr.argmax_rows();
        let agree = pp.iter().zip(&pr).filter(|(a, b)| a == b).count();
        assert!(
            agree * 100 >= pp.len() * 95,
            "{model}: only {agree}/{} predictions agree",
            pp.len()
        );
    }
}
