//! Observability-layer integration tests (PR 7).
//!
//! Contracts:
//!
//! * **Fingerprint audit** — tracing is pure observation: the same config
//!   run with a recording tracer (and the `TracingBackend` decorator in
//!   the stack) produces a bit-identical `Report::fingerprint` to the
//!   untraced run, and a disabled tracer adds no decorator at all.
//! * **Lane coverage** — a default-config traced run records at least one
//!   span in every subsystem lane (serve-engine, rounds, sweep, backend),
//!   and the Chrome export round-trips through the repo's own JSON
//!   parser with those lanes present.
//! * **Histogram parity** — the registry's latency histogram reproduces
//!   the report's nearest-rank percentiles bit-for-bit.
//!
//! The `ci_trace_file_is_valid_chrome_json` test additionally validates a
//! CLI-emitted `--trace-out` file when `ETUNER_TRACE_FILE` points at one
//! (the `make ci-trace` lane).

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::cost::device::DeviceModel;
use etuner::data::benchmarks::{Benchmark, Scenario};
use etuner::json::Json;
use etuner::model::ModelSession;
use etuner::runtime::{FaultPlan, TracingBackend};
use etuner::serve::{
    run_pool, FleetConfig, FleetPoolSpec, QueuedRequest, ServeConfig,
};
use etuner::sim::{run_config, run_config_traced, RunConfig, Simulation};
use etuner::testkit;
use etuner::trace::{self, chrome_trace_fleet, Kind, Lane, Tracer};

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c.faults = FaultPlan::none(); // pinned: ETUNER_FAULTS must not leak in
    c
}

/// Count Chrome-trace events per `(tid, ph)` in a parsed export.
fn count_spans_per_tid(v: &Json) -> Vec<(u64, usize)> {
    let evs = v.get("traceEvents").unwrap().arr().unwrap();
    let mut out: Vec<(u64, usize)> = (1..=4).map(|t| (t, 0)).collect();
    for e in evs {
        let ph = e.get("ph").unwrap().str().unwrap();
        if ph != "X" {
            continue;
        }
        let tid = e.get("tid").unwrap().num().unwrap() as u64;
        if let Some(slot) = out.iter_mut().find(|(t, _)| *t == tid) {
            slot.1 += 1;
        }
    }
    out
}

#[test]
fn traced_run_is_fingerprint_identical_and_covers_every_lane() {
    let be = testkit::refcpu_backend();
    let plain = run_config(be.as_ref(), quick(42)).unwrap();

    let tracer = Tracer::enabled(trace::DEFAULT_CAPACITY);
    let traced = run_config_traced(be.as_ref(), quick(42), &tracer).unwrap();

    assert_eq!(
        plain.fingerprint(),
        traced.fingerprint(),
        "recording a trace changed the scientific output"
    );

    // every subsystem lane recorded at least one span
    let evs = tracer.events();
    assert!(!evs.is_empty(), "traced run recorded nothing");
    for lane in Lane::ALL {
        assert!(
            evs.iter()
                .any(|e| e.lane == lane && matches!(e.kind, Kind::Span)),
            "no span in lane {:?} ({})",
            lane,
            lane.name()
        );
    }

    // ... and the Chrome export round-trips through the repo JSON parser
    // with one populated track per lane.
    let text = tracer.to_chrome_json().to_string();
    let v = Json::parse(&text).expect("chrome export must parse");
    for (tid, n) in count_spans_per_tid(&v) {
        assert!(n > 0, "chrome export has no spans on tid {tid}");
    }

    // time-in-state accounting is populated and consistent
    assert!(traced.time_tuning_s > 0.0, "no tuning time recorded");
    assert!(traced.time_serving_s > 0.0, "no serving time recorded");
    assert!(traced.time_idle_s >= 0.0);
    // ... and identical with tracing off (it comes from the scheduler
    // occupancy ledger, not the tracer).
    assert_eq!(plain.time_tuning_s.to_bits(), traced.time_tuning_s.to_bits());
    assert_eq!(
        plain.time_serving_s.to_bits(),
        traced.time_serving_s.to_bits()
    );
}

#[test]
fn disabled_tracer_constructs_no_decorator_and_passthrough_decorator_is_inert()
{
    let be = testkit::refcpu_backend();
    let plain = run_config(be.as_ref(), quick(7)).unwrap();

    // run_config_traced with a disabled tracer takes the exact
    // run_config path
    let off = run_config_traced(be.as_ref(), quick(7), &Tracer::disabled())
        .unwrap();
    assert_eq!(plain.fingerprint(), off.fingerprint());

    // even an explicitly constructed TracingBackend with a disabled
    // tracer is a pure passthrough
    let tb = TracingBackend::new(be.as_ref(), Tracer::disabled());
    let wrapped = Simulation::new(&tb, quick(7)).unwrap().run().unwrap();
    assert_eq!(
        plain.fingerprint(),
        wrapped.fingerprint(),
        "a disabled TracingBackend decorator changed the report"
    );
}

#[test]
fn report_histograms_reproduce_legacy_percentiles_bit_for_bit() {
    let be = testkit::refcpu_backend();
    // a real coalescing window so latencies are non-trivial
    let mut cfg = quick(11);
    cfg.serve.batch_window_s = 20.0;
    cfg.serve.slo_ms = 30_000.0;
    let r = run_config(be.as_ref(), cfg).unwrap();

    let h = r.hists.get("serve/latency_ms").expect("latency histogram");
    assert_eq!(h.count(), r.requests.len() as u64);
    for (p, legacy) in [
        (50.0, r.latency_p50_ms),
        (95.0, r.latency_p95_ms),
        (99.0, r.latency_p99_ms),
    ] {
        assert_eq!(
            h.percentile(p).to_bits(),
            legacy.to_bits(),
            "histogram p{p} diverged from the sorted-Vec report value"
        );
    }
    assert!(r.hists.get("serve/queue_depth").is_some());
    assert!(r.hists.get("serve/batch_rows").is_some());
    let rounds = r.hists.get("tune/round_s").expect("round histogram");
    assert_eq!(rounds.count(), r.rounds);
}

/// PR-8 satellite: a traced fleet pool run exports one Chrome track per
/// `(engine, lane)` pair, and both engines' serve lanes actually carry
/// events (the merged timeline keeps per-engine separation instead of
/// collapsing the fleet into four shared lanes).
#[test]
fn fleet_pool_trace_exports_one_track_per_engine_lane() {
    let be = testkit::refcpu_backend();
    let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
    let (d, rows) = (sess.m.d, sess.m.batch_infer / 4);
    drop(sess);

    let spec = FleetPoolSpec {
        backend: testkit::refcpu_spec(),
        model: "mbv2".into(),
        device: DeviceModel::jetson_nx_15w(),
        scenarios: (0..2)
            .map(|s| Scenario {
                id: s,
                classes: vec![s],
                seen: (0..=s).collect(),
                new_pattern: false,
            })
            .collect(),
        serve: ServeConfig {
            batch_window_s: 50.0,
            slo_ms: 1e12,
            rows_per_request: Some(rows),
            ..ServeConfig::default()
        },
        fleet: FleetConfig { engines: 2, ..FleetConfig::default() },
        trace: true,
        faults: FaultPlan::none(),
        fault_seed: 0,
    };
    let wl: Vec<QueuedRequest> = (0..8)
        .map(|i| QueuedRequest {
            arrival_t: i as f64,
            deadline_t: i as f64 + 1e9,
            scenario: i % 2,
            stale_batches: 0,
            x: (0..rows * d)
                .map(|k| ((i * 13 + k * 7) % 11) as f32 * 0.15 - 0.7)
                .collect(),
            y: vec![(i % 2) as i32; rows],
            rows,
        })
        .collect();

    let y = run_pool(&spec, &wl, 500.0, false).unwrap();
    assert_eq!(y.trace.len(), 2, "one trace batch per engine");
    assert!(y.trace.iter().all(|t| !t.is_empty()), "an engine went silent");

    let text = chrome_trace_fleet(&y.trace).to_string();
    let v = Json::parse(&text).expect("fleet chrome export must parse");
    let evs = v.get("traceEvents").unwrap().arr().unwrap();

    // one thread_name track per (engine, lane), named e{k}/{lane}
    let mut tracks = Vec::new();
    for e in evs {
        if e.get("name").unwrap().str().unwrap() == "thread_name" {
            tracks.push(
                e.get("args").unwrap().get("name").unwrap().str().unwrap(),
            );
        }
    }
    assert_eq!(
        tracks.len(),
        2 * Lane::ALL.len(),
        "expected one named track per (engine, lane): {tracks:?}"
    );
    for engine in 0..2 {
        for lane in Lane::ALL {
            let want = format!("e{engine}/{}", lane.name());
            assert!(
                tracks.iter().any(|t| *t == want),
                "missing fleet track {want}; got {tracks:?}"
            );
        }
    }
    // both engines' serve lanes carry real events on their own tids
    // (engine k's lane block starts at tid k*4+1 with serve-engine)
    for engine in 0u64..2 {
        let tid = engine * Lane::ALL.len() as u64 + 1;
        let n = evs
            .iter()
            .filter(|e| {
                e.get("name").unwrap().str().unwrap() != "thread_name"
                    && e.opt("tid").and_then(|t| t.num().ok())
                        == Some(tid as f64)
            })
            .count();
        assert!(n > 0, "engine {engine} has no events on its serve tid {tid}");
    }
}

/// PR-8 satellite: tracing stays pure observation under `--fleet`, and
/// the summary's time-in-state budget scales to N device-horizons — a
/// fleet of 4 accounts exactly 4x the wall-fleet total of a fleet of 1,
/// with the tuning ledger identical (rounds run on engine 0 only).
#[test]
fn fleet_trace_summary_time_in_state_sums_to_n_device_horizons() {
    let be = testkit::refcpu_backend();
    let mut cfg = quick(23);
    cfg.fleet.engines = 4;

    let plain = run_config(be.as_ref(), cfg.clone()).unwrap();
    let tracer = Tracer::enabled(trace::DEFAULT_CAPACITY);
    let traced = run_config_traced(be.as_ref(), cfg, &tracer).unwrap();

    assert_eq!(
        plain.fingerprint(),
        traced.fingerprint(),
        "recording a trace changed a fleet run's scientific output"
    );
    // the fleet shares one tracer in the sim path: the serve lane carries
    // every engine's activity on one interleaved timeline
    assert!(tracer
        .events()
        .iter()
        .any(|e| e.lane == Lane::Engine && matches!(e.kind, Kind::Span)));

    // time-in-state is worker-independent and budgeted per engine
    assert_eq!(plain.time_tuning_s.to_bits(), traced.time_tuning_s.to_bits());
    assert_eq!(
        plain.time_serving_s.to_bits(),
        traced.time_serving_s.to_bits()
    );
    let one = run_config(be.as_ref(), quick(23)).unwrap();
    assert_eq!(
        one.time_tuning_s.to_bits(),
        plain.time_tuning_s.to_bits(),
        "tuning runs on engine 0 regardless of fleet size"
    );
    let sum1 = one.time_serving_s + one.time_tuning_s + one.time_idle_s;
    let sum4 = plain.time_serving_s + plain.time_tuning_s + plain.time_idle_s;
    assert!(
        (sum4 - 4.0 * sum1).abs() <= 1e-6 * sum1.max(1.0),
        "fleet time budget is not 4 device-horizons: {sum4} vs 4 x {sum1}"
    );
}

#[test]
fn ci_trace_file_is_valid_chrome_json() {
    // `make ci-trace` runs the CLI with --trace-out and points this test
    // at the emitted file; without the env var the test is a no-op so the
    // plain suite stays hermetic.
    let Ok(path) = std::env::var("ETUNER_TRACE_FILE") else {
        return;
    };
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path}: {e}"));
    let v = Json::parse(&text).expect("CLI trace file must be valid JSON");
    assert_eq!(
        v.get("displayTimeUnit").unwrap().str().unwrap(),
        "ms",
        "not a Chrome trace-event export"
    );
    for (tid, n) in count_spans_per_tid(&v) {
        assert!(n > 0, "CLI trace has no spans on tid {tid} — a subsystem \
                 lane went silent");
    }
}
