//! Observability-layer integration tests (PR 7).
//!
//! Contracts:
//!
//! * **Fingerprint audit** — tracing is pure observation: the same config
//!   run with a recording tracer (and the `TracingBackend` decorator in
//!   the stack) produces a bit-identical `Report::fingerprint` to the
//!   untraced run, and a disabled tracer adds no decorator at all.
//! * **Lane coverage** — a default-config traced run records at least one
//!   span in every subsystem lane (serve-engine, rounds, sweep, backend),
//!   and the Chrome export round-trips through the repo's own JSON
//!   parser with those lanes present.
//! * **Histogram parity** — the registry's latency histogram reproduces
//!   the report's nearest-rank percentiles bit-for-bit.
//!
//! The `ci_trace_file_is_valid_chrome_json` test additionally validates a
//! CLI-emitted `--trace-out` file when `ETUNER_TRACE_FILE` points at one
//! (the `make ci-trace` lane).

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::benchmarks::Benchmark;
use etuner::json::Json;
use etuner::runtime::{FaultPlan, TracingBackend};
use etuner::sim::{run_config, run_config_traced, RunConfig, Simulation};
use etuner::testkit;
use etuner::trace::{self, Kind, Lane, Tracer};

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 80;
    c.faults = FaultPlan::none(); // pinned: ETUNER_FAULTS must not leak in
    c
}

/// Count Chrome-trace events per `(tid, ph)` in a parsed export.
fn count_spans_per_tid(v: &Json) -> Vec<(u64, usize)> {
    let evs = v.get("traceEvents").unwrap().arr().unwrap();
    let mut out: Vec<(u64, usize)> = (1..=4).map(|t| (t, 0)).collect();
    for e in evs {
        let ph = e.get("ph").unwrap().str().unwrap();
        if ph != "X" {
            continue;
        }
        let tid = e.get("tid").unwrap().num().unwrap() as u64;
        if let Some(slot) = out.iter_mut().find(|(t, _)| *t == tid) {
            slot.1 += 1;
        }
    }
    out
}

#[test]
fn traced_run_is_fingerprint_identical_and_covers_every_lane() {
    let be = testkit::refcpu_backend();
    let plain = run_config(be.as_ref(), quick(42)).unwrap();

    let tracer = Tracer::enabled(trace::DEFAULT_CAPACITY);
    let traced = run_config_traced(be.as_ref(), quick(42), &tracer).unwrap();

    assert_eq!(
        plain.fingerprint(),
        traced.fingerprint(),
        "recording a trace changed the scientific output"
    );

    // every subsystem lane recorded at least one span
    let evs = tracer.events();
    assert!(!evs.is_empty(), "traced run recorded nothing");
    for lane in Lane::ALL {
        assert!(
            evs.iter()
                .any(|e| e.lane == lane && matches!(e.kind, Kind::Span)),
            "no span in lane {:?} ({})",
            lane,
            lane.name()
        );
    }

    // ... and the Chrome export round-trips through the repo JSON parser
    // with one populated track per lane.
    let text = tracer.to_chrome_json().to_string();
    let v = Json::parse(&text).expect("chrome export must parse");
    for (tid, n) in count_spans_per_tid(&v) {
        assert!(n > 0, "chrome export has no spans on tid {tid}");
    }

    // time-in-state accounting is populated and consistent
    assert!(traced.time_tuning_s > 0.0, "no tuning time recorded");
    assert!(traced.time_serving_s > 0.0, "no serving time recorded");
    assert!(traced.time_idle_s >= 0.0);
    // ... and identical with tracing off (it comes from the scheduler
    // occupancy ledger, not the tracer).
    assert_eq!(plain.time_tuning_s.to_bits(), traced.time_tuning_s.to_bits());
    assert_eq!(
        plain.time_serving_s.to_bits(),
        traced.time_serving_s.to_bits()
    );
}

#[test]
fn disabled_tracer_constructs_no_decorator_and_passthrough_decorator_is_inert()
{
    let be = testkit::refcpu_backend();
    let plain = run_config(be.as_ref(), quick(7)).unwrap();

    // run_config_traced with a disabled tracer takes the exact
    // run_config path
    let off = run_config_traced(be.as_ref(), quick(7), &Tracer::disabled())
        .unwrap();
    assert_eq!(plain.fingerprint(), off.fingerprint());

    // even an explicitly constructed TracingBackend with a disabled
    // tracer is a pure passthrough
    let tb = TracingBackend::new(be.as_ref(), Tracer::disabled());
    let wrapped = Simulation::new(&tb, quick(7)).unwrap().run().unwrap();
    assert_eq!(
        plain.fingerprint(),
        wrapped.fingerprint(),
        "a disabled TracingBackend decorator changed the report"
    );
}

#[test]
fn report_histograms_reproduce_legacy_percentiles_bit_for_bit() {
    let be = testkit::refcpu_backend();
    // a real coalescing window so latencies are non-trivial
    let mut cfg = quick(11);
    cfg.serve.batch_window_s = 20.0;
    cfg.serve.slo_ms = 30_000.0;
    let r = run_config(be.as_ref(), cfg).unwrap();

    let h = r.hists.get("serve/latency_ms").expect("latency histogram");
    assert_eq!(h.count(), r.requests.len() as u64);
    for (p, legacy) in [
        (50.0, r.latency_p50_ms),
        (95.0, r.latency_p95_ms),
        (99.0, r.latency_p99_ms),
    ] {
        assert_eq!(
            h.percentile(p).to_bits(),
            legacy.to_bits(),
            "histogram p{p} diverged from the sorted-Vec report value"
        );
    }
    assert!(r.hists.get("serve/queue_depth").is_some());
    assert!(r.hists.get("serve/batch_rows").is_some());
    let rounds = r.hists.get("tune/round_s").expect("round histogram");
    assert_eq!(rounds.count(), r.rounds);
}

#[test]
fn ci_trace_file_is_valid_chrome_json() {
    // `make ci-trace` runs the CLI with --trace-out and points this test
    // at the emitted file; without the env var the test is a no-op so the
    // plain suite stays hermetic.
    let Ok(path) = std::env::var("ETUNER_TRACE_FILE") else {
        return;
    };
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path}: {e}"));
    let v = Json::parse(&text).expect("CLI trace file must be valid JSON");
    assert_eq!(
        v.get("displayTimeUnit").unwrap().str().unwrap(),
        "ms",
        "not a Chrome trace-event export"
    );
    for (tid, n) in count_spans_per_tid(&v) {
        assert!(n > 0, "CLI trace has no spans on tid {tid} — a subsystem \
                 lane went silent");
    }
}
