//! Whole-system integration tests: full continual-learning runs checking
//! the paper's qualitative claims hold on this testbed.
//!
//! Since the Backend refactor these are **no longer artifact-gated**:
//! every environment executes real models through
//! [`etuner::testkit::execution_backend`] (PJRT over the AOT artifacts
//! when available, the pure-Rust reference executor otherwise — same
//! segment semantics either way).  Accuracy floors are set modestly below
//! the observed PJRT values so both θ0 sources clear them.

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::arrival::ArrivalKind;
use etuner::data::benchmarks::Benchmark;
use etuner::sim::{RunConfig, Simulation};
use etuner::testkit;

fn quick(model: &str, b: Benchmark) -> RunConfig {
    let mut c = RunConfig::quickstart(model, b);
    c.n_requests = 80;
    c
}

#[test]
fn immediate_run_fires_one_round_per_batch() {
    let be = testkit::execution_backend();
    let cfg = quick("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    let batches = Benchmark::SCifar10.batches_per_scenario()
        * (Benchmark::SCifar10.scenario_count() - 1);
    assert_eq!(r.rounds as usize, batches);
    assert_eq!(r.train_iterations as usize, batches);
    assert_eq!(r.requests.len(), 80);
    assert!(r.avg_inference_accuracy > 0.2, "{}", r.summary());
}

#[test]
fn lazytune_merges_rounds_without_losing_data() {
    let be = testkit::execution_backend();
    let cfg = quick("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::None);
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    let batches = Benchmark::SCifar10.batches_per_scenario()
        * (Benchmark::SCifar10.scenario_count() - 1);
    // no batch dropped (the paper: "we do not drop any training data")
    assert_eq!(r.train_iterations as usize, batches);
    // but far fewer rounds were launched
    assert!(
        (r.rounds as usize) < batches / 2,
        "rounds {} vs batches {batches}",
        r.rounds
    );
}

#[test]
fn lazytune_cuts_time_and_energy_vs_immediate() {
    let be = testkit::execution_backend();
    let imm = Simulation::new(
        be.as_ref(),
        quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None),
    )
    .unwrap()
    .run()
    .unwrap();
    let lazy = Simulation::new(
        be.as_ref(),
        quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::None),
    )
    .unwrap()
    .run()
    .unwrap();
    assert!(lazy.energy.total_s() < 0.75 * imm.energy.total_s());
    assert!(lazy.energy.total_j() < 0.85 * imm.energy.total_j());
    // accuracy should not collapse (paper: -0.22% on average)
    assert!(
        lazy.avg_inference_accuracy > imm.avg_inference_accuracy - 0.08,
        "lazy {} vs imm {}",
        lazy.avg_inference_accuracy,
        imm.avg_inference_accuracy
    );
}

#[test]
fn simfreeze_freezes_layers_and_cuts_compute() {
    let be = testkit::execution_backend();
    let imm = Simulation::new(
        be.as_ref(),
        quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None),
    )
    .unwrap()
    .run()
    .unwrap();
    let sf = Simulation::new(
        be.as_ref(),
        quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
    )
    .unwrap()
    .run()
    .unwrap();
    // same number of rounds (tuning policy identical) ...
    assert_eq!(imm.rounds, sf.rounds);
    // ... but layers froze at some point
    assert!(
        sf.round_log.iter().any(|r| r.frozen_units > 0),
        "nothing ever froze"
    );
    // ... and training compute went down
    assert!(
        sf.train_tflops < imm.train_tflops,
        "{} !< {}",
        sf.train_tflops,
        imm.train_tflops
    );
    // memory at end below memory at begin (Fig 10 shape)
    assert!(sf.memory_end_bytes < sf.memory_begin_bytes);
}

#[test]
fn scenario_changes_are_detected_and_reset_lazytune() {
    let be = testkit::execution_backend();
    let mut cfg = quick("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::None);
    cfg.n_requests = 150; // enough requests for the detector to see jumps
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert!(
        r.scenario_changes_detected >= 2,
        "detected {} of 3 changes",
        r.scenario_changes_detected
    );
    // after a detection, some round must run with a lowered threshold
    let resets = r
        .round_log
        .windows(2)
        .filter(|w| w[1].batches_needed < w[0].batches_needed)
        .count();
    assert!(resets > 0, "batches_needed never dropped");
}

#[test]
fn semi_supervised_run_completes_with_ssl_steps() {
    let be = testkit::execution_backend();
    let mut cfg = quick("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
    cfg.labeled_fraction = Some(0.1);
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert_eq!(
        r.train_iterations as usize,
        Benchmark::SCifar10.batches_per_scenario() * 4
    );
    assert!(r.avg_inference_accuracy.is_finite());
}

#[test]
fn quant_run_completes_and_learns() {
    let be = testkit::execution_backend();
    let mut cfg = quick("res50", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze);
    cfg.quant = true;
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert!(r.avg_inference_accuracy > 0.2, "{}", r.summary());
}

#[test]
fn all_baselines_run_on_small_benchmark() {
    let be = testkit::execution_backend();
    for freeze in [
        FreezePolicyKind::Egeria,
        FreezePolicyKind::SlimFit,
        FreezePolicyKind::RigL,
        FreezePolicyKind::Ekya,
    ] {
        let cfg = quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::LazyTune, freeze);
        let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
        assert!(
            r.avg_inference_accuracy > 0.15,
            "{:?}: {}",
            freeze,
            r.summary()
        );
        assert!(r.energy.total_j() > 0.0);
    }
}

#[test]
fn runs_are_reproducible_per_seed() {
    let be = testkit::execution_backend();
    let mk = || {
        quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
            .with_seed(33)
    };
    let a = Simulation::new(be.as_ref(), mk()).unwrap().run().unwrap();
    let b = Simulation::new(be.as_ref(), mk()).unwrap().run().unwrap();
    assert_eq!(a.avg_inference_accuracy, b.avg_inference_accuracy);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.energy.total_j(), b.energy.total_j());
}

#[test]
fn different_arrival_kinds_complete() {
    let be = testkit::execution_backend();
    for kind in [ArrivalKind::Uniform, ArrivalKind::Normal, ArrivalKind::Trace] {
        let mut cfg = quick("mbv2", Benchmark::SCifar10)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
        cfg.train_arrival = kind;
        cfg.infer_arrival = kind;
        let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
        assert!(r.avg_inference_accuracy > 0.15, "{kind:?}");
    }
}

#[test]
fn nlp_benchmark_runs_on_bert() {
    let be = testkit::execution_backend();
    let cfg = quick("bert", Benchmark::News20)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
    let r = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
    assert!(r.avg_inference_accuracy > 0.25, "{}", r.summary());
}
