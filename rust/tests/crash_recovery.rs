//! Crash-durability battery: deterministic crash injection at round
//! boundaries, checksummed checkpoint recovery, and bit-identical resume.
//!
//! The headline invariant (ROADMAP: durability): for a fixed config and
//! seed, `fingerprint(crash at round boundary B, then --resume)` equals
//! `fingerprint(the uncrashed run)` — for **every** boundary B.  Round
//! boundaries are quiesce points (training buffer drained, serve queues
//! empty), so a checkpoint record plus the events-done index is the whole
//! simulation state; the battery proves it by induction over boundaries.
//!
//! Also covered here: checksum-detected corruption (`ckpt-flip` /
//! `ckpt-torn`) falling back to the previous valid record, the seeded
//! crash-rate loop converging through repeated resumes, sweep-cell
//! journal resume in `ParallelSweeper`, and the zero-overhead default
//! (no checkpoint dir → the exact pre-checkpoint code path).

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use etuner::ckpt::{Cadence, CrashInjected, SweepJournal};
use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::benchmarks::Benchmark;
use etuner::runtime::FaultPlan;
use etuner::sim::{run_config, ParallelSweeper, RunConfig};
use etuner::testkit;

/// Unique scratch dir per test case (no wall clock, no rand — a
/// process-local counter keeps parallel test binaries apart).
fn scratch(name: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "etuner-crashrec-{}-{}-{}",
        std::process::id(),
        name,
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick(seed: u64) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.n_requests = 60;
    c
}

/// `quick(seed)` with checkpointing into `dir` and the given fault plan.
fn ckpt_cfg(seed: u64, dir: &PathBuf, every: &str, plan: &str) -> RunConfig {
    let mut c = quick(seed);
    c.checkpoint.dir = Some(dir.clone());
    c.checkpoint.every = Cadence::parse(every).unwrap();
    c.faults = FaultPlan::parse(plan).unwrap();
    c
}

/// (a) The induction: crash after *every* round boundary, resume, and
/// demand the exact uncrashed fingerprint each time.  Cadence `3r` makes
/// recovery exercise both paths — journal-tail records between snapshots
/// and fresh snapshots at the cadence.
#[test]
fn resume_after_crash_at_every_round_boundary_is_bit_identical() {
    let be = testkit::execution_backend();
    let clean = run_config(be.as_ref(), quick(11)).unwrap();
    let rounds = clean.rounds;
    assert!(rounds >= 3, "run too small to exercise boundaries ({rounds})");

    for n in 1..=rounds {
        let dir = scratch("every-boundary");
        let plan = format!("crash:after-round-{n}");

        let err = run_config(be.as_ref(), ckpt_cfg(11, &dir, "3r", &plan))
            .expect_err("crash point never fired");
        let crash = err
            .downcast::<CrashInjected>()
            .expect("run died with a non-crash error");
        assert_eq!(crash.round, n, "crash latched at the wrong boundary");

        // resume under the *same* config (the digest pins it); the crash
        // latch was serialized post-fire, so the run completes this time.
        let mut cfg = ckpt_cfg(11, &dir, "3r", &plan);
        cfg.checkpoint.resume = true;
        let resumed = run_config(be.as_ref(), cfg).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            clean.fingerprint(),
            "resume after a crash at round {n} diverged from the uncrashed run"
        );
        assert_eq!(resumed.checkpoint_restores, 1);
        assert_eq!(resumed.checkpoint_fallbacks, 0);
        let _ = fs::remove_dir_all(&dir);
    }
}

/// Time-based crash point: `crash:t=0` fires at the first boundary.
#[test]
fn time_based_crash_point_resumes_bit_identically() {
    let be = testkit::execution_backend();
    let clean = run_config(be.as_ref(), quick(19)).unwrap();
    let dir = scratch("t-zero");

    let err = run_config(be.as_ref(), ckpt_cfg(19, &dir, "1r", "crash:t=0"))
        .expect_err("t=0 crash point never fired");
    err.downcast::<CrashInjected>().expect("non-crash error");

    let mut cfg = ckpt_cfg(19, &dir, "1r", "crash:t=0");
    cfg.checkpoint.resume = true;
    let resumed = run_config(be.as_ref(), cfg).unwrap();
    assert_eq!(resumed.fingerprint(), clean.fingerprint());
    let _ = fs::remove_dir_all(&dir);
}

/// Seeded crash-rate loop: every boundary flips a coin from a dedicated
/// stream.  The rate RNG is checkpointed post-draw, so each resume makes
/// progress and the crash sequence is exactly reproducible; looping
/// resume-until-Ok must converge to the uncrashed fingerprint.
#[test]
fn seeded_crash_rate_loop_converges_through_resumes() {
    let be = testkit::execution_backend();
    let clean = run_config(be.as_ref(), quick(17)).unwrap();
    let dir = scratch("rate");
    let plan = "crash:0.5,seed:4";

    let mut last = run_config(be.as_ref(), ckpt_cfg(17, &dir, "2r", plan));
    let mut resumes = 0u64;
    while let Err(e) = last {
        e.downcast::<CrashInjected>().expect("non-crash error");
        resumes += 1;
        assert!(resumes <= 64, "crash loop did not converge");
        let mut cfg = ckpt_cfg(17, &dir, "2r", plan);
        cfg.checkpoint.resume = true;
        last = run_config(be.as_ref(), cfg);
    }
    let fin = last.unwrap();
    assert_eq!(
        fin.fingerprint(),
        clean.fingerprint(),
        "crash-rate resume loop diverged after {resumes} resumes"
    );
    // each successful resume restored exactly once, and the report
    // accumulates them across the whole resume chain
    assert_eq!(fin.checkpoint_restores, resumes);
    let _ = fs::remove_dir_all(&dir);
}

/// (b) Corruption: flip one byte in (or tear) the newest record before
/// the crash, and recovery must detect the checksum/framing damage, fall
/// back to the previous valid record, count the fallback, and still land
/// the uncrashed fingerprint.  Because the corrupted record also held the
/// crash latch, the crash may re-fire on the redone boundary — the
/// resume-until-Ok loop absorbs that (it is exactly what a supervisor
/// restarting the process would experience).
#[test]
fn corrupt_newest_record_falls_back_and_still_lands_the_fingerprint() {
    let be = testkit::execution_backend();
    let clean = run_config(be.as_ref(), quick(13)).unwrap();
    assert!(clean.rounds >= 3, "run too small ({})", clean.rounds);

    for corrupt in ["ckpt-flip:3", "ckpt-torn:3"] {
        let dir = scratch("corrupt");
        let plan = format!("{corrupt},crash:after-round-3");

        let err = run_config(be.as_ref(), ckpt_cfg(13, &dir, "1r", &plan))
            .expect_err("crash point never fired");
        err.downcast::<CrashInjected>().expect("non-crash error");

        let mut fin = None;
        for _attempt in 0..8 {
            let mut cfg = ckpt_cfg(13, &dir, "1r", &plan);
            cfg.checkpoint.resume = true;
            match run_config(be.as_ref(), cfg) {
                Ok(r) => {
                    fin = Some(r);
                    break;
                }
                Err(e) => {
                    e.downcast::<CrashInjected>().expect("non-crash error");
                }
            }
        }
        let fin = fin.expect("corruption resume loop never completed");
        assert_eq!(
            fin.fingerprint(),
            clean.fingerprint(),
            "{corrupt}: fallback recovery diverged from the uncrashed run"
        );
        assert!(
            fin.checkpoint_fallbacks >= 1,
            "{corrupt}: recovery never detected the corrupted record"
        );
        let _ = fs::remove_dir_all(&dir);
    }
}

/// (c) Sweep-cell journal: a partial grid records its finished cells;
/// re-running the full grid completes only the unfinished ones, and the
/// merged results are bit-identical to an uninterrupted `run_many`.
#[test]
fn sweep_journal_resumes_only_unfinished_cells_bit_identically() {
    let cfgs: Vec<RunConfig> = (1..=4).map(quick).collect();
    let plain = ParallelSweeper::new(testkit::refcpu_spec(), 2)
        .unwrap()
        .run_many(&cfgs)
        .unwrap();

    let dir = scratch("sweep");
    let path = dir.join("journal.bin");
    let mut sw = ParallelSweeper::new(testkit::refcpu_spec(), 2).unwrap();
    sw.set_journal(&path);

    // interrupted grid: only the first two cells finish
    let partial = sw.run_many(&cfgs[..2]).unwrap();
    for (a, b) in plain[..2].iter().zip(&partial) {
        assert_eq!(a.fingerprint(), b.fingerprint());
    }
    assert_eq!(SweepJournal::new(&path).load().unwrap().len(), 2);

    // resume: the full grid — cells 0/1 read back, 2/3 run fresh
    let full = sw.run_many(&cfgs).unwrap();
    assert_eq!(SweepJournal::new(&path).load().unwrap().len(), 4);
    for (i, (a, b)) in plain.iter().zip(&full).enumerate() {
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "journal-merged cell {i} diverged from the uninterrupted sweep"
        );
    }

    // a third pass finds every cell journaled: nothing re-runs, nothing
    // is re-recorded
    let len = fs::metadata(&path).unwrap().len();
    let again = sw.run_many(&cfgs).unwrap();
    assert_eq!(
        fs::metadata(&path).unwrap().len(),
        len,
        "fully-journaled sweep re-recorded cells"
    );
    for (a, b) in full.iter().zip(&again) {
        assert_eq!(a.fingerprint(), b.fingerprint());
    }
    let _ = fs::remove_dir_all(&dir);
}

/// (d) The default config constructs none of this: counters stay zero,
/// and turning checkpointing *on* must not perturb the science either —
/// the writer only observes quiesced state, so the fingerprint is the
/// same with and without it.
#[test]
fn default_config_takes_the_pre_checkpoint_path() {
    let be = testkit::execution_backend();
    let off = run_config(be.as_ref(), quick(21)).unwrap();
    assert_eq!(off.checkpoints_written, 0);
    assert_eq!(off.checkpoint_bytes, 0);
    assert_eq!(off.checkpoint_restores, 0);
    assert_eq!(off.checkpoint_fallbacks, 0);

    let dir = scratch("passive");
    let mut cfg = quick(21);
    cfg.checkpoint.dir = Some(dir.clone());
    let on = run_config(be.as_ref(), cfg).unwrap();
    assert_eq!(
        off.fingerprint(),
        on.fingerprint(),
        "writing checkpoints perturbed the simulation"
    );
    assert!(on.checkpoints_written > 0, "no record hit the directory");
    assert!(on.checkpoint_bytes > 0);
    let _ = fs::remove_dir_all(&dir);
}
