//! Load-layer property battery (PR 10).
//!
//! Three contracts:
//!
//! * **Statistical shape** — each open-loop generator empirically hits
//!   its configured offered rate (counts are emergent, never rescaled),
//!   the diurnal envelope's peak/trough arrival ratio matches the
//!   configured amplitude, and the Zipf mix's empirical frequency
//!   ranking follows the skew.  Seeds are pinned, so these are exact
//!   regression tests, not flaky statistics.
//! * **Worker-count independence** — a sweep of open-loop workload
//!   configs yields bit-identical reports at `--jobs 1` and `--jobs 4`,
//!   and the capacity search's knee (plus its entire probe log) is
//!   bit-identical across job counts.
//! * **Observability** — open-loop runs publish the
//!   `load/interarrival_s` histogram without touching the scientific
//!   fingerprint.

use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::benchmarks::Benchmark;
use etuner::load::{
    capacity_search, open_loop_times, CapacitySpec, MixSampler, MixSpec,
    WorkloadKind, WorkloadSpec,
};
use etuner::rng::Pcg32;
use etuner::runtime::FaultPlan;
use etuner::sim::{ParallelSweeper, RunConfig};
use etuner::testkit;

// ---------------------------------------------------------------------------
// statistical shape of the generators
// ---------------------------------------------------------------------------

/// The empirical rate of every generator converges to the configured
/// offered rate.  Tolerances reflect each process's variance over the
/// pinned horizon: the on-off modulation (bursty) and the heavy tail
/// (pareto) mix slower than plain exponential gaps.
#[test]
fn empirical_mean_rate_matches_the_offered_rate() {
    let rate = 8.0;
    let horizon = 2000.0;
    let tolerances = [
        (WorkloadKind::Poisson, 0.05),
        (WorkloadKind::Bursty, 0.10),
        (WorkloadKind::Diurnal, 0.05),
        (WorkloadKind::Pareto, 0.10),
    ];
    for (kind, tol) in tolerances {
        let mut rng = Pcg32::new(90, 29);
        let xs = open_loop_times(kind, rate, horizon, &mut rng);
        let empirical = xs.len() as f64 / horizon;
        let rel = (empirical - rate).abs() / rate;
        assert!(
            rel <= tol,
            "{kind:?}: empirical rate {empirical:.3} vs offered {rate} \
             (rel err {rel:.4} > tol {tol})"
        );
    }
}

/// Arrivals in a window around the diurnal peak outnumber arrivals in
/// the mirror window around the trough by roughly the configured
/// `(1 + a) / (1 - a)` = 4 envelope ratio (window-averaging pulls the
/// exact expectation slightly below 4).
#[test]
fn diurnal_peak_to_trough_ratio_matches_the_envelope() {
    let horizon = 4000.0;
    let mut rng = Pcg32::new(17, 5);
    let xs = open_loop_times(WorkloadKind::Diurnal, 6.0, horizon, &mut rng);
    let count_in = |center: f64| {
        let half = horizon / 16.0;
        xs.iter()
            .filter(|&&t| (center - half..=center + half).contains(&t))
            .count()
    };
    let peak = count_in(horizon / 4.0);
    let trough = count_in(3.0 * horizon / 4.0);
    assert!(trough > 0, "trough window is empty");
    let ratio = peak as f64 / trough as f64;
    assert!(
        (3.0..5.0).contains(&ratio),
        "peak/trough ratio {ratio:.2} (peak {peak}, trough {trough}) is \
         not near the configured 4"
    );
}

/// Hotter ranks are strictly more frequent: the Zipf sampler's empirical
/// scenario counts decrease monotonically in rank order.
#[test]
fn zipf_frequency_ranking_matches_the_skew() {
    let spec = MixSpec::parse("zipf:s=1.1,k=8").unwrap();
    let sampler = MixSampler::new(&spec, 10, 1000.0);
    let mut rng = Pcg32::new(33, 3);
    let mut counts = [0usize; 10];
    for _ in 0..20_000 {
        counts[sampler.scenario_at(0.0, &mut rng)] += 1;
    }
    // ranks 0..8 map to scenarios 1..8 (no shift configured)
    for s in 1..8 {
        assert!(
            counts[s] > counts[s + 1],
            "scenario {s} ({}) not hotter than scenario {} ({}): {counts:?}",
            counts[s],
            s + 1,
            counts[s + 1]
        );
    }
    assert_eq!(counts[0], 0, "scenario 0 never serves inference");
    assert_eq!(counts[9], 0, "ranks were clamped to k=8");
}

// ---------------------------------------------------------------------------
// worker-count independence
// ---------------------------------------------------------------------------

fn load_cfg(
    seed: u64,
    kind: WorkloadKind,
    mix: Option<MixSpec>,
) -> RunConfig {
    let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze)
        .with_seed(seed);
    c.faults = FaultPlan::none(); // pinned: see tests/faults.rs module docs
    c.workload = Some(WorkloadSpec {
        kind,
        offered_rps: 1.5,
        window_s: Some(40.0),
        mix,
    });
    c
}

/// A mixed batch of open-loop workload configs sweeps bit-identically at
/// 1 and 4 workers — and each run published the interarrival histogram.
#[test]
fn workload_sweeps_are_bit_identical_across_jobs() {
    let cfgs = vec![
        load_cfg(
            3,
            WorkloadKind::Poisson,
            Some(MixSpec::parse("zipf:s=1.1,k=4,shift=0.5").unwrap()),
        ),
        load_cfg(4, WorkloadKind::Bursty, None),
        load_cfg(5, WorkloadKind::Pareto, None),
    ];
    let one = ParallelSweeper::new(testkit::refcpu_spec(), 1)
        .unwrap()
        .run_many(&cfgs)
        .unwrap();
    let four = ParallelSweeper::new(testkit::refcpu_spec(), 4)
        .unwrap()
        .run_many(&cfgs)
        .unwrap();
    assert_eq!(one.len(), four.len());
    for (a, b) in one.iter().zip(&four) {
        assert!(
            !a.requests.is_empty(),
            "open-loop workload served no requests"
        );
        assert!(
            a.hists.get("load/interarrival_s").is_some(),
            "open-loop run published no interarrival histogram"
        );
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.latency_p99_ms.to_bits(), b.latency_p99_ms.to_bits());
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "full report diverged across --jobs"
        );
    }
}

/// The capacity search returns the same knee — and the same probe log,
/// float for float — whether its batches run on 1 worker or 4.
#[test]
fn capacity_knee_is_bit_identical_across_jobs() {
    let base = load_cfg(2, WorkloadKind::Poisson, None);
    let spec = CapacitySpec {
        slo_ms: 400.0,
        drop_eps: 0.01,
        lo_rps: 0.2,
        hi_rps: 4.0,
        iters: 2,
        probes_per_iter: 1,
    };
    let seq = ParallelSweeper::new(testkit::refcpu_spec(), 1).unwrap();
    let par = ParallelSweeper::new(testkit::refcpu_spec(), 4).unwrap();
    let a = capacity_search(&seq, &base, &spec).unwrap();
    let b = capacity_search(&par, &base, &spec).unwrap();
    assert_eq!(a.knee_rps.to_bits(), b.knee_rps.to_bits());
    assert_eq!(a.p99_at_knee_ms.to_bits(), b.p99_at_knee_ms.to_bits());
    assert_eq!(a.saturated, b.saturated);
    assert_eq!(a.probes.len(), b.probes.len(), "probe schedules diverged");
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!(pa.offered_rps.to_bits(), pb.offered_rps.to_bits());
        assert_eq!(pa.p99_ms.to_bits(), pb.p99_ms.to_bits());
        assert_eq!(pa.drop_rate.to_bits(), pb.drop_rate.to_bits());
        assert_eq!(pa.passed, pb.passed);
    }
    // at minimum the endpoint batch ran; interior batches only run when
    // the bracket actually straddles the knee
    assert!(a.probes.len() >= 2, "endpoint batch missing");
}
