//! Property tests for the packed GEMM execution core: **bit-equality**
//! against the naive triple-loop oracle (`runtime::refcpu::naive`, the
//! seed kernels kept verbatim) over odd and degenerate shapes —
//! m, k, n ∈ {1, 3, 8, 17, 64} (non-multiples of the panel width, width
//! 1, and full panels), zeroed rows (exercising the `x == 0.0` skip
//! whose absence would flip zero signs), and all-zero inputs — for the
//! forward, dx, dw and QAT paths, at the kernel level, through the tape
//! path, and end-to-end through the backend for all three block kinds.
//!
//! "Bit-equality" is literal: every f32 is compared by `to_bits()`, so a
//! `-0.0` vs `+0.0` divergence fails.

use etuner::rng::Pcg32;
use etuner::runtime::refcpu::arena::Arena;
use etuner::runtime::refcpu::gemm::{self, Act};
use etuner::runtime::refcpu::kernels::{dense_bwd, dense_train, Ctx, DenseKey};
use etuner::runtime::refcpu::naive;
use etuner::runtime::{Backend, RefCpuBackend};

const DIMS: [usize; 5] = [1, 3, 8, 17, 64];

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}[{i}]: packed {x:?} ({:#010x}) != naive {y:?} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

fn randv(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// The x variants every shape is tested under: dense random, a zeroed
/// row (skip path), and all-zero.
fn x_variants(rng: &mut Pcg32, m: usize, k: usize) -> Vec<Vec<f32>> {
    let dense = randv(rng, m * k, 1.0);
    let mut zero_row = dense.clone();
    zero_row[..k].iter_mut().for_each(|v| *v = 0.0);
    // sprinkle interior zeros too, so the skip fires mid-reduction
    let mut sparse = dense.clone();
    for (i, v) in sparse.iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0;
        }
    }
    vec![dense, zero_row, sparse, vec![0.0; m * k]]
}

#[test]
fn packed_fwd_bit_equals_naive_over_shape_grid() {
    let mut rng = Pcg32::new(71, 1);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let w = randv(&mut rng, k * n, 0.5);
                let b = randv(&mut rng, n, 0.2);
                let pan = gemm::pack_w(&w, k, n, false);
                for x in x_variants(&mut rng, m, k) {
                    for act in [Act::None, Act::Relu, Act::Gelu] {
                        let want = naive::dense_fwd(&x, &w, &b, m, k, n, act, false);
                        let mut got = vec![0.0f32; m * n];
                        gemm::gemm_fwd(&x, &pan, &b, m, act, &mut got);
                        assert_bits_eq(&got, &want, &format!("fwd {act:?} m{m} k{k} n{n}"));
                    }
                }
            }
        }
    }
}

#[test]
fn packed_vjp_kernels_bit_equal_naive_over_shape_grid() {
    let mut rng = Pcg32::new(72, 2);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let w = randv(&mut rng, k * n, 0.5);
                let b = randv(&mut rng, n, 0.2);
                let dout = randv(&mut rng, m * n, 1.0);
                let pt = gemm::pack_wt(&w, k, n, false);
                for x in x_variants(&mut rng, m, k) {
                    let (want_dx, want_dw, want_db) =
                        naive::dense_vjp(&x, &w, &b, m, k, n, Act::None, false, &dout);
                    let mut dx = vec![0.0f32; m * k];
                    gemm::gemm_dx(&dout, &pt, m, &mut dx);
                    let mut dw = vec![0.0f32; k * n];
                    gemm::gemm_dw_acc(&x, &dout, m, k, n, &mut dw);
                    let mut db = vec![0.0f32; n];
                    gemm::db_acc(&dout, m, n, &mut db);
                    let tag = format!("m{m} k{k} n{n}");
                    assert_bits_eq(&dx, &want_dx, &format!("dx {tag}"));
                    assert_bits_eq(&dw, &want_dw, &format!("dw {tag}"));
                    assert_bits_eq(&db, &want_db, &format!("db {tag}"));
                }
            }
        }
    }
}

/// Tape-path VJP (dense_train + dense_bwd: activation rules, pack cache,
/// arena buffers) against the oracle, for every activation and QAT.
#[test]
fn tape_path_bit_equals_naive_for_all_acts_and_qat() {
    let mut rng = Pcg32::new(73, 3);
    let shapes = [(1, 1, 1), (3, 8, 17), (17, 3, 8), (8, 17, 3), (16, 64, 64)];
    for &(m, k, n) in &shapes {
        for quant in [false, true] {
            for act in [Act::None, Act::Relu, Act::Gelu] {
                let x = randv(&mut rng, m * k, 1.0);
                let w = randv(&mut rng, k * n, 0.5);
                let b = randv(&mut rng, n, 0.2);
                let dout = randv(&mut rng, m * n, 1.0);
                let tag = format!("{act:?} quant={quant} m{m} k{k} n{n}");

                let want_out = naive::dense_fwd(&x, &w, &b, m, k, n, act, quant);
                let (want_dx, want_dw, want_db) =
                    naive::dense_vjp(&x, &w, &b, m, k, n, act, quant, &dout);

                let mut pool = Arena::new();
                let mut packs = gemm::PackCache::new();
                let mut ctx = Ctx { pool: &mut pool, packs: &mut packs };
                let (out, tape) = dense_train(
                    etuner::runtime::refcpu::kernels::XBuf::Borrowed(&x),
                    &w,
                    &b,
                    m,
                    k,
                    n,
                    act,
                    quant,
                    DenseKey { src: 1, w_off: 0 },
                    &mut ctx,
                );
                assert_bits_eq(&out, &want_out, &format!("out {tag}"));
                let mut dparams = vec![0.0f32; k * n + n];
                let dx =
                    dense_bwd(&tape, &dout, Some(&out), &w, &mut dparams, 0, k * n, true, &mut ctx);
                assert_bits_eq(&dx, &want_dx, &format!("dx {tag}"));
                assert_bits_eq(&dparams[..k * n], &want_dw, &format!("dw {tag}"));
                assert_bits_eq(&dparams[k * n..], &want_db, &format!("db {tag}"));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// end-to-end: backend infer vs a naive full-model forward per block kind
// ---------------------------------------------------------------------------

/// Slice a named tensor out of flat θ by manifest offsets.
fn tensor_slice<'a>(
    theta: &'a [f32],
    mm: &etuner::runtime::ModelManifest,
    name: &str,
) -> &'a [f32] {
    let ti = mm
        .tensors
        .iter()
        .find(|t| t.name == name)
        .unwrap_or_else(|| panic!("no tensor {name}"));
    &theta[ti.offset..ti.offset + ti.size()]
}

/// Naive full-model forward written against the manifest layout, using
/// only oracle kernels — catches orchestration-level divergence (wrong
/// residual operand, stale buffer reuse) the kernel grid can't see.
fn naive_model_infer(
    be: &RefCpuBackend,
    model: &str,
    theta: &[f32],
    x: &[f32],
    b: usize,
) -> Vec<f32> {
    let mm = be.manifest().model(model).unwrap().clone();
    let sl = |name: &str| tensor_slice(theta, &mm, name);
    let (d, h) = (mm.d, mm.h);
    let mut hcur = naive::dense_fwd(x, sl("embed.w"), sl("embed.b"), b, d, h, Act::Relu, false);
    for i in 1..=mm.blocks {
        let w1 = sl(&format!("block{i}.w1"));
        let e = w1.len() / h;
        let b1 = sl(&format!("block{i}.b1"));
        let w2 = sl(&format!("block{i}.w2"));
        let b2 = sl(&format!("block{i}.b2"));
        match mm.kind.as_str() {
            "relu_res" | "bottleneck" => {
                let mid = naive::dense_fwd(&hcur, w1, b1, b, h, e, Act::Relu, false);
                let out = naive::dense_fwd(&mid, w2, b2, b, e, h, Act::None, false);
                hcur = if mm.kind == "relu_res" {
                    hcur.iter().zip(&out).map(|(&a, &v)| (a + v).max(0.0)).collect()
                } else {
                    hcur.iter().zip(&out).map(|(&a, &v)| a + v).collect()
                };
            }
            "preln_gelu" => {
                let s = sl(&format!("block{i}.ln_s"));
                let bb = sl(&format!("block{i}.ln_b"));
                let mut ln = vec![0.0f32; b * h];
                for r in 0..b {
                    let row = &hcur[r * h..(r + 1) * h];
                    let mu = row.iter().sum::<f32>() / h as f32;
                    let var =
                        row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
                    let is = 1.0 / (var + 1e-5).sqrt();
                    for j in 0..h {
                        ln[r * h + j] = (row[j] - mu) * is * s[j] + bb[j];
                    }
                }
                let mid = naive::dense_fwd(&ln, w1, b1, b, h, e, Act::Gelu, false);
                let out = naive::dense_fwd(&mid, w2, b2, b, e, h, Act::None, false);
                hcur = hcur.iter().zip(&out).map(|(&a, &v)| a + v).collect();
            }
            other => panic!("unknown kind {other}"),
        }
    }
    naive::dense_fwd(
        &hcur,
        sl("head.w"),
        sl("head.b"),
        b,
        h,
        mm.classes,
        Act::None,
        false,
    )
}

#[test]
fn backend_infer_bit_equals_naive_model_forward() {
    // one model per block kind: relu_res (tie-prone ReZero residuals),
    // bottleneck, preln_gelu (LayerNorm + GELU epilogue)
    for model in ["res50", "mbv2", "deit"] {
        let be = RefCpuBackend::builtin().unwrap();
        let mm = be.manifest().model(model).unwrap().clone();
        let theta = be.theta0(model).unwrap();
        let b = 5; // not a full panel multiple
        let mut rng = Pcg32::new(74, 4);
        let mut x = randv(&mut rng, b * mm.d, 1.0);
        // zero a row so the skip path runs end-to-end
        x[..mm.d].iter_mut().for_each(|v| *v = 0.0);

        let want = naive_model_infer(&be, model, &theta, &x, b);

        let tv = be.marshal_f32(&theta, &[mm.theta_len]).unwrap();
        let xv = be.marshal_f32(&x, &[b, mm.d]).unwrap();
        let out = be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        let got = out[0].read_f32().unwrap();
        assert_bits_eq(&got, &want, &format!("{model} logits"));

        // a second execute (warm packs, recycled scratch) must not move a bit
        let out2 = be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        assert_bits_eq(&out2[0].read_f32().unwrap(), &want, &format!("{model} warm logits"));
    }
}

#[test]
fn qat_pack_fusion_bit_equals_naive_qat() {
    // the fused quantize-while-packing path vs naive fake_quant + matmul
    let mut rng = Pcg32::new(75, 5);
    for &(m, k, n) in &[(4, 7, 9), (16, 64, 64), (1, 17, 3)] {
        let x = randv(&mut rng, m * k, 1.0);
        let w = randv(&mut rng, k * n, 0.5);
        let b = randv(&mut rng, n, 0.2);
        let want = naive::dense_fwd(&x, &w, &b, m, k, n, Act::Relu, true);
        let pan = gemm::pack_w(&w, k, n, true);
        let xq = naive::fake_quant(&x);
        let mut got = vec![0.0f32; m * n];
        gemm::gemm_fwd(&xq, &pan, &b, m, Act::Relu, &mut got);
        assert_bits_eq(&got, &want, &format!("qat m{m} k{k} n{n}"));
    }
}
