//! Freeze-aware FLOPs & memory accounting (paper Fig. 2).
//!
//! A training iteration decomposes into
//!   * forward        — every unit, frozen or not (Case 1/2/3 all pay it);
//!   * activation-grad — every unit *above* the earliest trainable unit
//!     (backprop must carry dL/dX down to it; Case 3 truncates this);
//!   * weight-grad     — every *trainable* unit (Case 2 skips it when a
//!     unit is frozen mid-network).
//!
//! Each component costs ≈ the unit's forward FLOPs, giving the standard
//! 1:2 fwd:bwd ratio when nothing is frozen.

use crate::runtime::artifact::ModelManifest;

/// Which freeze units are currently frozen.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FreezeState {
    pub frozen: Vec<bool>, // len = units (embed, blocks..., head)
}

impl FreezeState {
    pub fn none(units: usize) -> Self {
        FreezeState { frozen: vec![false; units] }
    }

    pub fn units(&self) -> usize {
        self.frozen.len()
    }

    /// Longest frozen prefix — selects the `train_k` artifact (real
    /// backprop truncation); interior frozen units are handled by lr-mask.
    pub fn frozen_prefix(&self) -> usize {
        self.frozen.iter().take_while(|&&f| f).count()
    }

    /// Index of the earliest trainable unit (== units() if all frozen).
    pub fn first_trainable(&self) -> usize {
        self.frozen_prefix()
    }

    /// Per-unit lr multipliers for the train artifacts (0 = frozen).
    pub fn lr_mask(&self) -> Vec<f32> {
        self.frozen.iter().map(|&f| if f { 0.0 } else { 1.0 }).collect()
    }

    pub fn trainable_count(&self) -> usize {
        self.frozen.iter().filter(|&&f| !f).count()
    }
}

/// Paper-scale FLOPs for ONE training iteration at `batch` samples.
pub fn train_iter_flops(m: &ModelManifest, fs: &FreezeState, batch: usize) -> f64 {
    debug_assert_eq!(fs.units(), m.units);
    let ft = fs.first_trainable();
    let mut fwd = 0.0;
    let mut act_grad = 0.0;
    let mut w_grad = 0.0;
    for (u, pu) in m.paper_units.iter().enumerate() {
        fwd += pu.fwd_flops;
        if u > ft {
            act_grad += pu.fwd_flops;
        }
        if !fs.frozen[u] {
            w_grad += pu.fwd_flops;
        }
    }
    (fwd + act_grad + w_grad) * batch as f64
}

/// Paper-scale FLOPs for one inference pass at `batch` samples.
pub fn infer_flops(m: &ModelManifest, batch: usize) -> f64 {
    m.paper_fwd_flops() * batch as f64
}

/// Paper-scale FLOPs for one CKA probe: forward through tuning + reference
/// model on the probe batch, plus the Gram reductions for `active_layers`.
pub fn cka_probe_flops(m: &ModelManifest, active_layers: usize) -> f64 {
    let fwd2 = 2.0 * m.paper_fwd_flops() * m.batch_probe as f64;
    // Gram: 3 products of (F x B)(B x F) per layer at paper scale F≈4096.
    let gram = active_layers as f64 * 3.0 * 2.0 * 4096.0 * 4096.0 * m.batch_probe as f64;
    fwd2 + gram
}

/// Training memory footprint (bytes, paper scale) for Fig. 10: parameters
/// (always resident) + gradients for trainable units + saved activations
/// for every unit at or above the earliest trainable one.
pub fn train_memory_bytes(m: &ModelManifest, fs: &FreezeState, batch: usize) -> f64 {
    let ft = fs.first_trainable();
    let params: f64 = m.paper_units.iter().map(|u| u.param_bytes).sum();
    let mut grads = 0.0;
    let mut acts = 0.0;
    for (u, pu) in m.paper_units.iter().enumerate() {
        if !fs.frozen[u] {
            grads += pu.param_bytes;
        }
        if u >= ft {
            // activation bytes per sample ≈ fwd_flops / arithmetic
            // intensity of the real layers (~150 FLOP/byte for conv nets).
            acts += pu.fwd_flops / 150.0 * batch as f64;
        }
    }
    params + grads + acts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment,
    };

    fn toy(units: usize) -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            d: 8,
            h: 4,
            blocks: units - 2,
            classes: 3,
            units,
            kind: "relu_res".into(),
            theta_len: 100,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![Segment { offset: 0, len: 10 }; units],
            tensors: vec![],
            head: HeadInfo {
                w_offset: 0,
                w_shape: [4, 3],
                b_offset: 0,
                classes: 3,
            },
            paper_units: (0..units)
                .map(|_| PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 })
                .collect(),
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn unfrozen_is_three_times_forward() {
        let m = toy(5);
        let fs = FreezeState::none(5);
        let fwd = infer_flops(&m, 16);
        let train = train_iter_flops(&m, &fs, 16);
        // act-grad skips the first unit (nothing below it needs dX)
        let expect = fwd * 3.0 - 1e9 * 16.0;
        assert!((train - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn freezing_prefix_cuts_activation_and_weight_grads() {
        let m = toy(5);
        let mut fs = FreezeState::none(5);
        let full = train_iter_flops(&m, &fs, 16);
        fs.frozen[0] = true;
        fs.frozen[1] = true;
        let cut = train_iter_flops(&m, &fs, 16);
        assert!(cut < full);
        // fwd unchanged: 5 fwd; act-grad: units 3,4 (above ft=2); w-grad: 2,3,4
        let expect = (5.0 + 2.0 + 3.0) * 1e9 * 16.0;
        assert!((cut - expect).abs() < 1.0, "{cut} vs {expect}");
    }

    #[test]
    fn interior_freeze_cuts_weight_grad_only() {
        let m = toy(5);
        let mut fs = FreezeState::none(5);
        let full = train_iter_flops(&m, &fs, 16);
        fs.frozen[2] = true; // interior: Case 2
        let cut = train_iter_flops(&m, &fs, 16);
        assert!((full - cut - 1e9 * 16.0).abs() < 1.0);
    }

    #[test]
    fn all_frozen_costs_forward_only() {
        let m = toy(4);
        let fs = FreezeState { frozen: vec![true; 4] };
        let train = train_iter_flops(&m, &fs, 16);
        assert!((train - infer_flops(&m, 16)).abs() < 1.0);
    }

    #[test]
    fn prefix_and_mask_helpers() {
        let fs = FreezeState { frozen: vec![true, true, false, true, false] };
        assert_eq!(fs.frozen_prefix(), 2);
        assert_eq!(fs.lr_mask(), vec![0.0, 0.0, 1.0, 0.0, 1.0]);
        assert_eq!(fs.trainable_count(), 2);
    }

    #[test]
    fn memory_shrinks_with_freezing() {
        let m = toy(6);
        let none = FreezeState::none(6);
        let mut half = FreezeState::none(6);
        for u in 0..3 {
            half.frozen[u] = true;
        }
        let m0 = train_memory_bytes(&m, &none, 16);
        let m1 = train_memory_bytes(&m, &half, 16);
        assert!(m1 < m0, "{m1} !< {m0}");
    }
}
