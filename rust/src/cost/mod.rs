//! Time / energy / memory cost model.
//!
//! The paper measures wall power on a Jetson Xavier NX (15W 6-core mode).
//! That device is unavailable here, so costs are charged analytically from
//! the *paper-scale* FLOPs/bytes carried in the artifact manifest: every
//! fine-tuning round pays (i) system initialization, (ii) model load+save,
//! and (iii) compute proportional to the freeze-dependent fwd/bwd FLOPs —
//! exactly the three bars of the paper's Fig. 3 breakdown.  The structural
//! savings ETuner exploits (fewer rounds → fewer init/load events; frozen
//! layers → fewer FLOPs) are therefore charged faithfully even though the
//! numbers are model-derived rather than measured.  Calibration targets and
//! validation are recorded in EXPERIMENTS.md §Calibration.

pub mod device;
pub mod energy;
pub mod flops;

pub use device::DeviceModel;
pub use energy::{CostBook, CostBreakdown};
pub use flops::FreezeState;
