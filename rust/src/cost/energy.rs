//! Cost accumulation: the run-level ledger behind Figs. 3/8/9 and every
//! energy column in the tables.

use super::device::DeviceModel;
use super::flops::{self, FreezeState};
use crate::runtime::artifact::ModelManifest;

/// Time/energy split by the paper's three Fig.-3 categories.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    pub init_s: f64,
    pub loadsave_s: f64,
    pub compute_s: f64,
    pub init_j: f64,
    pub loadsave_j: f64,
    pub compute_j: f64,
}

impl CostBreakdown {
    pub fn total_s(&self) -> f64 {
        self.init_s + self.loadsave_s + self.compute_s
    }

    pub fn total_j(&self) -> f64 {
        self.init_j + self.loadsave_j + self.compute_j
    }

    pub fn total_wh(&self) -> f64 {
        self.total_j() / 3600.0
    }

    pub fn add(&mut self, other: &CostBreakdown) {
        self.init_s += other.init_s;
        self.loadsave_s += other.loadsave_s;
        self.compute_s += other.compute_s;
        self.init_j += other.init_j;
        self.loadsave_j += other.loadsave_j;
        self.compute_j += other.compute_j;
    }
}

/// Run-level ledger: accumulates per-round costs and whole-run counters.
#[derive(Clone, Debug)]
pub struct CostBook {
    pub device: DeviceModel,
    pub breakdown: CostBreakdown,
    pub rounds: u64,
    pub train_iterations: u64,
    pub train_flops: f64,
    pub cka_probes: u64,
    pub cka_flops: f64,
}

impl CostBook {
    pub fn new(device: DeviceModel) -> Self {
        CostBook {
            device,
            breakdown: CostBreakdown::default(),
            rounds: 0,
            train_iterations: 0,
            train_flops: 0.0,
            cka_probes: 0,
            cka_flops: 0.0,
        }
    }

    /// Charge the per-round overheads (system init + model load/save).
    /// Returns the wall time added (virtual seconds).
    pub fn charge_round_overhead(&mut self, m: &ModelManifest) -> f64 {
        let bytes = m.paper_param_bytes();
        let init = self.device.init_s(bytes);
        let ls = self.device.loadsave_s(bytes);
        self.breakdown.init_s += init;
        self.breakdown.loadsave_s += ls;
        self.breakdown.init_j += self.device.overhead_j(init);
        self.breakdown.loadsave_j += self.device.overhead_j(ls);
        self.rounds += 1;
        init + ls
    }

    /// Charge `iters` training iterations under the given freeze state.
    /// Returns the wall time added.
    pub fn charge_train(
        &mut self,
        m: &ModelManifest,
        fs: &FreezeState,
        iters: u64,
    ) -> f64 {
        self.charge_train_scaled(m, fs, iters, 1.0)
    }

    /// Like [`Self::charge_train`] but with an efficiency scale — sparse
    /// training (RigL) cuts FLOPs on paper but edge GPUs don't realize the
    /// full saving (irregular access, workload imbalance; paper §V-C).
    pub fn charge_train_scaled(
        &mut self,
        m: &ModelManifest,
        fs: &FreezeState,
        iters: u64,
        scale: f64,
    ) -> f64 {
        let fl =
            flops::train_iter_flops(m, fs, m.batch_train) * iters as f64 * scale;
        let t = self.device.compute_s(fl);
        self.breakdown.compute_s += t;
        self.breakdown.compute_j += self.device.compute_j(fl);
        self.train_iterations += iters;
        self.train_flops += fl;
        t
    }

    /// Charge one CKA probe over `active_layers` non-frozen layers
    /// (SimFreeze overhead; the paper reports <2% of total energy).
    pub fn charge_cka_probe(&mut self, m: &ModelManifest, active_layers: usize) -> f64 {
        let fl = flops::cka_probe_flops(m, active_layers);
        let t = self.device.compute_s(fl);
        self.breakdown.compute_s += t;
        self.breakdown.compute_j += self.device.compute_j(fl);
        self.cka_probes += 1;
        self.cka_flops += fl;
        t
    }

    /// Checkpoint: persist the accumulated ledger.  The device model is
    /// pure configuration and is rebuilt from flags on resume.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.f64(self.breakdown.init_s);
        w.f64(self.breakdown.loadsave_s);
        w.f64(self.breakdown.compute_s);
        w.f64(self.breakdown.init_j);
        w.f64(self.breakdown.loadsave_j);
        w.f64(self.breakdown.compute_j);
        w.u64(self.rounds);
        w.u64(self.train_iterations);
        w.f64(self.train_flops);
        w.u64(self.cka_probes);
        w.f64(self.cka_flops);
    }

    /// Inverse of [`Self::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        self.breakdown.init_s = r.f64()?;
        self.breakdown.loadsave_s = r.f64()?;
        self.breakdown.compute_s = r.f64()?;
        self.breakdown.init_j = r.f64()?;
        self.breakdown.loadsave_j = r.f64()?;
        self.breakdown.compute_j = r.f64()?;
        self.rounds = r.u64()?;
        self.train_iterations = r.u64()?;
        self.train_flops = r.f64()?;
        self.cka_probes = r.u64()?;
        self.cka_flops = r.f64()?;
        Ok(())
    }

    /// Charge a validation evaluation (`n` samples forward).
    pub fn charge_validation(&mut self, m: &ModelManifest, n: usize) -> f64 {
        let fl = m.paper_fwd_flops() * n as f64;
        let t = self.device.compute_s(fl);
        self.breakdown.compute_s += t;
        self.breakdown.compute_j += self.device.compute_j(fl);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment,
    };

    fn toy() -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            d: 8,
            h: 4,
            blocks: 2,
            classes: 3,
            units: 4,
            kind: "relu_res".into(),
            theta_len: 100,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![Segment { offset: 0, len: 10 }; 4],
            tensors: vec![],
            head: HeadInfo { w_offset: 0, w_shape: [4, 3], b_offset: 0, classes: 3 },
            paper_units: (0..4)
                .map(|_| PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 })
                .collect(),
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn ledger_accumulates_by_category() {
        let mut book = CostBook::new(DeviceModel::jetson_nx_15w());
        let m = toy();
        let fs = FreezeState::none(4);
        book.charge_round_overhead(&m);
        book.charge_train(&m, &fs, 3);
        assert_eq!(book.rounds, 1);
        assert_eq!(book.train_iterations, 3);
        assert!(book.breakdown.init_s > 0.0);
        assert!(book.breakdown.loadsave_s > 0.0);
        assert!(book.breakdown.compute_s > 0.0);
        assert!(book.breakdown.total_j() > 0.0);
    }

    #[test]
    fn fewer_rounds_less_overhead_same_compute() {
        let m = toy();
        let fs = FreezeState::none(4);
        // immediate: 10 rounds x 1 iter
        let mut imm = CostBook::new(DeviceModel::jetson_nx_15w());
        for _ in 0..10 {
            imm.charge_round_overhead(&m);
            imm.charge_train(&m, &fs, 1);
        }
        // lazy: 2 rounds x 5 iters
        let mut lazy = CostBook::new(DeviceModel::jetson_nx_15w());
        for _ in 0..2 {
            lazy.charge_round_overhead(&m);
            lazy.charge_train(&m, &fs, 5);
        }
        assert_eq!(imm.train_flops, lazy.train_flops);
        assert!(lazy.breakdown.total_s() < imm.breakdown.total_s());
        assert!(lazy.breakdown.total_j() < imm.breakdown.total_j());
        assert!(
            (imm.breakdown.compute_j - lazy.breakdown.compute_j).abs() < 1e-9
        );
    }

    #[test]
    fn wh_conversion() {
        let mut b = CostBreakdown::default();
        b.compute_j = 3600.0;
        assert!((b.total_wh() - 1.0).abs() < 1e-12);
    }
}
