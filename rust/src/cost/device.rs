//! Device profiles: time/power for compute and per-round overheads.
//!
//! Default profile models the paper's platform (NVIDIA Jetson Xavier NX in
//! the 15W 6-core mode, max GPU clock).  Constants are calibrated so the
//! *immediate fine-tuning* baseline reproduces the paper's Fig. 3 breakdown
//! (overheads ≈ 58% of time and ≈ 38% of energy on average across models)
//! — see EXPERIMENTS.md §Calibration for the check.

/// Analytic edge-device model.
#[derive(Clone, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Sustained training throughput, FLOP/s (mixed fp16/fp32 on the NX).
    pub train_flops_per_s: f64,
    /// Board power while computing, watts.
    pub compute_watts: f64,
    /// Power during init / load / save (memory + CPU bound), watts.
    pub overhead_watts: f64,
    /// Fixed system-initialization latency per fine-tuning round, seconds
    /// (runtime/driver spin-up; the size-dependent part is separate).
    pub init_fixed_s: f64,
    /// Size-dependent init (model (re)compilation): s per parameter byte.
    pub init_s_per_byte: f64,
    /// Storage bandwidth for model load+save, bytes/s.
    pub loadsave_bytes_per_s: f64,
}

impl DeviceModel {
    /// Jetson Xavier NX, 15W 6-core mode (the paper's platform).
    pub fn jetson_nx_15w() -> Self {
        DeviceModel {
            name: "jetson-nx-15w",
            train_flops_per_s: 7.0e11,
            compute_watts: 15.0,
            overhead_watts: 6.5,
            init_fixed_s: 0.12,
            init_s_per_byte: 1.6e-9,
            loadsave_bytes_per_s: 1.4e9,
        }
    }

    /// Compute time for `flops` at sustained throughput, seconds.
    pub fn compute_s(&self, flops: f64) -> f64 {
        flops / self.train_flops_per_s
    }

    /// Per-round system initialization time for a model of `bytes`, s.
    pub fn init_s(&self, bytes: f64) -> f64 {
        self.init_fixed_s + self.init_s_per_byte * bytes
    }

    /// Per-round model load + save time for a model of `bytes`, s.
    pub fn loadsave_s(&self, bytes: f64) -> f64 {
        2.0 * bytes / self.loadsave_bytes_per_s
    }

    pub fn compute_j(&self, flops: f64) -> f64 {
        self.compute_s(flops) * self.compute_watts
    }

    pub fn overhead_j(&self, seconds: f64) -> f64 {
        seconds * self.overhead_watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn immediate_round_breakdown_matches_paper_fig3() {
        // One immediate round for a ResNet50-scale model: 1 batch of 16,
        // full train (3x fwd). Overheads should land near the paper's
        // 58%-time / 38%-energy averages (tolerance: the paper's bars vary
        // by model; we accept 45-70% and 25-55%).
        let d = DeviceModel::jetson_nx_15w();
        let bytes = 97.8e6;
        let fwd = 4.1e9 * 16.0;
        let compute = d.compute_s(3.0 * fwd);
        let overhead = d.init_s(bytes) + d.loadsave_s(bytes);
        let tfrac = overhead / (overhead + compute);
        assert!((0.45..0.70).contains(&tfrac), "time overhead {tfrac}");
        let ej = d.compute_j(3.0 * fwd);
        let oj = d.overhead_j(overhead);
        let efrac = oj / (oj + ej);
        assert!((0.25..0.55).contains(&efrac), "energy overhead {efrac}");
    }

    #[test]
    fn costs_scale_monotonically() {
        let d = DeviceModel::jetson_nx_15w();
        assert!(d.compute_s(2e9) > d.compute_s(1e9));
        assert!(d.init_s(1e8) > d.init_s(1e6));
        assert!(d.loadsave_s(1e8) > d.loadsave_s(1e6));
    }
}
