//! `etuner` CLI — the L3 leader entrypoint.
//!
//! ```text
//! etuner list                           # experiments + models
//! etuner run --model res50 --benchmark nc [--tune lazytune]
//!            [--freeze simfreeze] [--requests 200] [--seed 1]
//!            [--workload poisson --offered-rps 2 --mix zipf:s=1.1,k=8]
//!            [--backend pjrt|refcpu|auto]
//! etuner capacity [--workload poisson] [--slo-ms 250] [--lo 0.1 --hi 8]
//!                 [--iters 4] [--probes 3] [--jobs N]
//! etuner repro <id|all> [--seeds 1,2] [--requests 200] [--out results]
//!              [--jobs N]               # N sweep worker threads
//!              [--backend pjrt|refcpu|auto]
//! ```
//!
//! `--backend` selects the execution backend: `pjrt` runs the AOT HLO
//! artifacts (needs `make artifacts` + the `xla` cargo feature), `refcpu`
//! runs the pure-Rust reference executor (works on any machine, with or
//! without artifacts), and `auto` (the default) prefers pjrt when it can
//! actually execute here.

use anyhow::{bail, Context, Result};

use etuner::ckpt::{Cadence, CrashInjected};
use etuner::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use etuner::data::arrival::ArrivalKind;
use etuner::data::benchmarks::Benchmark;
use etuner::load::{
    capacity_search, CapacitySpec, MixSpec, WorkloadKind, WorkloadSpec,
};
use etuner::repro::experiments::{self, ReproOpts};
use etuner::runtime::{BackendKind, BackendSpec, FaultPlan};
use etuner::serve::{FaultScope, QueuePolicyKind, MAX_BANK_CAPACITY};
use etuner::sim::{run_config_traced, ParallelSweeper, RunConfig};
use etuner::testkit;
use etuner::trace::{self, Tracer};

/// `--backend` → construction spec over the artifact directory.
fn backend_spec(args: &[String]) -> Result<BackendSpec> {
    let kind = match opt(args, "--backend") {
        Some(s) => BackendKind::parse(s)?,
        None => BackendKind::Auto,
    };
    Ok(BackendSpec::new(kind, testkit::artifacts_dir()))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "list" => {
            println!("experiments (etuner repro <id>):");
            for (id, desc) in experiments::list() {
                println!("  {id:<6} {desc}");
            }
            println!("\nmodels: res50 mbv2 deit bert");
            println!("benchmarks: nc nic79 nic391 scifar10 news20");
            println!("tune policies: immediate static:<n> lazytune");
            println!("freeze policies: none simfreeze egeria slimfit rigl ekya");
            Ok(())
        }
        "run" => cmd_run(&args[1..]),
        "capacity" => cmd_capacity(&args[1..]),
        "repro" => cmd_repro(&args[1..]),
        "help" | "--help" | "-h" => {
            println!(
                "usage: etuner <list|run|capacity|repro> [options]\n\
                 run   --model M --benchmark B [--tune P] [--freeze F]\n\
                       [--requests N] [--seed S] [--arrival poisson|uniform|normal|trace]\n\
                       [--workload poisson|bursty|diurnal|pareto]\n\
                       [--offered-rps R] [--load-window S] [--mix SPEC]\n\
                       [--quant] [--labeled FRAC] [--cka-th TH]\n\
                       [--batch-window S] [--slo-ms MS] [--no-batching]\n\
                       [--queue-policy fifo|edf] [--max-queue N]\n\
                       [--shed-infeasible] [--bank-capacity N]\n\
                       [--fleet N] [--no-affinity] [--rebalance-threshold X]\n\
                       [--faults SPEC] [--fault-seed S] [--fault-scope engine0|all]\n\
                       [--checkpoint-dir DIR] [--checkpoint-every Nr|Ss]\n\
                       [--resume DIR]\n\
                       [--trace] [--trace-out FILE] [--trace-summary]\n\
                       [--backend pjrt|refcpu|auto]\n\
                       --batch-window S coalesces requests for up to S virtual\n\
                       seconds per padded execute (0 = off); --slo-ms sets the\n\
                       latency SLO; --no-batching forces the direct per-request\n\
                       path (bit-identical reports to --batch-window 0)\n\
                       --queue-policy orders the serving queue: fifo (default)\n\
                       or edf (earliest-deadline-first across scenarios);\n\
                       --max-queue N drops arrivals beyond N queued (0 = no\n\
                       cap); --shed-infeasible drops arrivals whose deadline\n\
                       cannot be met even on an idle device; --bank-capacity N\n\
                       bounds the resident per-scenario serving-theta banks\n\
                       (LRU-evicted beyond N; default 4, ceiling 8 so banks\n\
                       fit the session theta-cache)\n\
                       --fleet N serves through N independent engines behind\n\
                       a scenario-affinity router (default 1: the bare\n\
                       engine, bit-identical reports); --no-affinity routes\n\
                       purely least-loaded; --rebalance-threshold X installs\n\
                       a second bank for a scenario once one engine holds\n\
                       more than X of its fleet-wide queued requests\n\
                       (default 0.5; 0 disables rebalancing)\n\
                       --faults injects deterministic backend faults:\n\
                       comma-separated exec:RATE, marshal:RATE,\n\
                       spike:RATExSECONDS, burst:N, seed:S (e.g.\n\
                       --faults exec:0.05,spike:0.02x0.25,burst:2); the\n\
                       serving engine retries with virtual-time backoff,\n\
                       trips a circuit breaker, and serves stale banks\n\
                       degraded while it is open; --fault-seed varies the\n\
                       fault stream without changing the run seed;\n\
                       --fault-scope picks which engines the plan degrades\n\
                       in the multi-backend pool runner: engine0 (default)\n\
                       or all (per-engine salted fault streams); the plan\n\
                       also accepts crash:after-round-N / crash:t=S /\n\
                       crash:RATE (deterministic crash points, exit code 3)\n\
                       and ckpt-flip:N / ckpt-torn:N (corrupt the Nth\n\
                       checkpoint record to exercise recovery)\n\
                       --checkpoint-dir DIR checkpoints every round boundary\n\
                       into DIR (crash-durable: atomic snapshots on the\n\
                       --checkpoint-every cadence, e.g. 5r or 120s, plus an\n\
                       append-only journal between them); --resume DIR\n\
                       restores the newest valid record and continues to a\n\
                       bit-identical report (default: no checkpointing, the\n\
                       exact pre-checkpoint code path)\n\
                       --workload switches the inference stream to an\n\
                       open-loop generator (timestamps at the configured\n\
                       offered rate, independent of completions, so queues\n\
                       genuinely grow): poisson, bursty (Markov-modulated\n\
                       on-off), diurnal (sinusoidal rate envelope, one\n\
                       cycle per horizon), pareto (heavy-tailed gaps);\n\
                       --offered-rps R sets the mean offered rate (default\n\
                       2); --load-window S only generates arrivals in\n\
                       [0, S) of the horizon; --mix zipf:s=1.1,k=8 draws\n\
                       each request's scenario from a Zipf popularity law\n\
                       (skew s over the k hottest scenarios; add shift=0.5\n\
                       to rotate popularity mid-run and stress bank\n\
                       eviction + fleet affinity)\n\
                       --trace records a virtual-time timeline (also enabled\n\
                       by ETUNER_TRACE=1 or by either flag below);\n\
                       --trace-out FILE writes it as Chrome trace-event JSON\n\
                       (open in Perfetto / chrome://tracing);\n\
                       --trace-summary prints the serving/tuning/idle\n\
                       time-in-state table after the run\n\
                 capacity [--model M] [--benchmark B] [--seed S] [--fleet N]\n\
                       [--workload K] [--mix SPEC] [--load-window S]\n\
                       [--max-queue N] [--shed-infeasible]\n\
                       [--slo-ms MS] [--drop-eps E] [--lo RPS] [--hi RPS]\n\
                       [--iters N] [--probes N] [--jobs N] [--backend ...]\n\
                       bisects offered RPS for the latency-vs-throughput\n\
                       knee: the highest rate whose probe run meets\n\
                       p99 <= --slo-ms (default 250) and drop-rate <=\n\
                       --drop-eps (default 0.01); each bisection iteration\n\
                       probes a fixed fan-out of --probes rates (default 3)\n\
                       through the parallel sweeper, so the knee is\n\
                       bit-identical for any --jobs\n\
                 repro <id|all> [--seeds 1,2] [--requests N] [--out DIR] [--jobs N]\n\
                       [--quarantine-after N] [--sweep-journal FILE]\n\
                       [--backend pjrt|refcpu|auto]\n\
                       --jobs N runs N seed-sweep workers (default: all cores)\n\
                       --quarantine-after N quarantines a sweep cell after N\n\
                       worker panics (default 2; min 1); --sweep-journal FILE\n\
                       records each finished cell so an interrupted sweep\n\
                       resumes, re-running only unfinished cells\n\
                 --backend: pjrt executes the AOT artifacts (make artifacts +\n\
                       --features xla); refcpu is the pure-rust reference\n\
                       executor (no artifacts needed — uses the built-in model\n\
                       family, bit-deterministic across --jobs); auto (default)\n\
                       prefers pjrt when it can execute here"
            );
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `etuner help`"),
    }
}

fn opt<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

fn parse_tune(s: &str) -> Result<TunePolicyKind> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "immediate" | "immed" => TunePolicyKind::Immediate,
        "lazytune" | "lazy" => TunePolicyKind::LazyTune,
        other => {
            if let Some(n) = other.strip_prefix("static:") {
                TunePolicyKind::Static(n.parse()?)
            } else {
                bail!("unknown tune policy {other:?}")
            }
        }
    })
}

fn parse_freeze(s: &str) -> Result<FreezePolicyKind> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "none" => FreezePolicyKind::None,
        "simfreeze" => FreezePolicyKind::SimFreeze,
        "egeria" => FreezePolicyKind::Egeria,
        "slimfit" => FreezePolicyKind::SlimFit,
        "rigl" => FreezePolicyKind::RigL,
        "ekya" => FreezePolicyKind::Ekya,
        other => bail!("unknown freeze policy {other:?}"),
    })
}

fn cmd_run(args: &[String]) -> Result<()> {
    let model = opt(args, "--model").unwrap_or("res50");
    let bench = Benchmark::parse(opt(args, "--benchmark").unwrap_or("nc"))
        .context("bad --benchmark")?;
    let mut cfg = RunConfig::quickstart(model, bench);
    if let Some(t) = opt(args, "--tune") {
        cfg.tune = parse_tune(t)?;
    }
    if let Some(f) = opt(args, "--freeze") {
        cfg.freeze = parse_freeze(f)?;
    }
    if let Some(n) = opt(args, "--requests") {
        cfg.n_requests = n.parse()?;
    }
    if let Some(s) = opt(args, "--seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(a) = opt(args, "--arrival") {
        let k = ArrivalKind::parse(a).context("bad --arrival")?;
        cfg.train_arrival = k;
        cfg.infer_arrival = k;
    }
    if let Some(th) = opt(args, "--cka-th") {
        cfg.cka_th = th.parse()?;
    }
    if let Some(l) = opt(args, "--labeled") {
        cfg.labeled_fraction = Some(l.parse()?);
    }
    cfg.quant = flag(args, "--quant");
    cfg.oracle_change_detection = flag(args, "--oracle-changes");
    if let Some(w) = opt(args, "--batch-window") {
        cfg.serve.batch_window_s = w.parse().context("bad --batch-window")?;
    }
    if let Some(s) = opt(args, "--slo-ms") {
        cfg.serve.slo_ms = s.parse().context("bad --slo-ms")?;
    }
    if let Some(p) = opt(args, "--queue-policy") {
        cfg.serve.queue_policy =
            QueuePolicyKind::parse(p).context("bad --queue-policy")?;
    }
    if let Some(q) = opt(args, "--max-queue") {
        cfg.serve.max_queue = q.parse().context("bad --max-queue")?;
    }
    if let Some(b) = opt(args, "--bank-capacity") {
        let n: usize = b.parse().context("bad --bank-capacity")?;
        let clamped = n.clamp(1, MAX_BANK_CAPACITY);
        if clamped != n {
            trace::note(format_args!(
                "[etuner] --bank-capacity {n} is outside 1..={MAX_BANK_CAPACITY} \
                 (banks must fit the session theta-cache alongside the live \
                 parameters); clamping to {clamped}"
            ));
        }
        cfg.serve.bank_capacity = clamped;
    }
    cfg.serve.shed_infeasible = flag(args, "--shed-infeasible");
    cfg.serve_direct = flag(args, "--no-batching");
    if let Some(n) = opt(args, "--fleet") {
        let n: usize = n.parse().context("bad --fleet")?;
        cfg.fleet.engines = n.max(1);
    }
    if let Some(th) = opt(args, "--rebalance-threshold") {
        cfg.fleet.rebalance_threshold =
            th.parse().context("bad --rebalance-threshold")?;
    }
    if flag(args, "--no-affinity") {
        cfg.fleet.affinity = false;
    }
    if let Some(f) = opt(args, "--faults") {
        cfg.faults = FaultPlan::parse(f).context("bad --faults")?;
    }
    if let Some(s) = opt(args, "--fault-seed") {
        cfg.faults.seed = s.parse().context("bad --fault-seed")?;
    }
    if let Some(s) = opt(args, "--fault-scope") {
        cfg.fleet.fault_scope =
            FaultScope::parse(s).context("bad --fault-scope")?;
    }
    if let Some(d) = opt(args, "--checkpoint-dir") {
        cfg.checkpoint.dir = Some(d.into());
    }
    if let Some(e) = opt(args, "--checkpoint-every") {
        cfg.checkpoint.every =
            Cadence::parse(e).context("bad --checkpoint-every")?;
    }
    if let Some(d) = opt(args, "--resume") {
        cfg.checkpoint.dir = Some(d.into());
        cfg.checkpoint.resume = true;
    }
    if let Some(d) = opt(args, "--decay") {
        use etuner::coordinator::lazytune::DecayKind;
        cfg.decay = match d {
            "log" | "logarithmic" => DecayKind::Logarithmic,
            "exp" | "exponential" => DecayKind::Exponential,
            "add" | "additive" => DecayKind::Additive,
            other => bail!("unknown decay {other:?}"),
        };
    }
    cfg.workload = parse_workload(args)?;
    if let Some(w) = &cfg.workload {
        trace::note(format_args!(
            "[etuner] open-loop workload: {} at {} rps{} (--requests ignored; \
             request count is emergent)",
            w.kind.name(),
            w.offered_rps,
            match &w.mix {
                Some(m) => format!(", mix {}", m.label()),
                None => String::new(),
            },
        ));
    }

    let trace_out = opt(args, "--trace-out");
    let trace_summary = flag(args, "--trace-summary");
    let trace_on = flag(args, "--trace")
        || trace_out.is_some()
        || trace_summary
        || trace::env_enabled();
    let tracer = if trace_on {
        Tracer::enabled(trace::DEFAULT_CAPACITY)
    } else {
        Tracer::disabled()
    };

    let be = backend_spec(args)?.create()?;
    trace::note(format_args!("[etuner] backend: {}", be.name()));
    let faults_on = cfg.faults.enabled();
    let ckpt_dir = cfg.checkpoint.dir.clone();
    let report = match run_config_traced(be.as_ref(), cfg, &tracer) {
        Ok(r) => r,
        Err(e) => match e.downcast::<CrashInjected>() {
            Ok(crash) => {
                let hint = match ckpt_dir {
                    Some(d) => format!("resume with --resume {}", d.display()),
                    None => "no --checkpoint-dir, so there is nothing to \
                             resume from"
                        .into(),
                };
                eprintln!(
                    "[etuner] injected crash at round {} (t={:.3}s); {hint}",
                    crash.round, crash.t
                );
                std::process::exit(3);
            }
            Err(e) => return Err(e),
        },
    };
    println!("{}", report.summary());
    println!(
        "  breakdown: init {:.1}s / loadsave {:.1}s / compute {:.1}s; \
         {:.2} Wh total; {} scenario changes detected; wall {:.1}s",
        report.energy.init_s,
        report.energy.loadsave_s,
        report.energy.compute_s,
        report.energy.total_wh(),
        report.scenario_changes_detected,
        report.wall_exec_s,
    );
    println!(
        "  serving: p50 {:.1}ms / p95 {:.1}ms / p99 {:.1}ms; \
         {} of {} requests over the {:.0}ms SLO; \
         {} executes ({:.2} req/exec); {} rounds deferred",
        report.latency_p50_ms,
        report.latency_p95_ms,
        report.latency_p99_ms,
        report.slo_violations,
        report.requests.len(),
        report.slo_ms,
        report.serve_executes,
        report.avg_batch_requests,
        report.rounds_deferred,
    );
    println!(
        "  control plane: {} queue; {} dropped ({} queue-full, {} infeasible); \
         {} deadline misses; {} banks peak resident ({} evictions)",
        report.queue_policy,
        report.requests_dropped,
        report.drops_queue_full,
        report.drops_slo_infeasible,
        report.deadline_misses,
        report.banks_peak_resident,
        report.bank_evictions,
    );
    if report.fleet_engines > 1 {
        println!(
            "  fleet: {} engines; {} routed by affinity / {} least-loaded; \
             {} cross-engine retries; {} rebalances",
            report.fleet_engines,
            report.fleet_routed_affinity,
            report.fleet_routed_least_loaded,
            report.fleet_cross_engine_retries,
            report.fleet_rebalances,
        );
    }
    for s in &report.per_scenario_latency {
        println!(
            "    scen {}: {} reqs, mean {:.1}ms / p95 {:.1}ms / max {:.1}ms, \
             {} deadline misses",
            s.scenario, s.requests, s.mean_ms, s.p95_ms, s.max_ms,
            s.deadline_misses,
        );
    }
    if faults_on {
        println!(
            "  recovery: {} faults injected ({} exec, {} marshal, {} spikes, \
             +{:.2}s latency); {} retries; {} breaker trips; \
             {} degraded serves; {} unavailable drops; {} round rollbacks",
            report.faults_injected_exec
                + report.faults_injected_marshal
                + report.faults_injected_spikes,
            report.faults_injected_exec,
            report.faults_injected_marshal,
            report.faults_injected_spikes,
            report.fault_delay_injected_s,
            report.serve_retries,
            report.breaker_trips,
            report.degraded_serves,
            report.drops_backend_unavailable,
            report.round_rollbacks,
        );
    }
    if let Some(path) = trace_out {
        let json = tracer.to_chrome_json().to_string();
        std::fs::write(path, &json)
            .with_context(|| format!("writing --trace-out {path}"))?;
        trace::note(format_args!(
            "[etuner] wrote {} trace events to {path} (load in Perfetto or \
             chrome://tracing; {} dropped by the ring)",
            tracer.events().len(),
            tracer.dropped(),
        ));
    }
    if trace_summary {
        print!("{}", trace::summary_table(&report, &tracer));
    }
    Ok(())
}

/// `--workload`/`--offered-rps`/`--load-window`/`--mix` → open-loop spec.
/// `None` when `--workload` is absent: the closed arrival stream stays
/// byte-identical to every pre-load-layer release.
fn parse_workload(args: &[String]) -> Result<Option<WorkloadSpec>> {
    let Some(w) = opt(args, "--workload") else {
        if opt(args, "--offered-rps").is_some() || opt(args, "--mix").is_some()
        {
            bail!(
                "--offered-rps/--mix require --workload \
                 <poisson|bursty|diurnal|pareto>"
            );
        }
        return Ok(None);
    };
    let kind = WorkloadKind::parse(w).with_context(|| {
        format!("bad --workload {w:?} (poisson|bursty|diurnal|pareto)")
    })?;
    let mut spec = WorkloadSpec {
        kind,
        offered_rps: 2.0,
        window_s: None,
        mix: None,
    };
    if let Some(r) = opt(args, "--offered-rps") {
        spec.offered_rps = r.parse().context("bad --offered-rps")?;
    }
    if let Some(s) = opt(args, "--load-window") {
        spec.window_s = Some(s.parse().context("bad --load-window")?);
    }
    if let Some(m) = opt(args, "--mix") {
        spec.mix = Some(MixSpec::parse(m)?);
    }
    Ok(Some(spec))
}

fn cmd_capacity(args: &[String]) -> Result<()> {
    let model = opt(args, "--model").unwrap_or("mbv2");
    let bench =
        Benchmark::parse(opt(args, "--benchmark").unwrap_or("scifar10"))
            .context("bad --benchmark")?;
    let mut cfg = RunConfig::quickstart(model, bench);
    if let Some(s) = opt(args, "--seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(n) = opt(args, "--fleet") {
        let n: usize = n.parse().context("bad --fleet")?;
        cfg.fleet.engines = n.max(1);
    }
    if let Some(q) = opt(args, "--max-queue") {
        cfg.serve.max_queue = q.parse().context("bad --max-queue")?;
    }
    cfg.serve.shed_infeasible = flag(args, "--shed-infeasible");
    // Probe workload: --workload defaults to poisson here (unlike `run`,
    // where its absence means "closed stream"); offered_rps is a
    // placeholder the search overrides per probe.  A bounded generation
    // window keeps event counts sane at high probe rates.
    let kind = match opt(args, "--workload") {
        Some(w) => WorkloadKind::parse(w).with_context(|| {
            format!("bad --workload {w:?} (poisson|bursty|diurnal|pareto)")
        })?,
        None => WorkloadKind::Poisson,
    };
    let window_s = match opt(args, "--load-window") {
        Some(s) => s.parse().context("bad --load-window")?,
        None => 120.0,
    };
    let mix = match opt(args, "--mix") {
        Some(m) => Some(MixSpec::parse(m)?),
        None => None,
    };
    cfg.workload = Some(WorkloadSpec {
        kind,
        offered_rps: 0.0,
        window_s: Some(window_s),
        mix,
    });

    let mut spec = CapacitySpec::default();
    if let Some(s) = opt(args, "--slo-ms") {
        spec.slo_ms = s.parse().context("bad --slo-ms")?;
    }
    if let Some(e) = opt(args, "--drop-eps") {
        spec.drop_eps = e.parse().context("bad --drop-eps")?;
    }
    if let Some(l) = opt(args, "--lo") {
        spec.lo_rps = l.parse().context("bad --lo")?;
    }
    if let Some(h) = opt(args, "--hi") {
        spec.hi_rps = h.parse().context("bad --hi")?;
    }
    if let Some(i) = opt(args, "--iters") {
        spec.iters = i.parse().context("bad --iters")?;
    }
    if let Some(p) = opt(args, "--probes") {
        spec.probes_per_iter = p.parse().context("bad --probes")?;
    }
    cfg.serve.slo_ms = spec.slo_ms;

    let jobs = match opt(args, "--jobs") {
        Some(j) => j.parse().context("bad --jobs")?,
        None => ParallelSweeper::default_jobs(),
    };
    let sw = ParallelSweeper::new(backend_spec(args)?, jobs)?;
    trace::note(format_args!("[etuner] backend: {}", sw.backend().name()));
    if let Some(w) = &cfg.workload {
        println!(
            "capacity search: {} workload{} | {model}/{} fleet={} | \
             SLO p99<={}ms drop<={} | bracket [{}, {}] rps, {} iters x {} \
             probes, {} jobs",
            w.kind.name(),
            match &w.mix {
                Some(m) => format!(" ({})", m.label()),
                None => String::new(),
            },
            bench.name(),
            cfg.fleet.engines,
            spec.slo_ms,
            spec.drop_eps,
            spec.lo_rps,
            spec.hi_rps,
            spec.iters,
            spec.probes_per_iter,
            sw.jobs(),
        );
    }
    let res = capacity_search(&sw, &cfg, &spec)?;
    for p in &res.probes {
        println!(
            "  probe {:>9.4} rps: p99 {:>8.1} ms, drop {:.4}, served {:>6}, \
             dropped {:>5}  {}",
            p.offered_rps,
            p.p99_ms,
            p.drop_rate,
            p.served,
            p.dropped,
            if p.passed { "PASS" } else { "FAIL" },
        );
    }
    if !res.saturated {
        println!(
            "  note: hi bracket {} rps still met the SLO — knee is a lower \
             bound; widen --hi to find the true knee",
            res.bracket_hi_rps,
        );
    }
    let bound = if res.saturated {
        format!("first failing rate {:.4} rps", res.bracket_hi_rps)
    } else {
        "bracket never saturated".to_string()
    };
    println!(
        "knee: {:.4} rps sustainable (p99 {:.1} ms, drop {:.4} at knee); \
         {bound}; {} probe runs",
        res.knee_rps,
        res.p99_at_knee_ms,
        res.drop_rate_at_knee,
        res.probes.len(),
    );
    Ok(())
}

fn cmd_repro(args: &[String]) -> Result<()> {
    let id = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .map(String::as_str)
        .unwrap_or("all");
    let mut opts = ReproOpts::default();
    if let Some(s) = opt(args, "--seeds") {
        opts.seeds = s
            .split(',')
            .map(|x| x.parse().context("bad --seeds"))
            .collect::<Result<_>>()?;
    }
    if let Some(n) = opt(args, "--requests") {
        opts.n_requests = n.parse()?;
    }
    if let Some(o) = opt(args, "--out") {
        opts.results_dir = o.into();
    }
    let jobs = match opt(args, "--jobs") {
        Some(j) => j.parse().context("bad --jobs")?,
        None => ParallelSweeper::default_jobs(),
    };
    let mut sw = ParallelSweeper::new(backend_spec(args)?, jobs)?;
    if let Some(n) = opt(args, "--quarantine-after") {
        sw.set_quarantine_after(n.parse().context("bad --quarantine-after")?);
    }
    if let Some(p) = opt(args, "--sweep-journal") {
        sw.set_journal(p);
    }
    if flag(args, "--trace") || trace::env_enabled() {
        sw.set_tracer(Tracer::enabled(trace::DEFAULT_CAPACITY));
    }
    trace::note(format_args!("[etuner] backend: {}", sw.backend().name()));
    experiments::run_experiment(&sw, id, &opts)
}
