//! Virtual-time structured tracing for the tuning/serving stack.
//!
//! EdgeOL's whole argument is a *schedule* — when fine-tuning rounds fire,
//! how long they occupy the device, which batch flushes block serving —
//! yet until this layer the only visibility was the end-of-run
//! [`crate::metrics::Report`] plus ad-hoc `ETUNER_DEBUG` eprintlns.  The
//! [`Tracer`] records a timeline of **virtual-time** events (the
//! simulator's seconds, never wall clock) into a preallocated ring buffer,
//! and exports it two ways:
//!
//! * [`chrome_trace`] — Chrome trace-event JSON (`--trace-out trace.json`),
//!   loadable in Perfetto / `chrome://tracing`, one "thread" lane per
//!   subsystem ([`Lane`]);
//! * [`summary_table`] — a plain-text time-in-state table
//!   (`--trace-summary`): serving vs tuning vs idle, the paper's Fig. 1
//!   timeline reconstructed from a real run.
//!
//! Cost discipline mirrors [`crate::runtime::FaultPlan`]: the default
//! [`Tracer::disabled`] holds **no allocation at all** (an empty
//! `Option`), cloning it is free, and every record method is one inlined
//! `is_some` check before returning.  Nothing is allocated unless
//! `--trace` / `ETUNER_TRACE` turns tracing on, and the enabled buffer is
//! bounded: when the ring wraps, the oldest events are overwritten and a
//! `dropped` counter records the loss instead of growing memory.
//!
//! All data recorded here is observability-only: nothing feeds back into
//! scheduling decisions and nothing enters [`Report::fingerprint`]
//! (asserted by `tests/trace.rs`).
//!
//! [`Report::fingerprint`]: crate::metrics::Report::fingerprint

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt;
use std::rc::Rc;
use std::sync::OnceLock;

use crate::json::Json;
use crate::metrics::Report;

/// Maximum number of typed `(key, value)` annotations per event.  Fixed so
/// [`Event`] is `Copy` and recording never allocates; callers truncate.
pub const MAX_ARGS: usize = 6;

/// Default ring capacity (events) used by the CLI.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// One timeline lane per subsystem — rendered as a Chrome trace "thread".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Lane {
    /// Serving control plane: arrivals, admission, flushes, executes.
    Engine,
    /// Tune-vs-serve scheduler: round trigger/defer/run.
    Rounds,
    /// Sweep orchestration: cell claims, restarts, quarantines.
    Sweep,
    /// Backend execute boundary (the `TracingBackend` decorator).
    Backend,
}

impl Lane {
    pub const ALL: [Lane; 4] =
        [Lane::Engine, Lane::Rounds, Lane::Sweep, Lane::Backend];

    /// Stable lane name used for the Chrome `thread_name` metadata and the
    /// summary table.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Engine => "serve-engine",
            Lane::Rounds => "rounds",
            Lane::Sweep => "sweep",
            Lane::Backend => "backend",
        }
    }

    fn idx(self) -> usize {
        match self {
            Lane::Engine => 0,
            Lane::Rounds => 1,
            Lane::Sweep => 2,
            Lane::Backend => 3,
        }
    }

    /// Chrome trace `tid` (1-based so lane 0 isn't confused with the pid).
    fn tid(self) -> u64 {
        self.idx() as u64 + 1
    }
}

/// Event flavor, mapped to Chrome trace phases on export.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Complete span (`ph:"X"`): `[t0, t0+dur]`.
    Span,
    /// Instant (`ph:"i"`).
    Instant,
    /// Typed counter sample (`ph:"C"`).
    Counter,
}

/// One recorded event.  `Copy` and allocation-free by construction: names
/// are `&'static str` and annotations live in a fixed inline array.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub lane: Lane,
    pub kind: Kind,
    pub name: &'static str,
    /// Virtual-time start (seconds).
    pub t0: f64,
    /// Virtual-time duration (seconds; 0 for instants/counters).
    pub dur: f64,
    args: [(&'static str, f64); MAX_ARGS],
    n_args: u8,
}

impl Event {
    /// The typed annotations recorded with this event.
    pub fn args(&self) -> &[(&'static str, f64)] {
        &self.args[..self.n_args as usize]
    }
}

fn pack_args(args: &[(&'static str, f64)]) -> ([(&'static str, f64); MAX_ARGS], u8) {
    let mut a = [("", 0.0); MAX_ARGS];
    let n = args.len().min(MAX_ARGS);
    a[..n].copy_from_slice(&args[..n]);
    (a, n as u8)
}

/// The enabled tracer's storage: a bounded ring of events plus per-lane
/// open-span stacks.  Extracted ([`Tracer::take_events`]) to move event
/// batches across threads (sweep workers record locally, the coordinator
/// absorbs in deterministic cell order).
#[derive(Debug)]
struct TraceBuf {
    events: Vec<Event>,
    cap: usize,
    /// Ring write cursor, valid once `events.len() == cap`.
    next: usize,
    /// Events overwritten after the ring wrapped.
    dropped: u64,
    /// Per-lane stacks of open spans: (name, t0).
    open: [Vec<(&'static str, f64)>; 4],
    /// Last virtual time seen (backend-boundary events are stamped with
    /// this — backend calls are instantaneous in virtual time).
    now: f64,
}

impl TraceBuf {
    fn new(capacity: usize) -> TraceBuf {
        let cap = capacity.max(16);
        TraceBuf {
            events: Vec::with_capacity(cap),
            cap,
            next: 0,
            dropped: 0,
            open: [Vec::new(), Vec::new(), Vec::new(), Vec::new()],
            now: 0.0,
        }
    }

    fn push(&mut self, e: Event) {
        if self.events.len() < self.cap {
            self.events.push(e);
        } else {
            self.events[self.next] = e;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }
}

/// Cheap, cloneable handle to a (possibly absent) trace buffer.
///
/// `Tracer::disabled()` is the default everywhere a tracer is threaded
/// (`ServeEngine`, `Simulation`, `ParallelSweeper`, `TracingBackend`) and
/// holds nothing: no allocation, and every record method returns after one
/// inlined `is_some` check.  `Tracer::enabled(cap)` preallocates the ring.
/// Clones share the same buffer (single-threaded `Rc` — a tracer never
/// crosses threads; sweep workers build their own and hand the events
/// back).
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    buf: Option<Rc<RefCell<TraceBuf>>>,
}

impl Tracer {
    /// The no-op tracer: zero allocations, zero recorded events.
    #[inline]
    pub fn disabled() -> Tracer {
        Tracer { buf: None }
    }

    /// A recording tracer with a preallocated ring of `capacity` events.
    pub fn enabled(capacity: usize) -> Tracer {
        Tracer { buf: Some(Rc::new(RefCell::new(TraceBuf::new(capacity)))) }
    }

    /// Whether events are being recorded.
    #[inline]
    pub fn on(&self) -> bool {
        self.buf.is_some()
    }

    /// Advance the tracer's virtual clock (used to stamp backend-boundary
    /// events, which have no virtual duration of their own).
    #[inline]
    pub fn set_now(&self, t: f64) {
        if let Some(b) = &self.buf {
            b.borrow_mut().now = t;
        }
    }

    /// Last virtual time seen via [`Self::set_now`] (0 when disabled).
    #[inline]
    pub fn now(&self) -> f64 {
        match &self.buf {
            Some(b) => b.borrow().now,
            None => 0.0,
        }
    }

    /// Record a complete span `[t0, t1]`.
    #[inline]
    pub fn span(
        &self,
        lane: Lane,
        name: &'static str,
        t0: f64,
        t1: f64,
        args: &[(&'static str, f64)],
    ) {
        if let Some(b) = &self.buf {
            let (a, n) = pack_args(args);
            b.borrow_mut().push(Event {
                lane,
                kind: Kind::Span,
                name,
                t0,
                dur: (t1 - t0).max(0.0),
                args: a,
                n_args: n,
            });
        }
    }

    /// Open a span on `lane`; closed by the matching [`Self::end`].
    #[inline]
    pub fn begin(&self, lane: Lane, name: &'static str, t: f64) {
        if let Some(b) = &self.buf {
            b.borrow_mut().open[lane.idx()].push((name, t));
        }
    }

    /// Close the innermost open span on `lane`, recording it as a complete
    /// span with `args` attached.  Unbalanced `end`s are ignored.
    #[inline]
    pub fn end(&self, lane: Lane, t: f64, args: &[(&'static str, f64)]) {
        if let Some(b) = &self.buf {
            let mut b = b.borrow_mut();
            if let Some((name, t0)) = b.open[lane.idx()].pop() {
                let (a, n) = pack_args(args);
                b.push(Event {
                    lane,
                    kind: Kind::Span,
                    name,
                    t0,
                    dur: (t - t0).max(0.0),
                    args: a,
                    n_args: n,
                });
            }
        }
    }

    /// Record an instant event.
    #[inline]
    pub fn instant(
        &self,
        lane: Lane,
        name: &'static str,
        t: f64,
        args: &[(&'static str, f64)],
    ) {
        if let Some(b) = &self.buf {
            let (a, n) = pack_args(args);
            b.borrow_mut().push(Event {
                lane,
                kind: Kind::Instant,
                name,
                t0: t,
                dur: 0.0,
                args: a,
                n_args: n,
            });
        }
    }

    /// Record a typed counter sample (rendered as a Chrome counter track).
    #[inline]
    pub fn counter(&self, lane: Lane, name: &'static str, t: f64, value: f64) {
        if let Some(b) = &self.buf {
            let (a, n) = pack_args(&[("value", value)]);
            b.borrow_mut().push(Event {
                lane,
                kind: Kind::Counter,
                name,
                t0: t,
                dur: 0.0,
                args: a,
                n_args: n,
            });
        }
    }

    /// Structured replacement for the scattered `ETUNER_DEBUG` eprintln
    /// sites: records an instant *and* keeps the legacy stderr echo when
    /// `ETUNER_DEBUG` is set — so existing debugging workflows keep
    /// working whether or not tracing is on.
    #[inline]
    pub fn debug(
        &self,
        lane: Lane,
        name: &'static str,
        t: f64,
        args: &[(&'static str, f64)],
        msg: fmt::Arguments<'_>,
    ) {
        if debug_enabled() {
            eprintln!("{msg}");
        }
        self.instant(lane, name, t, args);
    }

    /// Events overwritten after the ring wrapped (0 when disabled).
    pub fn dropped(&self) -> u64 {
        match &self.buf {
            Some(b) => b.borrow().dropped,
            None => 0,
        }
    }

    /// Snapshot of all recorded events in chronological record order.
    pub fn events(&self) -> Vec<Event> {
        match &self.buf {
            Some(b) => {
                let b = b.borrow();
                let mut out =
                    Vec::with_capacity(b.events.len());
                // ring order: oldest surviving event first
                out.extend_from_slice(&b.events[b.next..]);
                out.extend_from_slice(&b.events[..b.next]);
                out
            }
            None => Vec::new(),
        }
    }

    /// Drain the buffer, returning the events (record order) and leaving
    /// the ring empty.  Used by sweep workers to hand their thread-local
    /// timeline back to the coordinator.
    pub fn take_events(&self) -> Vec<Event> {
        match &self.buf {
            Some(b) => {
                let mut b = b.borrow_mut();
                let next = b.next;
                let mut evs = std::mem::take(&mut b.events);
                evs.rotate_left(next.min(evs.len()));
                b.next = 0;
                evs
            }
            None => Vec::new(),
        }
    }

    /// Append a batch of events (e.g. a sweep worker's drained buffer).
    pub fn absorb(&self, events: &[Event]) {
        if let Some(b) = &self.buf {
            let mut b = b.borrow_mut();
            for &e in events {
                b.push(e);
            }
        }
    }

    /// Export the recorded timeline as Chrome trace-event JSON.
    pub fn to_chrome_json(&self) -> Json {
        chrome_trace(&self.events())
    }
}

// ---------------------------------------------------------------------------
// Environment knobs
// ---------------------------------------------------------------------------

/// `ETUNER_DEBUG` gate, cached once per process (moved here from
/// `serve::engine` so every subsystem shares one check).
pub fn debug_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("ETUNER_DEBUG").is_ok())
}

/// `ETUNER_TRACE` gate: any value other than empty/`0` enables tracing on
/// the CLI even without `--trace` (mirrors `ETUNER_FAULTS`' env path).
pub fn env_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        matches!(std::env::var("ETUNER_TRACE"), Ok(v) if !v.is_empty() && v != "0")
    })
}

/// Startup/config diagnostics that predate any tracer instance (bad env
/// specs, backend selection).  One funnel instead of scattered eprintlns.
pub fn note(msg: fmt::Arguments<'_>) {
    eprintln!("{msg}");
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build Chrome trace-event JSON (the `{"traceEvents": [...]}` object
/// format) from a recorded event batch.  Timestamps are **virtual-time
/// microseconds** — Perfetto renders the simulated schedule, not wall
/// clock.  One metadata `thread_name` record per [`Lane`] gives each
/// subsystem its own track.
pub fn chrome_trace(events: &[Event]) -> Json {
    let mut evs: Vec<Json> = Vec::with_capacity(events.len() + 5);
    evs.push(obj(vec![
        ("ph", Json::Str("M".into())),
        ("name", Json::Str("process_name".into())),
        ("pid", Json::Num(1.0)),
        ("args", obj(vec![("name", Json::Str("etuner (virtual time)".into()))])),
    ]));
    for lane in Lane::ALL {
        evs.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(lane.tid() as f64)),
            ("args", obj(vec![("name", Json::Str(lane.name().into()))])),
        ]));
    }
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by(|a, b| {
        a.t0.partial_cmp(&b.t0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.lane.cmp(&b.lane))
    });
    for e in sorted {
        evs.push(event_json(e, e.lane.tid()));
    }
    obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// One Chrome trace-event record, with the caller choosing the `tid` (the
/// single-timeline exporter uses the lane's own tid; the fleet exporter
/// offsets by engine so each (engine, lane) pair gets its own track).
fn event_json(e: &Event, tid: u64) -> Json {
    let ts = e.t0 * 1e6;
    let mut args: Vec<(&str, Json)> = Vec::new();
    match e.kind {
        Kind::Counter => {
            // counter tracks carry their value under the series name
            let v = e.args().first().map(|&(_, v)| v).unwrap_or(0.0);
            args.push((e.name, Json::Num(v)));
        }
        _ => {
            for &(k, v) in e.args() {
                args.push((k, Json::Num(v)));
            }
        }
    }
    let mut fields = vec![
        ("name", Json::Str(e.name.into())),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("args", obj(args)),
    ];
    match e.kind {
        Kind::Span => {
            fields.push(("ph", Json::Str("X".into())));
            fields.push(("dur", Json::Num(e.dur * 1e6)));
        }
        Kind::Instant => {
            fields.push(("ph", Json::Str("i".into())));
            fields.push(("s", Json::Str("t".into())));
        }
        Kind::Counter => fields.push(("ph", Json::Str("C".into()))),
    }
    obj(fields)
}

/// Chrome trace `tid` for `lane` on fleet engine `engine`: engines are
/// blocks of 4 consecutive tids, so every (engine, lane) pair renders as
/// its own named track.
fn fleet_tid(engine: usize, lane: Lane) -> u64 {
    engine as u64 * Lane::ALL.len() as u64 + lane.tid()
}

/// Build Chrome trace-event JSON for a **fleet** run: one per-engine event
/// batch per serving engine (engine id = slice index, the order
/// [`crate::serve::FleetYield`] merges in).  Each (engine, lane) pair gets
/// its own `thread_name` track (`e0/serve-engine`, `e0/rounds`, …,
/// `e1/serve-engine`, …); events are sorted by virtual time, then engine,
/// then lane, so the export is independent of how the engine pool was
/// driven (sequential vs threaded).
pub fn chrome_trace_fleet(per_engine: &[Vec<Event>]) -> Json {
    let total: usize = per_engine.iter().map(|evs| evs.len()).sum();
    let mut evs: Vec<Json> =
        Vec::with_capacity(total + per_engine.len() * Lane::ALL.len() + 1);
    evs.push(obj(vec![
        ("ph", Json::Str("M".into())),
        ("name", Json::Str("process_name".into())),
        ("pid", Json::Num(1.0)),
        ("args", obj(vec![(
            "name",
            Json::Str("etuner fleet (virtual time)".into()),
        )])),
    ]));
    for engine in 0..per_engine.len() {
        for lane in Lane::ALL {
            evs.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(fleet_tid(engine, lane) as f64)),
                ("args", obj(vec![(
                    "name",
                    Json::Str(format!("e{engine}/{}", lane.name())),
                )])),
            ]));
        }
    }
    let mut sorted: Vec<(usize, &Event)> = per_engine
        .iter()
        .enumerate()
        .flat_map(|(k, batch)| batch.iter().map(move |e| (k, e)))
        .collect();
    sorted.sort_by(|(ka, a), (kb, b)| {
        a.t0.partial_cmp(&b.t0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(ka.cmp(kb))
            .then(a.lane.cmp(&b.lane))
    });
    for (engine, e) in sorted {
        evs.push(event_json(e, fleet_tid(engine, e.lane)));
    }
    obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Plain-text time-in-state table (`--trace-summary`): how the run's
/// virtual horizon split between serving executes, fine-tuning rounds, and
/// idle — the paper's Fig. 1 timeline as numbers — plus per-lane event
/// counts when a tracer was recording.
pub fn summary_table(report: &Report, tracer: &Tracer) -> String {
    let total = (report.time_serving_s
        + report.time_tuning_s
        + report.time_idle_s)
        .max(1e-12);
    let mut s = String::new();
    s.push_str("time-in-state (virtual seconds)\n");
    s.push_str(&format!("  {:<10} {:>12} {:>8}\n", "state", "time_s", "share"));
    for (name, v) in [
        ("serving", report.time_serving_s),
        ("tuning", report.time_tuning_s),
        ("idle", report.time_idle_s),
    ] {
        s.push_str(&format!(
            "  {:<10} {:>12.3} {:>7.1}%\n",
            name,
            v,
            100.0 * v / total
        ));
    }
    if tracer.on() {
        let mut spans: BTreeMap<Lane, (u64, u64, u64)> = BTreeMap::new();
        for e in tracer.events() {
            let c = spans.entry(e.lane).or_default();
            match e.kind {
                Kind::Span => c.0 += 1,
                Kind::Instant => c.1 += 1,
                Kind::Counter => c.2 += 1,
            }
        }
        s.push_str(&format!(
            "trace lanes ({} events dropped by ring)\n",
            tracer.dropped()
        ));
        s.push_str(&format!(
            "  {:<14} {:>8} {:>9} {:>9}\n",
            "lane", "spans", "instants", "counters"
        ));
        for lane in Lane::ALL {
            let (sp, i, c) = spans.get(&lane).copied().unwrap_or_default();
            s.push_str(&format!(
                "  {:<14} {:>8} {:>9} {:>9}\n",
                lane.name(),
                sp,
                i,
                c
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        t.set_now(5.0);
        t.begin(Lane::Engine, "flush", 1.0);
        t.end(Lane::Engine, 2.0, &[]);
        t.instant(Lane::Rounds, "trigger", 3.0, &[("backlog", 4.0)]);
        t.counter(Lane::Engine, "queue_depth", 3.0, 7.0);
        assert!(!t.on());
        assert!(t.events().is_empty());
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.now(), 0.0);
    }

    #[test]
    fn begin_end_pairs_into_spans() {
        let t = Tracer::enabled(64);
        t.begin(Lane::Rounds, "round", 10.0);
        t.begin(Lane::Rounds, "inner", 11.0);
        t.end(Lane::Rounds, 12.0, &[("x", 1.0)]);
        t.end(Lane::Rounds, 15.0, &[]);
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "inner");
        assert!((evs[0].dur - 1.0).abs() < 1e-12);
        assert_eq!(evs[0].args(), &[("x", 1.0)]);
        assert_eq!(evs[1].name, "round");
        assert!((evs[1].dur - 5.0).abs() < 1e-12);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let t = Tracer::enabled(16);
        for i in 0..20 {
            t.instant(Lane::Engine, "e", i as f64, &[]);
        }
        let evs = t.events();
        assert_eq!(evs.len(), 16);
        assert_eq!(t.dropped(), 4);
        // oldest surviving first
        assert!((evs[0].t0 - 4.0).abs() < 1e-12);
        assert!((evs[15].t0 - 19.0).abs() < 1e-12);
    }

    #[test]
    fn chrome_export_parses_and_names_lanes() {
        let t = Tracer::enabled(64);
        t.span(Lane::Engine, "execute", 1.0, 2.5, &[("scenario", 0.0)]);
        t.instant(Lane::Sweep, "cell_claim", 0.0, &[("cell", 0.0)]);
        t.counter(Lane::Engine, "queue_depth", 1.0, 3.0);
        let text = t.to_chrome_json().to_string();
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().arr().unwrap();
        // 1 process + 4 thread metadata + 3 events
        assert_eq!(evs.len(), 8);
        let span = evs
            .iter()
            .find(|e| {
                e.opt("ph").and_then(|p| p.str().ok()) == Some("X")
            })
            .unwrap();
        assert_eq!(span.get("name").unwrap().str().unwrap(), "execute");
        assert!((span.get("ts").unwrap().num().unwrap() - 1e6).abs() < 1e-6);
        assert!((span.get("dur").unwrap().num().unwrap() - 1.5e6).abs() < 1e-6);
    }

    #[test]
    fn fleet_export_gives_each_engine_lane_its_own_track() {
        let e0 = Tracer::enabled(16);
        e0.span(Lane::Engine, "execute", 1.0, 2.0, &[]);
        let e1 = Tracer::enabled(16);
        e1.span(Lane::Engine, "execute", 1.0, 2.0, &[]);
        e1.instant(Lane::Rounds, "round_trigger", 0.5, &[]);
        let text =
            chrome_trace_fleet(&[e0.take_events(), e1.take_events()])
                .to_string();
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().arr().unwrap();
        // 1 process + 2 engines x 4 lanes metadata + 3 events
        assert_eq!(evs.len(), 12);
        let tracks: Vec<String> = evs
            .iter()
            .filter(|e| {
                e.get("name").unwrap().str().unwrap() == "thread_name"
            })
            .map(|e| {
                e.get("args")
                    .unwrap()
                    .get("name")
                    .unwrap()
                    .str()
                    .unwrap()
                    .to_string()
            })
            .collect();
        assert_eq!(tracks.len(), 8);
        assert!(tracks.contains(&"e0/serve-engine".to_string()));
        assert!(tracks.contains(&"e1/rounds".to_string()));
        // same lane on different engines lands on different tids
        let exec_tids: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("name").unwrap().str().unwrap() == "execute")
            .map(|e| e.get("tid").unwrap().num().unwrap())
            .collect();
        assert_eq!(exec_tids.len(), 2);
        assert!((exec_tids[0] - 1.0).abs() < 1e-12, "e0 engine lane: tid 1");
        assert!((exec_tids[1] - 5.0).abs() < 1e-12, "e1 engine lane: tid 5");
    }

    #[test]
    fn take_and_absorb_move_events_between_tracers() {
        let worker = Tracer::enabled(32);
        worker.instant(Lane::Sweep, "cell_claim", 0.0, &[("worker", 1.0)]);
        worker.span(Lane::Sweep, "cell", 0.0, 9.0, &[("cell", 2.0)]);
        let batch = worker.take_events();
        assert_eq!(batch.len(), 2);
        assert!(worker.events().is_empty());
        let main = Tracer::enabled(32);
        main.absorb(&batch);
        assert_eq!(main.events().len(), 2);
    }

    #[test]
    fn summary_table_reports_time_in_state() {
        let r = Report {
            time_serving_s: 25.0,
            time_tuning_s: 50.0,
            time_idle_s: 25.0,
            ..Report::default()
        };
        let t = Tracer::enabled(8);
        t.span(Lane::Rounds, "round", 0.0, 50.0, &[]);
        let s = summary_table(&r, &t);
        assert!(s.contains("tuning"));
        assert!(s.contains("50.0%"));
        assert!(s.contains("rounds"));
    }
}
