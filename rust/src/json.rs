//! Minimal JSON substrate (parser + writer) for the artifact manifest and
//! result files.  No external crates are available offline, so this is a
//! small, strict, recursive-descent implementation covering the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
            }
            _ => bail!("not an object (want key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte utf-8: find the full char in the source.
                    let start = self.i - 1;
                    let rest = std::str::from_utf8(&self.b[start..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().arr().unwrap()[2]
                .get("b").unwrap().str().unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(
            Json::parse("\"\\u00e9x\"").unwrap(),
            Json::Str("éx".into())
        );
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn roundtrips_through_writer() {
        let src = r#"{"models":{"res50":{"d":128,"units":[{"o":0}]}},"s":"x\"y"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().arr().unwrap().len(), 2);
    }
}
