//! Inert stand-in for the `xla` crate when the `xla` cargo feature is off.
//!
//! Mirrors exactly the API surface [`super::client::PjrtBackend`] uses so
//! the whole crate (coordinator, policies, simulator data structures, CLI)
//! compiles and unit-tests on machines without the XLA toolchain.  The
//! literal type is the shared [`HostLiteral`](crate::runtime::hostlit) —
//! fully functional, including tuple literals — so the marshalling layer
//! and its caches are exercised for real; anything that would need an
//! actual PJRT client fails with a clear error at runtime (machines
//! without the toolchain run models through
//! [`crate::runtime::RefCpuBackend`] instead).

use std::path::Path;

pub use super::hostlit::{ArrayShape, Error, NativeType};

/// The stub's literal IS the host literal (tuple support included).
pub type Literal = super::hostlit::HostLiteral;

const NO_XLA: &str = "etuner was built without the `xla` feature; \
                      rebuild with `--features xla` for PJRT execution \
                      or select the refcpu backend";

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error::new(NO_XLA))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::new(NO_XLA))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::new(NO_XLA))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::new(NO_XLA))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::new(NO_XLA))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_shape_and_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn stub_literals_carry_real_tuples() {
        // the old stub returned Err(NO_XLA) here; multi-output segments
        // now have a working host representation.
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32, 2.0]),
            Literal::vec1(&[3.0f32]),
        ]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[1].to_vec::<f32>().unwrap(), vec![3.0]);
    }

    #[test]
    fn execution_paths_error_without_xla() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
