//! Inert stand-in for the `xla` crate when the `xla` cargo feature is off.
//!
//! Mirrors exactly the API surface the runtime layer uses so the whole
//! crate (coordinator, policies, simulator data structures, CLI) compiles
//! and unit-tests on machines without the XLA toolchain.  Host-side
//! literals are *functional* (shape + data round-trips work, so the
//! marshalling layer and its caches can be exercised); anything that would
//! need a real PJRT client fails with a clear error at runtime.

use std::path::Path;

/// Error type standing in for `xla::Error`; only `Debug` is needed by the
/// `map_err(|e| anyhow!("..: {e:?}"))` call sites.
#[derive(Debug)]
pub struct Error(pub &'static str);

const NO_XLA: &str = "etuner was built without the `xla` feature; \
                      rebuild with `--features xla` to execute artifacts";

/// Element types a stub literal can hold.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Conversion glue so `Literal::vec1` / `Literal::to_vec` stay generic like
/// the real crate's.
pub trait NativeType: Sized + Copy {
    fn wrap(data: &[Self]) -> Data;
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Data {
        Data::F32(data.to_vec())
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32")),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Data {
        Data::I32(data.to_vec())
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error> {
        match data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32")),
        }
    }
}

/// Host literal: shape + typed data (enough for marshal/unmarshal tests).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Shape view matching `xla::ArrayShape`'s `dims()` accessor.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error("reshape: element count mismatch"));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error(NO_XLA))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error(NO_XLA))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(NO_XLA))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(NO_XLA))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(NO_XLA))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(NO_XLA))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_shape_and_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn execution_paths_error_without_xla() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
