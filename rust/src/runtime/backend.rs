//! The execute-boundary abstraction: an object-safe [`Backend`] trait with
//! two runtime-selectable implementations.
//!
//! * [`PjrtBackend`](super::client::PjrtBackend) — compiles the AOT HLO
//!   artifacts and executes them through the PJRT C API (real execution
//!   needs the `xla` cargo feature; without it construction fails with a
//!   clear error).
//! * [`RefCpuBackend`](super::refcpu::RefCpuBackend) — a pure-Rust
//!   reference executor implementing the artifact segments' actual
//!   semantics (forward pass, SGD train step, SimSiam step, CKA probe)
//!   for the linear/CWR-head model family, on the same flat-θ layout the
//!   manifest describes.  Runs everywhere, bit-deterministically — CI
//!   executes full simulations with it.
//!
//! Everything above `runtime/` (model/, sim/, serve/) depends only on this
//! trait; no `cfg(feature = "xla")` branching escapes the runtime layer.
//!
//! # Buffer ownership (adopt/donate)
//!
//! [`Value`] is a backend-owned buffer handle.  Callers *adopt* output
//! values (e.g. [`crate::model::ModelSession`] keeps a train step's output
//! θ value as the next step's input) and *donate* them back by reference
//! through [`Backend::execute`] — the backend never requires a host
//! round-trip between consecutive executes.  This is what lets θ become
//! device-resident later: a `Value` may wrap a device buffer without any
//! caller changing.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use super::artifact::Manifest;
use super::exec::TensorF32;
use super::hostlit::HostLiteral;

static NEXT_BUF_ID: AtomicU64 = AtomicU64::new(1);

/// A backend-owned buffer handle crossing the execute boundary.
///
/// Every value carries a process-unique `buf_id` assigned at construction.
/// Because [`crate::model::ModelSession`] keeps θ values alive per
/// `(Params::id, Params::generation)` and adopts train-step *output*
/// values, a buf id is a stable proxy for "this exact θ content": any
/// generation bump produces a new value and therefore a new id.  The
/// reference executor keys its packed-weight cache on it, so packs
/// invalidate exactly when the session's θ-literal cache does.
pub struct Value {
    repr: Repr,
    id: u64,
}

enum Repr {
    /// Host literal (reference executor, and the PJRT path built without
    /// the `xla` feature, where the stub literal is the host literal).
    Host(HostLiteral),
    /// Real PJRT literal (only with the `xla` feature).
    #[cfg(feature = "xla")]
    Xla(xla::Literal),
}

impl Value {
    /// Wrap a host literal (fresh buf id).
    pub fn host(lit: HostLiteral) -> Value {
        Value {
            repr: Repr::Host(lit),
            id: NEXT_BUF_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Wrap a PJRT literal (fresh buf id).
    #[cfg(feature = "xla")]
    pub fn xla(lit: xla::Literal) -> Value {
        Value {
            repr: Repr::Xla(lit),
            id: NEXT_BUF_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Process-unique buffer id (never reused; see the type docs).
    pub fn buf_id(&self) -> u64 {
        self.id
    }

    /// Borrow the host literal; errors for device-side values.
    pub fn as_host(&self) -> Result<&HostLiteral> {
        match &self.repr {
            Repr::Host(l) => Ok(l),
            #[cfg(feature = "xla")]
            Repr::Xla(_) => Err(anyhow::anyhow!(
                "value is a PJRT literal, not a host literal"
            )),
        }
    }

    /// Borrow the PJRT literal; errors for host values.
    #[cfg(feature = "xla")]
    pub fn as_xla(&self) -> Result<&xla::Literal> {
        match &self.repr {
            Repr::Xla(l) => Ok(l),
            Repr::Host(_) => Err(anyhow::anyhow!(
                "value is a host literal, not a PJRT literal"
            )),
        }
    }

    /// Read back as a host tensor (shape + f32 data).
    pub fn to_tensor(&self) -> Result<TensorF32> {
        match &self.repr {
            Repr::Host(l) => {
                let shape = l
                    .shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
                let data = l
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                Ok(TensorF32::new(shape, data))
            }
            #[cfg(feature = "xla")]
            Repr::Xla(l) => {
                let shape = l
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                let data = l
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                Ok(TensorF32::new(dims, data))
            }
        }
    }

    /// Read back the raw f32 data (no shape; the flat-θ fast path).
    pub fn read_f32(&self) -> Result<Vec<f32>> {
        match &self.repr {
            Repr::Host(l) => l
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec: {e:?}")),
            #[cfg(feature = "xla")]
            Repr::Xla(l) => l
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec: {e:?}")),
        }
    }
}

/// Backend-internal performance counters (execution-core plumbing, *not*
/// scientific output — excluded from [`crate::metrics::Report::fingerprint`]
/// like the session's marshal counters).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendPerf {
    /// Weight panels packed (per layer × direction × quantization).
    pub gemm_packs: u64,
    /// GEMM calls that reused an already-packed panel.
    pub gemm_pack_hits: u64,
    /// Scratch buffers allocated fresh (arena misses).
    pub scratch_allocs: u64,
    /// Scratch buffers served from the arena free list.
    pub scratch_reuses: u64,
    /// Bytes handed out from recycled scratch buffers.
    pub scratch_bytes_reused: u64,
}

/// Fault-injection counters reported by a fault-wrapping backend (see
/// [`crate::runtime::faults::FaultyBackend`]).  Plain backends report
/// zeros.  Excluded from [`crate::metrics::Report::fingerprint`] like
/// [`BackendPerf`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// Execute-segment errors injected.
    pub exec_faults: u64,
    /// Marshal errors injected.
    pub marshal_faults: u64,
    /// Virtual-time latency spikes injected.
    pub latency_spikes: u64,
    /// Total virtual seconds of injected spike latency.
    pub spike_s_total: f64,
}

/// Object-safe execute boundary: load/marshal/execute/read-back.
///
/// A backend binds an artifact *source* (directory or built-in) and
/// executes named segments on [`Value`] buffers.  All methods take `&self`
/// — backends use interior mutability for caches/counters and are driven
/// from a single thread each ([`crate::sim::ParallelSweeper`] constructs
/// one backend per worker).
pub trait Backend {
    /// Short identifier (`"pjrt"` / `"refcpu"`) for logs and reports.
    fn name(&self) -> &'static str;

    /// The manifest describing models, flat-θ layout, and segment names.
    fn manifest(&self) -> &Manifest;

    /// Number of segment executions so far (metrics/tests).
    fn executions(&self) -> u64;

    /// Marshal host f32 data into a backend buffer (`[]` = rank-0 scalar).
    fn marshal_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value>;

    /// Marshal host i32 data (labels input of the train segments).
    fn marshal_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value>;

    /// Execute a named segment; returns the flattened output tuple.
    /// Inputs are donated by reference — the caller keeps ownership and
    /// no buffer is rebuilt for the call.
    fn execute(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>>;

    /// Initial (pre-deployment) parameters for a model.
    fn theta0(&self, model: &str) -> Result<Vec<f32>>;

    /// Initial SimSiam projector/predictor parameters.
    fn phi0(&self, model: &str) -> Result<Vec<f32>>;

    /// Execution-core counters (packed-weight cache, scratch arena).
    /// Backends without those caches report zeros.
    fn perf(&self) -> BackendPerf {
        BackendPerf::default()
    }

    /// Pre-build any per-θ derived state (packed weight panels) for the
    /// given segment, so the *next* `execute` on this θ value pays no
    /// preparation cost.  The serving engine calls this when it installs
    /// a fresh CWR-bank θ, moving pack work off the request path.
    ///
    /// **Multi-θ contract:** warm state is keyed per `Value::buf_id`, and
    /// callers may hold *many* values warm simultaneously — the serving
    /// engine's `BankSet` keeps one bank-installed serving θ resident per
    /// active scenario beside the live training θ.  Warming one value
    /// must never invalidate another's state; each stays warm until its
    /// own `release` (or the backend's internal cap evicts it).
    fn warm(&self, _segment: &str, _theta: &Value) -> Result<()> {
        Ok(())
    }

    /// Fault-injection counters.  Only the fault-wrapping decorator
    /// ([`crate::runtime::faults::FaultyBackend`]) reports nonzero values;
    /// plain backends use this default.
    fn fault_stats(&self) -> FaultStats {
        FaultStats::default()
    }

    /// Drain injected virtual-time latency accumulated since the last
    /// drain (seconds).  The serving engine adds this to the service time
    /// it charges through `DeviceModel` — spikes cost *virtual* time,
    /// never wall clock.  Plain backends always return 0.
    fn take_injected_delay_s(&self) -> f64 {
        0.0
    }

    /// Serialize fault-injection state (RNG position, burst counters,
    /// undrained spike delay) for the checkpoint subsystem.  Only the
    /// fault-wrapping decorator returns `Some`; plain backends have no
    /// fault state and use this default.  `&self` + interior mutability,
    /// like `fault_stats`/`take_injected_delay_s`.
    fn fault_state_save(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore fault-injection state saved by [`Backend::fault_state_save`]
    /// — the decorator resumes its injection stream bit-identically.
    /// No-op on plain backends.
    fn fault_state_load(&self, _bytes: &[u8]) {}

    /// A value previously produced by this backend is being dropped by a
    /// caller-side cache; derived state keyed on its buf id can be freed.
    /// ([`crate::model::ModelSession`] calls this whenever its
    /// generation-keyed θ cache evicts or replaces an entry, and — via
    /// `ModelSession::release_params` — when the serving engine's
    /// `BankSet` LRU-evicts a scenario's resident bank.)  Buf ids are
    /// process-unique and never reused, so releasing one warmed θ leaves
    /// every other resident bank's state intact.
    fn release(&self, _buf_id: u64) {}
}

/// Which backend to construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT over the AOT artifacts (needs `make artifacts`; real execution
    /// needs the `xla` cargo feature).
    Pjrt,
    /// Pure-Rust reference executor (runs everywhere).
    RefCpu,
    /// Prefer PJRT when it can actually execute here, else refcpu.
    Auto,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "pjrt" | "xla" => BackendKind::Pjrt,
            "refcpu" | "ref" | "cpu" => BackendKind::RefCpu,
            "auto" => BackendKind::Auto,
            other => anyhow::bail!(
                "unknown backend {other:?} (expected pjrt|refcpu|auto)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::RefCpu => "refcpu",
            BackendKind::Auto => "auto",
        }
    }
}

/// Recipe for constructing a backend: kind + artifact directory.
///
/// Cheap, `Sync`, and cloneable — the sweep engine hands one to every
/// worker thread so each constructs its own backend (backends themselves
/// are deliberately single-threaded).
#[derive(Clone, Debug)]
pub struct BackendSpec {
    pub kind: BackendKind,
    pub dir: PathBuf,
}

impl BackendSpec {
    pub fn new<P: AsRef<Path>>(kind: BackendKind, dir: P) -> BackendSpec {
        BackendSpec { kind, dir: dir.as_ref().to_path_buf() }
    }

    /// Auto-selecting spec over an artifact directory.
    pub fn auto<P: AsRef<Path>>(dir: P) -> BackendSpec {
        BackendSpec::new(BackendKind::Auto, dir)
    }

    /// Reference-executor spec (uses the directory's manifest/θ0 when
    /// present, the built-in model family otherwise).
    pub fn refcpu<P: AsRef<Path>>(dir: P) -> BackendSpec {
        BackendSpec::new(BackendKind::RefCpu, dir)
    }

    /// Construct the backend this spec describes.
    pub fn create(&self) -> Result<Box<dyn Backend>> {
        match self.kind {
            BackendKind::Pjrt => Ok(Box::new(
                super::client::PjrtBackend::load(&self.dir)?,
            )),
            BackendKind::RefCpu => Ok(Box::new(
                super::refcpu::RefCpuBackend::load(&self.dir)?,
            )),
            BackendKind::Auto => {
                // PJRT wins when it can actually execute here: the
                // artifacts exist AND the PJRT client comes up.  The only
                // *silent* fallback is the expected no-`xla`-feature stub
                // refusal; artifacts that are present but unloadable for a
                // real reason (broken XLA install, corrupt artifacts) must
                // surface the error, not quietly degrade to fp-divergent
                // refcpu numbers.
                if self.dir.join("manifest.json").exists() {
                    match super::client::PjrtBackend::load(&self.dir) {
                        Ok(be) => return Ok(Box::new(be)),
                        Err(e)
                            if format!("{e:?}")
                                .contains("without the `xla` feature") => {}
                        Err(e) => {
                            return Err(e.context(
                                "artifacts present but the pjrt backend \
                                 failed to load (force the reference \
                                 executor with --backend refcpu)",
                            ))
                        }
                    }
                }
                Ok(Box::new(super::refcpu::RefCpuBackend::load(&self.dir)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_rejects() {
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("refcpu").unwrap(), BackendKind::RefCpu);
        assert_eq!(BackendKind::parse("AUTO").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn host_value_reads_back() {
        let v = Value::host(HostLiteral::f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap());
        let t = v.to_tensor().unwrap();
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(v.read_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(v.as_host().is_ok());
    }

    #[test]
    fn buf_ids_are_process_unique() {
        let a = Value::host(HostLiteral::f32(&[1.0], &[1]).unwrap());
        let b = Value::host(HostLiteral::f32(&[1.0], &[1]).unwrap());
        assert_ne!(a.buf_id(), b.buf_id(), "identical content, distinct ids");
        assert_ne!(a.buf_id(), 0, "0 is reserved as 'no buffer'");
    }

    #[test]
    fn auto_spec_falls_back_to_refcpu_without_artifacts() {
        let spec = BackendSpec::auto("/nonexistent/artifacts");
        let be = spec.create().unwrap();
        assert_eq!(be.name(), "refcpu");
        assert!(be.manifest().model("mbv2").is_ok());
    }
}
