//! The PJRT backend: compile-on-demand executable cache over the artifact
//! directory.  One compiled executable per artifact, reused for the whole
//! process lifetime (the paper's per-round "system initialization" cost is
//! *charged* by the cost model, not re-paid for real — see
//! [`crate::cost::device`]).
//!
//! This file is the only place that touches the `xla` crate (or, without
//! the `xla` cargo feature, its API-identical inert stand-in
//! [`super::stub`]); everything above sees only the [`Backend`] trait.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::backend::{Backend, Value};

#[cfg(not(feature = "xla"))]
use crate::runtime::stub as xla;

/// Wrap a PJRT-path literal as a [`Value`].  With the `xla` feature this
/// is a real PJRT literal; without it the stub literal *is* the host
/// literal, so the two variants coincide.
#[cfg(feature = "xla")]
fn wrap(lit: xla::Literal) -> Value {
    Value::xla(lit)
}

#[cfg(not(feature = "xla"))]
fn wrap(lit: xla::Literal) -> Value {
    Value::host(lit)
}

#[cfg(feature = "xla")]
fn unwrap(v: &Value) -> Result<&xla::Literal> {
    v.as_xla().map_err(|_| {
        anyhow::anyhow!("pjrt backend received a host value from another backend")
    })
}

#[cfg(not(feature = "xla"))]
fn unwrap(v: &Value) -> Result<&xla::Literal> {
    v.as_host()
}

/// PJRT execution backend: CPU client + manifest + executable cache.
///
/// Not `Sync`: PJRT executables are cached behind a `RefCell`.  Run one
/// backend per thread (the simulator is single-threaded per run;
/// [`crate::sim::ParallelSweeper`] parallelizes across runs by constructing
/// one backend per worker thread).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    exec_count: Cell<u64>,
}

impl PjrtBackend {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<PjrtBackend> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtBackend {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_count: Cell::new(0),
        })
    }

    /// Fetch (compiling on first use) the executable for an artifact name.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute with borrowed literals; returns the output tuple's element
    /// literals (aot.py lowers with `return_tuple=True`).
    fn exec_lits(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        self.exec_count.set(self.exec_count.get() + 1);
        let out = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// Read a raw little-endian f32 binary (the `<model>_theta0.bin`
    /// initial parameters written by aot.py).
    pub fn load_f32_bin(&self, file: &str) -> Result<Vec<f32>> {
        read_f32_bin(&self.dir, file)
    }
}

/// Read `<dir>/<file>` as raw little-endian f32 (shared with the refcpu
/// backend, which loads the same θ0 binaries for artifact parity).
pub(crate) fn read_f32_bin(dir: &Path, file: &str) -> Result<Vec<f32>> {
    let path = dir.join(file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{file}: not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executions(&self) -> u64 {
        self.exec_count.get()
    }

    fn marshal_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        let lit = xla::Literal::vec1(data);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = lit
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))?;
        Ok(wrap(lit))
    }

    fn marshal_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        let lit = xla::Literal::vec1(data);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = lit
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape i32 {shape:?}: {e:?}"))?;
        Ok(wrap(lit))
    }

    fn execute(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let lits: Vec<&xla::Literal> =
            inputs.iter().map(|v| unwrap(v)).collect::<Result<_>>()?;
        Ok(self.exec_lits(name, &lits)?.into_iter().map(wrap).collect())
    }

    fn theta0(&self, model: &str) -> Result<Vec<f32>> {
        self.load_f32_bin(&format!("{model}_theta0.bin"))
    }

    fn phi0(&self, model: &str) -> Result<Vec<f32>> {
        self.load_f32_bin(&format!("{model}_phi0.bin"))
    }
}
