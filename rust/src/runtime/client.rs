//! The PJRT client wrapper: compile-on-demand executable cache over the
//! artifact directory.  One compiled executable per artifact, reused for
//! the whole process lifetime (the paper's per-round "system initialization"
//! cost is *charged* by the cost model, not re-paid for real — see
//! [`crate::cost::device`]).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::exec::TensorF32;

#[cfg(not(feature = "xla"))]
use crate::runtime::stub as xla;

/// Loaded runtime: PJRT CPU client + manifest + executable cache.
///
/// Not `Sync`: PJRT executables are cached behind a `RefCell`.  Run one
/// `Runtime` per thread (the simulator is single-threaded per run;
/// [`crate::sim::ParallelSweeper`] parallelizes across runs by constructing
/// one runtime per worker thread).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    exec_count: RefCell<u64>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Number of artifact executions so far (metrics/tests).
    pub fn executions(&self) -> u64 {
        *self.exec_count.borrow()
    }

    /// Fetch (compiling on first use) the executable for an artifact name.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 host tensors; returns the flattened
    /// output tuple as host tensors.  Integer inputs go through
    /// [`Self::exec_raw`].
    pub fn exec(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(TensorF32::to_literal).collect::<Result<_>>()?;
        self.exec_raw(name, &lits)
    }

    /// Execute with pre-built literals (callers with i32 inputs or reused
    /// buffers).  Output tuple is decomposed into individual tensors.
    pub fn exec_raw(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<TensorF32>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.exec_refs(name, &refs)
    }

    /// Execute with borrowed literals — the zero-copy entry: callers keep
    /// ownership of cached literals (e.g. the session's θ literal) and no
    /// literal is rebuilt or cloned for the call.
    pub fn exec_refs(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<TensorF32>> {
        self.exec_lits(name, inputs)?
            .into_iter()
            .map(TensorF32::from_literal)
            .collect()
    }

    /// Like [`Self::exec_refs`] but returns the raw output literals, so a
    /// caller can keep one (e.g. the updated θ of a train step) as the next
    /// call's input without a host round-trip re-marshal.
    pub fn exec_lits(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        *self.exec_count.borrow_mut() += 1;
        let out = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// Read a raw little-endian f32 binary (the `<model>_theta0.bin`
    /// initial parameters written by aot.py).
    pub fn load_f32_bin(&self, file: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "{file}: not a multiple of 4 bytes");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Initial parameters for a model.
    pub fn theta0(&self, model: &str) -> Result<Vec<f32>> {
        self.load_f32_bin(&format!("{model}_theta0.bin"))
    }

    /// Initial SimSiam projector/predictor parameters.
    pub fn phi0(&self, model: &str) -> Result<Vec<f32>> {
        self.load_f32_bin(&format!("{model}_phi0.bin"))
    }
}
