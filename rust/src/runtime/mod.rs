//! Runtime layer: the [`Backend`] execute boundary and its two
//! implementations.  Everything above this module deals in `Vec<f32>`,
//! [`TensorF32`], and opaque [`Value`] buffer handles — no XLA types, no
//! `cfg(feature = "xla")` branching, escape upward.
//!
//! * [`PjrtBackend`] loads the AOT artifacts emitted by `make artifacts`
//!   (HLO **text** interchange — jax ≥ 0.5 protos carry 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects, while the text parser reassigns
//!   ids and round-trips cleanly; see DESIGN.md / aot.py) and executes
//!   them through the PJRT C API.  Real execution needs the `xla` cargo
//!   feature; without it the API-identical [`stub`] makes everything
//!   compile and constructing the backend fails with a clear error.
//! * [`RefCpuBackend`] is a pure-Rust reference executor implementing the
//!   segments' actual semantics (forward, SGD train step, SimSiam step,
//!   CKA) on the manifest's flat-θ layout.  It runs *everywhere* — CI
//!   executes full end-to-end simulations with it ([`refcpu::builtin`]
//!   synthesizes the model family when no artifact directory exists), and
//!   its runs are bit-deterministic across sweep worker counts.
//!
//! Select at runtime with [`BackendSpec`] (`--backend {pjrt,refcpu,auto}`
//! on the CLI; `auto` prefers PJRT when it can actually execute here and
//! falls back to refcpu).

pub mod artifact;
pub mod backend;
pub mod client;
pub mod exec;
pub mod faults;
pub mod hostlit;
pub mod refcpu;
#[cfg(not(feature = "xla"))]
pub mod stub;
pub mod tracing;

pub use artifact::{Manifest, ModelManifest, Segment, TensorInfo};
pub use backend::{
    Backend, BackendKind, BackendPerf, BackendSpec, FaultStats, Value,
};
pub use faults::{FaultPlan, FaultyBackend};
pub use tracing::TracingBackend;
pub use client::PjrtBackend;
pub use exec::TensorF32;
pub use hostlit::HostLiteral;
pub use refcpu::RefCpuBackend;
