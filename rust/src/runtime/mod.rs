//! PJRT runtime: loads the AOT artifacts emitted by `make artifacts` and
//! executes them on the request path.  This is the only module that talks
//! to XLA; everything above it deals in `Vec<f32>`.
//!
//! Interchange is **HLO text** (see DESIGN.md / aot.py): jax ≥ 0.5 protos
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids and round-trips cleanly.
//!
//! Builds without the `xla` cargo feature swap the real bindings for
//! [`stub`], an API-identical inert backend: literals still marshal on the
//! host (so the zero-copy caches are testable), but artifact execution
//! reports a clear error.

pub mod artifact;
pub mod client;
pub mod exec;
#[cfg(not(feature = "xla"))]
pub mod stub;

pub use artifact::{Manifest, ModelManifest, Segment, TensorInfo};
pub use client::Runtime;
pub use exec::TensorF32;
