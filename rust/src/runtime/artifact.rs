//! Artifact manifest: everything the coordinator needs to know about the
//! AOT-compiled programs — names, flat-θ layout, freeze-unit segments, and
//! the paper-scale per-unit cost anchors used by [`crate::cost`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json::Json;

/// Contiguous slice of the flat parameter vector owned by one freeze unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    pub offset: usize,
    pub len: usize,
}

/// One named tensor inside the flat θ vector.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub unit: usize,
    pub offset: usize,
}

impl TensorInfo {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Classifier-head location (CWR does per-class row surgery here).
#[derive(Clone, Debug)]
pub struct HeadInfo {
    pub w_offset: usize,
    pub w_shape: [usize; 2], // (H, C) row-major
    pub b_offset: usize,
    pub classes: usize,
}

/// Paper-scale cost anchors for one freeze unit (per-image forward FLOPs
/// and parameter bytes of the corresponding slice of the *real* model).
#[derive(Clone, Copy, Debug)]
pub struct PaperUnit {
    pub fwd_flops: f64,
    pub param_bytes: f64,
}

/// Artifact names for one model.
#[derive(Clone, Debug, Default)]
pub struct ArtifactNames {
    pub infer: String,
    pub features: String,
    pub train: Vec<String>,   // index = prefix-frozen unit count k
    pub train_q: Vec<String>, // 8-bit QAT variants (may be empty)
    pub ssl: Option<String>,
    pub ssl_phi_len: usize,
}

/// Everything the coordinator needs about one deployed model.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub d: usize,
    pub h: usize,
    pub blocks: usize,
    pub classes: usize,
    pub units: usize,
    pub kind: String,
    pub theta_len: usize,
    pub batch_train: usize,
    pub batch_infer: usize,
    pub batch_probe: usize,
    pub unit_segments: Vec<Segment>,
    pub tensors: Vec<TensorInfo>,
    pub head: HeadInfo,
    pub paper_units: Vec<PaperUnit>,
    pub artifacts: ArtifactNames,
}

impl ModelManifest {
    /// Artifact implementing a train step with `k` prefix-frozen units.
    pub fn train_artifact(&self, k: usize, quant: bool) -> Result<&str> {
        let list = if quant { &self.artifacts.train_q } else { &self.artifacts.train };
        list.get(k)
            .map(|s| s.as_str())
            .with_context(|| format!("{}: no train artifact k={k} quant={quant}", self.name))
    }

    /// Total paper-scale forward FLOPs per image.
    pub fn paper_fwd_flops(&self) -> f64 {
        self.paper_units.iter().map(|u| u.fwd_flops).sum()
    }

    /// Total paper-scale parameter bytes.
    pub fn paper_param_bytes(&self) -> f64 {
        self.paper_units.iter().map(|u| u.param_bytes).sum()
    }
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
    /// feature-width -> cka artifact name
    pub cka: BTreeMap<usize, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models")?.obj()? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let mut cka = BTreeMap::new();
        for (w, n) in v.get("cka")?.obj()? {
            cka.insert(w.parse::<usize>()?, n.str()?.to_string());
        }
        Ok(Manifest { models, cka })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model {name:?}"))
    }

    pub fn cka_artifact(&self, width: usize) -> Result<&str> {
        self.cka
            .get(&width)
            .map(|s| s.as_str())
            .with_context(|| format!("no cka artifact for width {width}"))
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelManifest> {
    let arts = m.get("artifacts")?;
    let train = arts
        .get("train")?
        .arr()?
        .iter()
        .map(|a| Ok(a.str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    let train_q = match arts.opt("train_q") {
        Some(a) => a
            .arr()?
            .iter()
            .map(|x| Ok(x.str()?.to_string()))
            .collect::<Result<Vec<_>>>()?,
        None => vec![],
    };
    let head = m.get("head")?;
    let hw = head.get("w_shape")?.arr()?;
    Ok(ModelManifest {
        name: name.to_string(),
        d: m.get("d")?.usize()?,
        h: m.get("h")?.usize()?,
        blocks: m.get("blocks")?.usize()?,
        classes: m.get("classes")?.usize()?,
        units: m.get("units")?.usize()?,
        kind: m.get("kind")?.str()?.to_string(),
        theta_len: m.get("theta_len")?.usize()?,
        batch_train: m.get("batch_train")?.usize()?,
        batch_infer: m.get("batch_infer")?.usize()?,
        batch_probe: m.get("batch_probe")?.usize()?,
        unit_segments: m
            .get("unit_segments")?
            .arr()?
            .iter()
            .map(|s| {
                Ok(Segment {
                    offset: s.get("offset")?.usize()?,
                    len: s.get("len")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        tensors: m
            .get("tensors")?
            .arr()?
            .iter()
            .map(|t| {
                Ok(TensorInfo {
                    name: t.get("name")?.str()?.to_string(),
                    shape: t
                        .get("shape")?
                        .arr()?
                        .iter()
                        .map(|d| d.usize())
                        .collect::<Result<Vec<_>>>()?,
                    unit: t.get("unit")?.usize()?,
                    offset: t.get("offset")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        head: HeadInfo {
            w_offset: head.get("w_offset")?.usize()?,
            w_shape: [hw[0].usize()?, hw[1].usize()?],
            b_offset: head.get("b_offset")?.usize()?,
            classes: hw[1].usize()?,
        },
        paper_units: m
            .get("paper_units")?
            .arr()?
            .iter()
            .map(|u| {
                Ok(PaperUnit {
                    fwd_flops: u.get("fwd_flops")?.num()?,
                    param_bytes: u.get("param_bytes")?.num()?,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        artifacts: ArtifactNames {
            infer: arts.get("infer")?.str()?.to_string(),
            features: arts.get("features")?.str()?.to_string(),
            train,
            train_q,
            ssl: arts.opt("ssl").map(|s| s.str().map(str::to_string)).transpose()?,
            ssl_phi_len: arts.opt("ssl_phi_len").map(|v| v.usize()).transpose()?.unwrap_or(0),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1,
      "models": {
        "toy": {
          "d": 8, "h": 4, "blocks": 2, "classes": 3, "kind": "relu_res",
          "units": 4, "theta_len": 100,
          "batch_train": 16, "batch_infer": 64, "batch_probe": 16,
          "unit_segments": [{"offset":0,"len":36},{"offset":36,"len":20},
                            {"offset":56,"len":20},{"offset":76,"len":24}],
          "tensors": [{"name":"embed.w","shape":[8,4],"unit":0,"offset":0}],
          "head": {"w_offset":76,"w_shape":[4,3],"b_offset":88,"b_shape":[3]},
          "paper_units": [{"fwd_flops":1e9,"param_bytes":1e6},
                          {"fwd_flops":2e9,"param_bytes":2e6},
                          {"fwd_flops":2e9,"param_bytes":2e6},
                          {"fwd_flops":1e8,"param_bytes":1e5}],
          "artifacts": {"infer":"toy_infer","features":"toy_features",
                        "train":["toy_train_0","toy_train_1"],
                        "train_q":[]}
        }
      },
      "cka": {"4": "cka_4"}
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.units, 4);
        assert_eq!(toy.unit_segments.len(), 4);
        assert_eq!(toy.train_artifact(1, false).unwrap(), "toy_train_1");
        assert!(toy.train_artifact(5, false).is_err());
        assert!(toy.train_artifact(0, true).is_err());
        assert_eq!(m.cka_artifact(4).unwrap(), "cka_4");
        assert!(m.cka_artifact(9).is_err());
        assert!((toy.paper_fwd_flops() - 5.1e9).abs() < 1.0);
    }

    #[test]
    fn unknown_model_is_error() {
        let m = Manifest::parse(MINI).unwrap();
        assert!(m.model("nope").is_err());
    }
}
