//! Host-side tensor type.
//!
//! Everything above the runtime deals in `TensorF32` (shape + contiguous
//! row-major data).  Conversions to backend buffers happen only at the
//! execute boundary, through [`crate::runtime::Backend::marshal_f32`] and
//! [`crate::runtime::Value::to_tensor`] — this module has no backend
//! dependency at all.

/// A host f32 tensor: row-major contiguous.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorF32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        TensorF32 { shape: vec![], data: vec![v] }
    }

    pub fn vec(data: Vec<f32>) -> Self {
        TensorF32 { shape: vec![data.len()], data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// argmax over the last axis of a rank-2 tensor, per row.
    pub fn argmax_rows(&self) -> Vec<usize> {
        debug_assert_eq!(self.shape.len(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                let mut best = 0;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    /// log-sum-exp per row (rank-2) — the energy-score OOD statistic is
    /// `E(x) = -logsumexp(logits)` (paper §IV-A3, citing [56]).
    pub fn logsumexp_rows(&self) -> Vec<f32> {
        debug_assert_eq!(self.shape.len(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let s: f32 = row.iter().map(|&v| (v - m).exp()).sum();
                m + s.ln()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_picks_max_per_row() {
        let t = TensorF32::new(vec![2, 3], vec![0.1, 0.9, 0.2, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn logsumexp_matches_naive() {
        let t = TensorF32::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let naive = (1f32.exp() + 2f32.exp() + 3f32.exp() + 4f32.exp()).ln();
        assert!((t.logsumexp_rows()[0] - naive).abs() < 1e-5);
    }

    #[test]
    fn logsumexp_stable_for_large_values() {
        let t = TensorF32::new(vec![1, 2], vec![1000.0, 1000.0]);
        let v = t.logsumexp_rows()[0];
        assert!((v - (1000.0 + 2f32.ln())).abs() < 1e-3);
        assert!(v.is_finite());
    }

    #[test]
    fn row_view_is_correct_slice() {
        let t = TensorF32::new(vec![3, 2], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.row(1), &[2.0, 3.0]);
    }
}
