//! Host-side tensor type and literal conversion helpers.
//!
//! Everything above the runtime deals in `TensorF32` (shape + contiguous
//! row-major data).  Conversions to/from `xla::Literal` happen only at the
//! execute boundary.

use anyhow::Result;

#[cfg(not(feature = "xla"))]
use crate::runtime::stub as xla;

/// A host f32 tensor: row-major contiguous.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorF32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        TensorF32 { shape: vec![], data: vec![v] }
    }

    pub fn vec(data: Vec<f32>) -> Self {
        TensorF32 { shape: vec![data.len()], data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        f32_literal(&self.data, &self.shape)
    }

    pub fn from_literal(lit: xla::Literal) -> Result<TensorF32> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        Ok(TensorF32::new(dims, data))
    }

    /// argmax over the last axis of a rank-2 tensor, per row.
    pub fn argmax_rows(&self) -> Vec<usize> {
        debug_assert_eq!(self.shape.len(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                let mut best = 0;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    /// log-sum-exp per row (rank-2) — the energy-score OOD statistic is
    /// `E(x) = -logsumexp(logits)` (paper §IV-A3, citing [56]).
    pub fn logsumexp_rows(&self) -> Vec<f32> {
        debug_assert_eq!(self.shape.len(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let s: f32 = row.iter().map(|&v| (v - m).exp()).sum();
                m + s.ln()
            })
            .collect()
    }
}

/// Build an f32 literal straight from a host slice — the zero-copy-side
/// marshalling entry: no intermediate `Vec` / `TensorF32` is materialized,
/// the slice goes directly into the literal.  An empty `shape` produces a
/// rank-0 scalar.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.is_empty() {
        return lit
            .reshape(&[])
            .map_err(|e| anyhow::anyhow!("reshape scalar: {e:?}"));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
}

/// Build an i32 literal (labels input of the train artifacts).
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape i32 {shape:?}: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_picks_max_per_row() {
        let t = TensorF32::new(vec![2, 3], vec![0.1, 0.9, 0.2, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn logsumexp_matches_naive() {
        let t = TensorF32::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let naive = (1f32.exp() + 2f32.exp() + 3f32.exp() + 4f32.exp()).ln();
        assert!((t.logsumexp_rows()[0] - naive).abs() < 1e-5);
    }

    #[test]
    fn logsumexp_stable_for_large_values() {
        let t = TensorF32::new(vec![1, 2], vec![1000.0, 1000.0]);
        let v = t.logsumexp_rows()[0];
        assert!((v - (1000.0 + 2f32.ln())).abs() < 1e-3);
        assert!(v.is_finite());
    }

    #[test]
    fn literal_roundtrip_preserves_shape_and_data() {
        let t = TensorF32::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let back = TensorF32::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
        let s = TensorF32::scalar(7.5);
        let lit = f32_literal(&s.data, &s.shape).unwrap();
        let back = TensorF32::from_literal(lit).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.data, vec![7.5]);
    }

    #[test]
    fn row_view_is_correct_slice() {
        let t = TensorF32::new(vec![3, 2], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.row(1), &[2.0, 3.0]);
    }
}
