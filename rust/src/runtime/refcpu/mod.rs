//! The pure-Rust reference backend: executes the artifact segments'
//! *semantics* (forward pass, SGD train step, SimSiam step, feature probe,
//! CKA Gram statistic) on the host, with no XLA toolchain, for the
//! linear/CWR-head model family described by the [`Manifest`].
//!
//! # Execution core
//!
//! Since PR 3 every CI test, bench series, sweep worker, and serving run
//! executes through this backend, so its kernels are the hot path of the
//! whole repo.  The core is built from three pieces:
//!
//! * **Packed GEMM family** ([`gemm`]) — register-blocked kernels for
//!   `out = act(x·w + b)`, `dx = dz·wᵀ`, and `dw += xᵀ·dz` with the bias
//!   and ReLU/GELU epilogues fused into the tile loop.  The k-reduction
//!   stays serial and in-order per output element (tiling is over m/n
//!   only), so results are **bit-identical** to the seed's naive triple
//!   loops — which survive in [`naive`] as the oracle that
//!   `tests/refcpu_gemm.rs` checks equality against.
//! * **Weight-pack cache** ([`gemm::PackCache`]) — weights are packed
//!   into padded row panels (and transposed panels for the backward dx
//!   kernel) once per θ *buffer*, keyed by [`Value::buf_id`].  Buf ids
//!   change exactly when a [`crate::model::Params`] generation does, so
//!   packs invalidate in lockstep with the session's θ-literal cache:
//!   one pack per train-step generation bump, zero packs in steady-state
//!   serving (the serving engine [`Backend::warm`]s the pack when it
//!   installs a CWR-bank θ).  [`Backend::release`] drops packs when the
//!   session evicts the matching θ value.  Under QAT the fake-quantizer
//!   is fused into the pack, so `train_q` never materializes `wq`.
//! * **Scratch arena** ([`arena::Arena`]) — every intermediate buffer
//!   (activations, tapes, cotangents, the flat gradient) is recycled
//!   through a length-bucketed pool; after one warm-up execute the
//!   steady state is zero fresh allocations per call.  Escaping outputs
//!   (θ′, logits) move into their output literal without a copy
//!   (`HostLiteral::f32_owned`).
//!
//! Counters for all three (packs built/hit, scratch allocs/reuses/bytes)
//! surface through [`Backend::perf`] into `Report`.
//!
//! # Artifact sources
//!
//! * **directory** — when `<dir>/manifest.json` exists, the backend loads
//!   aot.py's manifest and θ0/φ0 binaries, so a refcpu run and a PJRT run
//!   start from the *same* parameters and must agree on predictions to
//!   within fp tolerance (`tests/backend_parity.rs`);
//! * **built-in** — otherwise the [`builtin`] model family is synthesized
//!   in-process, which is what lets CI machines execute full end-to-end
//!   simulations with zero build-time dependencies (the portability
//!   argument TinyOL makes for dependency-free on-device kernels).
//!
//! Execution is sequential and deterministic: a simulation produces
//! bit-identical reports for any `--jobs` worker count, and none of the
//! caches above change a single output bit (asserted by the fingerprint
//! suites in `tests/`).

pub mod arena;
pub mod builtin;
pub mod gemm;
pub mod kernels;
pub mod naive;

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::backend::{Backend, BackendPerf, Value};
use super::hostlit::HostLiteral;
use self::arena::Arena;
use self::gemm::PackCache;
use self::kernels::{Ctx, RefModel};

/// Where θ0/φ0 come from.
enum Source {
    /// aot.py artifact directory (manifest + `<model>_theta0.bin`).
    Dir(PathBuf),
    /// Built-in family: deterministic in-process init.
    Builtin {
        theta0: HashMap<String, Vec<f32>>,
        phi0: HashMap<String, Vec<f32>>,
    },
}

/// What one artifact segment computes.
enum Op {
    Infer,
    Features,
    Train { quant: bool },
    Ssl,
    Cka,
}

struct OpSpec {
    model: String,
    op: Op,
}

/// Pure-Rust reference executor (see module docs).
pub struct RefCpuBackend {
    manifest: Manifest,
    source: Source,
    models: HashMap<String, RefModel>,
    ops: HashMap<String, OpSpec>,
    exec_count: Cell<u64>,
    /// Scratch arena shared by every kernel call on this backend.
    scratch: RefCell<Arena>,
    /// Generation-keyed packed-weight cache (see module docs).
    packs: RefCell<PackCache>,
}

impl RefCpuBackend {
    /// Bind an artifact directory when its manifest exists, else the
    /// built-in model family.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<RefCpuBackend> {
        let dir = dir.as_ref().to_path_buf();
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(&dir)?;
            Self::new(manifest, Source::Dir(dir))
        } else {
            Self::builtin()
        }
    }

    /// The built-in model family, ignoring any artifact directory.
    pub fn builtin() -> Result<RefCpuBackend> {
        let manifest = builtin::manifest();
        let mut theta0 = HashMap::new();
        let mut phi0 = HashMap::new();
        for (name, mm) in &manifest.models {
            theta0.insert(name.clone(), builtin::theta0(mm));
            if mm.artifacts.ssl.is_some() {
                phi0.insert(name.clone(), builtin::phi0(mm));
            }
        }
        Self::new(manifest, Source::Builtin { theta0, phi0 })
    }

    fn new(manifest: Manifest, source: Source) -> Result<RefCpuBackend> {
        let mut models = HashMap::new();
        let mut ops = HashMap::new();
        for (name, mm) in &manifest.models {
            models.insert(name.clone(), RefModel::from_manifest(mm)?);
            let mut add = |art: &str, op: Op| {
                ops.insert(art.to_string(), OpSpec { model: name.clone(), op });
            };
            add(&mm.artifacts.infer, Op::Infer);
            add(&mm.artifacts.features, Op::Features);
            for t in &mm.artifacts.train {
                add(t, Op::Train { quant: false });
            }
            for t in &mm.artifacts.train_q {
                add(t, Op::Train { quant: true });
            }
            if let Some(ssl) = &mm.artifacts.ssl {
                add(ssl, Op::Ssl);
            }
        }
        for cka_name in manifest.cka.values() {
            ops.insert(
                cka_name.clone(),
                OpSpec { model: String::new(), op: Op::Cka },
            );
        }
        Ok(RefCpuBackend {
            manifest,
            source,
            models,
            ops,
            exec_count: Cell::new(0),
            scratch: RefCell::new(Arena::new()),
            packs: RefCell::new(PackCache::new()),
        })
    }

    fn model(&self, name: &str) -> Result<&RefModel> {
        self.models
            .get(name)
            .with_context(|| format!("refcpu: unknown model {name:?}"))
    }

    /// Borrow input `idx` as an f32 host literal slice + shape.
    fn f32_in<'a>(inputs: &'a [&Value], idx: usize) -> Result<(&'a [f32], Vec<usize>)> {
        let lit = inputs
            .get(idx)
            .with_context(|| format!("refcpu: missing input {idx}"))?
            .as_host()?;
        let data = lit
            .f32_slice()
            .map_err(|e| anyhow::anyhow!("input {idx}: {e:?}"))?;
        let shape = lit
            .shape()
            .map_err(|e| anyhow::anyhow!("input {idx}: {e:?}"))?;
        Ok((data, shape))
    }

    fn i32_in<'a>(inputs: &'a [&Value], idx: usize) -> Result<&'a [i32]> {
        inputs
            .get(idx)
            .with_context(|| format!("refcpu: missing input {idx}"))?
            .as_host()?
            .i32_slice()
            .map_err(|e| anyhow::anyhow!("input {idx}: {e:?}"))
    }

    /// Buf id of input `idx` — the weight-pack cache key for θ/φ inputs.
    fn src_of(inputs: &[&Value], idx: usize) -> u64 {
        inputs.get(idx).map(|v| v.buf_id()).unwrap_or(0)
    }

    /// Rows of a `[b, width]` input (validating the row width).
    fn rows(shape: &[usize], data_len: usize, width: usize, what: &str) -> Result<usize> {
        anyhow::ensure!(
            shape.len() == 2 && shape[1] == width && shape[0] * width == data_len,
            "refcpu: bad {what} shape {shape:?} (want [b, {width}])"
        );
        Ok(shape[0])
    }
}

fn out_f32(data: &[f32], shape: &[usize]) -> Result<Value> {
    Ok(Value::host(
        HostLiteral::f32(data, shape).map_err(|e| anyhow::anyhow!("{e:?}"))?,
    ))
}

/// Move an escaping kernel output into its literal without a copy.
fn out_f32_owned(data: Vec<f32>, shape: &[usize]) -> Result<Value> {
    Ok(Value::host(
        HostLiteral::f32_owned(data, shape).map_err(|e| anyhow::anyhow!("{e:?}"))?,
    ))
}

impl Backend for RefCpuBackend {
    fn name(&self) -> &'static str {
        "refcpu"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executions(&self) -> u64 {
        self.exec_count.get()
    }

    fn marshal_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        out_f32(data, shape)
    }

    fn marshal_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        Ok(Value::host(
            HostLiteral::i32(data, shape).map_err(|e| anyhow::anyhow!("{e:?}"))?,
        ))
    }

    fn execute(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let spec = self
            .ops
            .get(name)
            .with_context(|| format!("refcpu: unknown segment {name:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        let mut pool = self.scratch.borrow_mut();
        let mut packs = self.packs.borrow_mut();
        let mut ctx = Ctx { pool: &mut pool, packs: &mut packs };
        match &spec.op {
            Op::Infer => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (x, xs) = Self::f32_in(inputs, 1)?;
                let b = Self::rows(&xs, x.len(), model.d, "x")?;
                let logits = model.infer(theta, x, b, Self::src_of(inputs, 0), &mut ctx);
                Ok(vec![out_f32_owned(logits, &[b, model.classes])?])
            }
            Op::Features => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (x, xs) = Self::f32_in(inputs, 1)?;
                let b = Self::rows(&xs, x.len(), model.d, "x")?;
                let feats = model.features(theta, x, b, Self::src_of(inputs, 0), &mut ctx);
                Ok(vec![out_f32_owned(feats, &[model.blocks + 1, b, model.h])?])
            }
            Op::Train { quant } => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (x, xs) = Self::f32_in(inputs, 1)?;
                let b = Self::rows(&xs, x.len(), model.d, "x")?;
                let y = Self::i32_in(inputs, 2)?;
                anyhow::ensure!(y.len() == b, "refcpu: bad y len {}", y.len());
                anyhow::ensure!(
                    y.iter().all(|&c| (c as usize) < model.classes && c >= 0),
                    "refcpu: label out of range"
                );
                let (mask, _) = Self::f32_in(inputs, 3)?;
                anyhow::ensure!(mask.len() == model.blocks + 2, "refcpu: bad mask len");
                let (lr, _) = Self::f32_in(inputs, 4)?;
                anyhow::ensure!(!lr.is_empty(), "refcpu: empty lr input");
                let (theta_new, loss) = model.train_step(
                    theta,
                    x,
                    y,
                    b,
                    mask,
                    lr[0],
                    *quant,
                    Self::src_of(inputs, 0),
                    &mut ctx,
                );
                Ok(vec![
                    out_f32_owned(theta_new, &[model.theta_len])?,
                    out_f32(&[loss], &[])?,
                ])
            }
            Op::Ssl => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (phi, _) = Self::f32_in(inputs, 1)?;
                let (x1, x1s) = Self::f32_in(inputs, 2)?;
                let (x2, x2s) = Self::f32_in(inputs, 3)?;
                let b = Self::rows(&x1s, x1.len(), model.d, "x1")?;
                let b2 = Self::rows(&x2s, x2.len(), model.d, "x2")?;
                anyhow::ensure!(b == b2, "refcpu: ssl view batch mismatch");
                let (mask, _) = Self::f32_in(inputs, 4)?;
                anyhow::ensure!(mask.len() == model.blocks + 2, "refcpu: bad mask len");
                let (lr, _) = Self::f32_in(inputs, 5)?;
                anyhow::ensure!(!lr.is_empty(), "refcpu: empty lr input");
                anyhow::ensure!(
                    phi.len() == 2 * model.h * model.h + 2 * model.h,
                    "refcpu: bad φ len {}",
                    phi.len()
                );
                let phi_src = Self::src_of(inputs, 1);
                let (theta_new, phi_new, loss) = model.ssl_step(
                    theta,
                    phi,
                    x1,
                    x2,
                    b,
                    mask,
                    lr[0],
                    Self::src_of(inputs, 0),
                    phi_src,
                    &mut ctx,
                );
                // φ is marshalled fresh per ssl call (the session does not
                // cache it), so its packs are single-use: release them now
                // — their storage recycles into the next call's packs and
                // the src cap never churns on ssl loops.
                ctx.packs.release(phi_src);
                let phi_len = phi_new.len();
                Ok(vec![
                    out_f32_owned(theta_new, &[model.theta_len])?,
                    out_f32_owned(phi_new, &[phi_len])?,
                    out_f32(&[loss], &[])?,
                ])
            }
            Op::Cka => {
                let (fx, fxs) = Self::f32_in(inputs, 0)?;
                let (fy, fys) = Self::f32_in(inputs, 1)?;
                anyhow::ensure!(
                    fxs.len() == 2 && fxs == fys,
                    "refcpu: cka shapes {fxs:?} vs {fys:?}"
                );
                let v = kernels::cka(fx, fy, fxs[0], fxs[1]);
                Ok(vec![out_f32(&[v], &[])?])
            }
        }
    }

    fn theta0(&self, model: &str) -> Result<Vec<f32>> {
        match &self.source {
            Source::Dir(dir) => {
                super::client::read_f32_bin(dir, &format!("{model}_theta0.bin"))
            }
            Source::Builtin { theta0, .. } => theta0
                .get(model)
                .cloned()
                .with_context(|| format!("refcpu: no θ0 for model {model:?}")),
        }
    }

    fn phi0(&self, model: &str) -> Result<Vec<f32>> {
        match &self.source {
            Source::Dir(dir) => {
                super::client::read_f32_bin(dir, &format!("{model}_phi0.bin"))
            }
            Source::Builtin { phi0, .. } => phi0
                .get(model)
                .cloned()
                .with_context(|| format!("refcpu: no φ0 for model {model:?}")),
        }
    }

    fn perf(&self) -> BackendPerf {
        let pool = self.scratch.borrow();
        let packs = self.packs.borrow();
        BackendPerf {
            gemm_packs: packs.built(),
            gemm_pack_hits: packs.hits(),
            scratch_allocs: pool.fresh_allocs(),
            scratch_reuses: pool.reuses(),
            scratch_bytes_reused: pool.bytes_reused(),
        }
    }

    fn warm(&self, segment: &str, theta: &Value) -> Result<()> {
        let Some(spec) = self.ops.get(segment) else {
            anyhow::bail!("refcpu: cannot warm unknown segment {segment:?}");
        };
        // only the forward-panel segments have per-θ state worth
        // pre-building; warming a train segment is a no-op (its packs are
        // per-generation anyway).
        if !matches!(spec.op, Op::Infer | Op::Features) {
            return Ok(());
        }
        let model = self.model(&spec.model)?;
        let lit = theta.as_host()?;
        let data = lit
            .f32_slice()
            .map_err(|e| anyhow::anyhow!("warm {segment}: {e:?}"))?;
        anyhow::ensure!(data.len() == model.theta_len, "refcpu: warm bad θ len");
        let mut pool = self.scratch.borrow_mut();
        let mut packs = self.packs.borrow_mut();
        let mut ctx = Ctx { pool: &mut pool, packs: &mut packs };
        model.warm_infer(data, theta.buf_id(), &mut ctx);
        Ok(())
    }

    fn release(&self, buf_id: u64) {
        self.packs.borrow_mut().release(buf_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_backend_executes_infer() {
        let be = RefCpuBackend::builtin().unwrap();
        let mm = be.manifest().model("mbv2").unwrap().clone();
        let theta = be.theta0("mbv2").unwrap();
        let tv = be.marshal_f32(&theta, &[mm.theta_len]).unwrap();
        let x = vec![0.1f32; 4 * mm.d];
        let xv = be.marshal_f32(&x, &[4, mm.d]).unwrap();
        let out = be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        assert_eq!(out.len(), 1);
        let t = out[0].to_tensor().unwrap();
        assert_eq!(t.shape, vec![4, mm.classes]);
        assert!(t.data.iter().all(|v| v.is_finite()));
        assert_eq!(be.executions(), 1);
    }

    #[test]
    fn unknown_segment_is_an_error() {
        let be = RefCpuBackend::builtin().unwrap();
        assert!(be.execute("nope_infer", &[]).is_err());
    }

    #[test]
    fn theta_marshal_roundtrip_is_lossless() {
        let be = RefCpuBackend::builtin().unwrap();
        let theta = be.theta0("res50").unwrap();
        let v = be.marshal_f32(&theta, &[theta.len()]).unwrap();
        assert_eq!(v.read_f32().unwrap(), theta);
    }

    #[test]
    fn same_theta_value_executes_without_repacking() {
        let be = RefCpuBackend::builtin().unwrap();
        let mm = be.manifest().model("mbv2").unwrap().clone();
        let theta = be.theta0("mbv2").unwrap();
        let tv = be.marshal_f32(&theta, &[mm.theta_len]).unwrap();
        let x = vec![0.1f32; 4 * mm.d];
        let xv = be.marshal_f32(&x, &[4, mm.d]).unwrap();
        be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        let after_first = be.perf();
        assert!(after_first.gemm_packs > 0, "first execute must pack");
        let a = be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        let b = be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        let after = be.perf();
        assert_eq!(
            after.gemm_packs, after_first.gemm_packs,
            "same θ buffer re-packed"
        );
        assert!(after.gemm_pack_hits > after_first.gemm_pack_hits);
        assert!(after.scratch_reuses > 0, "scratch never recycled");
        assert_eq!(a[0].read_f32().unwrap(), b[0].read_f32().unwrap());
    }

    #[test]
    fn warm_prepacks_and_release_drops() {
        let be = RefCpuBackend::builtin().unwrap();
        let mm = be.manifest().model("mbv2").unwrap().clone();
        let theta = be.theta0("mbv2").unwrap();
        let tv = be.marshal_f32(&theta, &[mm.theta_len]).unwrap();
        be.warm(&mm.artifacts.infer, &tv).unwrap();
        let warmed = be.perf().gemm_packs;
        assert!(warmed > 0);
        // the execute after a warm finds every panel packed
        let x = vec![0.1f32; 4 * mm.d];
        let xv = be.marshal_f32(&x, &[4, mm.d]).unwrap();
        be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        assert_eq!(be.perf().gemm_packs, warmed, "execute packed after warm");
        // release invalidates: the next execute packs again
        be.release(tv.buf_id());
        be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        assert!(be.perf().gemm_packs > warmed);
        // warming a train segment is a no-op, unknown segments error
        assert!(be.warm(&mm.artifacts.train[0], &tv).is_ok());
        assert!(be.warm("nope_infer", &tv).is_err());
    }
}
