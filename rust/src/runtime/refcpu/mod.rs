//! The pure-Rust reference backend: executes the artifact segments'
//! *semantics* (forward pass, SGD train step, SimSiam step, feature probe,
//! CKA Gram statistic) on the host, with no XLA toolchain, for the
//! linear/CWR-head model family described by the [`Manifest`].
//!
//! Two artifact sources:
//! * **directory** — when `<dir>/manifest.json` exists, the backend loads
//!   aot.py's manifest and θ0/φ0 binaries, so a refcpu run and a PJRT run
//!   start from the *same* parameters and must agree on predictions to
//!   within fp tolerance (`tests/backend_parity.rs`);
//! * **built-in** — otherwise the [`builtin`] model family is synthesized
//!   in-process, which is what lets CI machines execute full end-to-end
//!   simulations with zero build-time dependencies (the portability
//!   argument TinyOL makes for dependency-free on-device kernels).
//!
//! Execution is sequential and deterministic: a simulation produces
//! bit-identical reports for any `--jobs` worker count.

pub mod builtin;
pub mod kernels;

use std::cell::Cell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::backend::{Backend, Value};
use super::hostlit::HostLiteral;
use self::kernels::RefModel;

/// Where θ0/φ0 come from.
enum Source {
    /// aot.py artifact directory (manifest + `<model>_theta0.bin`).
    Dir(PathBuf),
    /// Built-in family: deterministic in-process init.
    Builtin {
        theta0: HashMap<String, Vec<f32>>,
        phi0: HashMap<String, Vec<f32>>,
    },
}

/// What one artifact segment computes.
enum Op {
    Infer,
    Features,
    Train { quant: bool },
    Ssl,
    Cka,
}

struct OpSpec {
    model: String,
    op: Op,
}

/// Pure-Rust reference executor (see module docs).
pub struct RefCpuBackend {
    manifest: Manifest,
    source: Source,
    models: HashMap<String, RefModel>,
    ops: HashMap<String, OpSpec>,
    exec_count: Cell<u64>,
}

impl RefCpuBackend {
    /// Bind an artifact directory when its manifest exists, else the
    /// built-in model family.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<RefCpuBackend> {
        let dir = dir.as_ref().to_path_buf();
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(&dir)?;
            Self::new(manifest, Source::Dir(dir))
        } else {
            Self::builtin()
        }
    }

    /// The built-in model family, ignoring any artifact directory.
    pub fn builtin() -> Result<RefCpuBackend> {
        let manifest = builtin::manifest();
        let mut theta0 = HashMap::new();
        let mut phi0 = HashMap::new();
        for (name, mm) in &manifest.models {
            theta0.insert(name.clone(), builtin::theta0(mm));
            if mm.artifacts.ssl.is_some() {
                phi0.insert(name.clone(), builtin::phi0(mm));
            }
        }
        Self::new(manifest, Source::Builtin { theta0, phi0 })
    }

    fn new(manifest: Manifest, source: Source) -> Result<RefCpuBackend> {
        let mut models = HashMap::new();
        let mut ops = HashMap::new();
        for (name, mm) in &manifest.models {
            models.insert(name.clone(), RefModel::from_manifest(mm)?);
            let mut add = |art: &str, op: Op| {
                ops.insert(art.to_string(), OpSpec { model: name.clone(), op });
            };
            add(&mm.artifacts.infer, Op::Infer);
            add(&mm.artifacts.features, Op::Features);
            for t in &mm.artifacts.train {
                add(t, Op::Train { quant: false });
            }
            for t in &mm.artifacts.train_q {
                add(t, Op::Train { quant: true });
            }
            if let Some(ssl) = &mm.artifacts.ssl {
                add(ssl, Op::Ssl);
            }
        }
        for cka_name in manifest.cka.values() {
            ops.insert(
                cka_name.clone(),
                OpSpec { model: String::new(), op: Op::Cka },
            );
        }
        Ok(RefCpuBackend {
            manifest,
            source,
            models,
            ops,
            exec_count: Cell::new(0),
        })
    }

    fn model(&self, name: &str) -> Result<&RefModel> {
        self.models
            .get(name)
            .with_context(|| format!("refcpu: unknown model {name:?}"))
    }

    /// Borrow input `idx` as an f32 host literal slice + shape.
    fn f32_in<'a>(inputs: &'a [&Value], idx: usize) -> Result<(&'a [f32], Vec<usize>)> {
        let lit = inputs
            .get(idx)
            .with_context(|| format!("refcpu: missing input {idx}"))?
            .as_host()?;
        let data = lit
            .f32_slice()
            .map_err(|e| anyhow::anyhow!("input {idx}: {e:?}"))?;
        let shape = lit
            .shape()
            .map_err(|e| anyhow::anyhow!("input {idx}: {e:?}"))?;
        Ok((data, shape))
    }

    fn i32_in<'a>(inputs: &'a [&Value], idx: usize) -> Result<&'a [i32]> {
        inputs
            .get(idx)
            .with_context(|| format!("refcpu: missing input {idx}"))?
            .as_host()?
            .i32_slice()
            .map_err(|e| anyhow::anyhow!("input {idx}: {e:?}"))
    }

    /// Rows of a `[b, width]` input (validating the row width).
    fn rows(shape: &[usize], data_len: usize, width: usize, what: &str) -> Result<usize> {
        anyhow::ensure!(
            shape.len() == 2 && shape[1] == width && shape[0] * width == data_len,
            "refcpu: bad {what} shape {shape:?} (want [b, {width}])"
        );
        Ok(shape[0])
    }
}

fn out_f32(data: &[f32], shape: &[usize]) -> Result<Value> {
    Ok(Value::Host(
        HostLiteral::f32(data, shape).map_err(|e| anyhow::anyhow!("{e:?}"))?,
    ))
}

impl Backend for RefCpuBackend {
    fn name(&self) -> &'static str {
        "refcpu"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executions(&self) -> u64 {
        self.exec_count.get()
    }

    fn marshal_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        out_f32(data, shape)
    }

    fn marshal_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        Ok(Value::Host(
            HostLiteral::i32(data, shape).map_err(|e| anyhow::anyhow!("{e:?}"))?,
        ))
    }

    fn execute(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let spec = self
            .ops
            .get(name)
            .with_context(|| format!("refcpu: unknown segment {name:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        match &spec.op {
            Op::Infer => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (x, xs) = Self::f32_in(inputs, 1)?;
                let b = Self::rows(&xs, x.len(), model.d, "x")?;
                let logits = model.infer(theta, x, b);
                Ok(vec![out_f32(&logits, &[b, model.classes])?])
            }
            Op::Features => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (x, xs) = Self::f32_in(inputs, 1)?;
                let b = Self::rows(&xs, x.len(), model.d, "x")?;
                let feats = model.features(theta, x, b);
                Ok(vec![out_f32(&feats, &[model.blocks + 1, b, model.h])?])
            }
            Op::Train { quant } => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (x, xs) = Self::f32_in(inputs, 1)?;
                let b = Self::rows(&xs, x.len(), model.d, "x")?;
                let y = Self::i32_in(inputs, 2)?;
                anyhow::ensure!(y.len() == b, "refcpu: bad y len {}", y.len());
                anyhow::ensure!(
                    y.iter().all(|&c| (c as usize) < model.classes && c >= 0),
                    "refcpu: label out of range"
                );
                let (mask, _) = Self::f32_in(inputs, 3)?;
                anyhow::ensure!(mask.len() == model.blocks + 2, "refcpu: bad mask len");
                let (lr, _) = Self::f32_in(inputs, 4)?;
                anyhow::ensure!(!lr.is_empty(), "refcpu: empty lr input");
                let (theta_new, loss) =
                    model.train_step(theta, x, y, b, mask, lr[0], *quant);
                Ok(vec![
                    out_f32(&theta_new, &[model.theta_len])?,
                    out_f32(&[loss], &[])?,
                ])
            }
            Op::Ssl => {
                let model = self.model(&spec.model)?;
                let (theta, _) = Self::f32_in(inputs, 0)?;
                anyhow::ensure!(theta.len() == model.theta_len, "refcpu: bad θ len");
                let (phi, _) = Self::f32_in(inputs, 1)?;
                let (x1, x1s) = Self::f32_in(inputs, 2)?;
                let (x2, x2s) = Self::f32_in(inputs, 3)?;
                let b = Self::rows(&x1s, x1.len(), model.d, "x1")?;
                let b2 = Self::rows(&x2s, x2.len(), model.d, "x2")?;
                anyhow::ensure!(b == b2, "refcpu: ssl view batch mismatch");
                let (mask, _) = Self::f32_in(inputs, 4)?;
                anyhow::ensure!(mask.len() == model.blocks + 2, "refcpu: bad mask len");
                let (lr, _) = Self::f32_in(inputs, 5)?;
                anyhow::ensure!(!lr.is_empty(), "refcpu: empty lr input");
                anyhow::ensure!(
                    phi.len() == 2 * model.h * model.h + 2 * model.h,
                    "refcpu: bad φ len {}",
                    phi.len()
                );
                let (theta_new, phi_new, loss) =
                    model.ssl_step(theta, phi, x1, x2, b, mask, lr[0]);
                Ok(vec![
                    out_f32(&theta_new, &[model.theta_len])?,
                    out_f32(&phi_new, &[phi_new.len()])?,
                    out_f32(&[loss], &[])?,
                ])
            }
            Op::Cka => {
                let (fx, fxs) = Self::f32_in(inputs, 0)?;
                let (fy, fys) = Self::f32_in(inputs, 1)?;
                anyhow::ensure!(
                    fxs.len() == 2 && fxs == fys,
                    "refcpu: cka shapes {fxs:?} vs {fys:?}"
                );
                let v = kernels::cka(fx, fy, fxs[0], fxs[1]);
                Ok(vec![out_f32(&[v], &[])?])
            }
        }
    }

    fn theta0(&self, model: &str) -> Result<Vec<f32>> {
        match &self.source {
            Source::Dir(dir) => {
                super::client::read_f32_bin(dir, &format!("{model}_theta0.bin"))
            }
            Source::Builtin { theta0, .. } => theta0
                .get(model)
                .cloned()
                .with_context(|| format!("refcpu: no θ0 for model {model:?}")),
        }
    }

    fn phi0(&self, model: &str) -> Result<Vec<f32>> {
        match &self.source {
            Source::Dir(dir) => {
                super::client::read_f32_bin(dir, &format!("{model}_phi0.bin"))
            }
            Source::Builtin { phi0, .. } => phi0
                .get(model)
                .cloned()
                .with_context(|| format!("refcpu: no φ0 for model {model:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_backend_executes_infer() {
        let be = RefCpuBackend::builtin().unwrap();
        let mm = be.manifest().model("mbv2").unwrap().clone();
        let theta = be.theta0("mbv2").unwrap();
        let tv = be.marshal_f32(&theta, &[mm.theta_len]).unwrap();
        let x = vec![0.1f32; 4 * mm.d];
        let xv = be.marshal_f32(&x, &[4, mm.d]).unwrap();
        let out = be.execute(&mm.artifacts.infer, &[&tv, &xv]).unwrap();
        assert_eq!(out.len(), 1);
        let t = out[0].to_tensor().unwrap();
        assert_eq!(t.shape, vec![4, mm.classes]);
        assert!(t.data.iter().all(|v| v.is_finite()));
        assert_eq!(be.executions(), 1);
    }

    #[test]
    fn unknown_segment_is_an_error() {
        let be = RefCpuBackend::builtin().unwrap();
        assert!(be.execute("nope_infer", &[]).is_err());
    }

    #[test]
    fn theta_marshal_roundtrip_is_lossless() {
        let be = RefCpuBackend::builtin().unwrap();
        let theta = be.theta0("res50").unwrap();
        let v = be.marshal_f32(&theta, &[theta.len()]).unwrap();
        assert_eq!(v.read_f32().unwrap(), theta);
    }
}
