//! Pure-Rust forward/backward kernels for the deployed model family,
//! running on the packed GEMM core in [`super::gemm`].
//!
//! Implements, in f32 with bit-stable operation order, the exact
//! semantics the python side lowers to HLO (see `python/compile/model.py`
//! + `kernels/matmul.py`): `act(x @ w + b)` dense layers with
//! ReLU/tanh-GELU epilogues, the three block kinds (`relu_res`,
//! `bottleneck`, `preln_gelu`), LayerNorm, the mean-CE loss with
//! log-softmax, per-tensor symmetric fake-quantization with a
//! straight-through gradient, global-norm clipping at 5.0, the SimSiam
//! cosine loss, and the linear-CKA Gram statistic.
//!
//! Backward passes mirror the JAX `custom_vjp` rules one-to-one:
//! * dense ReLU uses the *output* mask (`dout * (out > 0)`) — the output
//!   is not copied into the tape; the VJP reads it from where it already
//!   lives (the next layer's input, or the residual operand);
//! * dense GELU pushes the cotangent through the tanh-approximation
//!   derivative at the saved pre-activation;
//! * the `relu_res` blocks' *outer* residual ReLU is `jnp.maximum`, whose
//!   tie case routes half the cotangent (`lax.max` JVP) — reproduced here
//!   so zero-initialized residual paths differentiate identically;
//! * fake-quant is a straight-through estimator: forward uses quantized
//!   values, backward treats the quantizer as identity, and downstream
//!   VJPs contract against the saved *quantized* tensors.  The weight
//!   side of the quantizer is fused into the pack step (one quantized
//!   panel per θ generation), so `train_q` never materializes `wq`.
//!
//! Everything is sequential and allocation-order deterministic, so runs
//! are bit-identical across sweep worker counts.  All intermediates come
//! from the per-backend scratch [`Arena`]; weight panels come from the
//! generation-keyed [`PackCache`].  The pre-PR-4 naive loops survive in
//! [`super::naive`] as the oracle `tests/refcpu_gemm.rs` checks
//! bit-equality against.

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use crate::runtime::artifact::ModelManifest;

use super::arena::Arena;
use super::gemm::{self, PackCache};

pub use super::gemm::{gelu, gelu_prime, Act};

pub const MAX_GRAD_NORM: f32 = 5.0;
const LN_EPS: f32 = 1e-5;

/// Execution context threaded through every kernel call: the backend's
/// scratch arena and its generation-keyed weight-pack cache.
pub struct Ctx<'c> {
    pub pool: &'c mut Arena,
    pub packs: &'c mut PackCache,
}

// ---------------------------------------------------------------------------
// elementwise pieces
// ---------------------------------------------------------------------------

/// In-place clip-by-global-norm (matches `_clip_global` in model.py).
pub fn clip_global(g: &mut [f32], max_norm: f32) {
    let norm = g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32;
    let scale = (max_norm / norm.max(1e-12)).min(1.0);
    if scale < 1.0 {
        for v in g.iter_mut() {
            *v *= scale;
        }
    }
}

// ---------------------------------------------------------------------------
// dense layer (act(x @ w + b)) with tape
// ---------------------------------------------------------------------------

/// An input buffer as threaded through the tape: the caller's batch is
/// borrowed (zero copy), every interior activation is *moved* in from
/// the arena (zero copy), and QAT's quantized copies are arena buffers.
pub enum XBuf<'a> {
    Borrowed(&'a [f32]),
    Pooled(Vec<f32>),
}

impl<'a> XBuf<'a> {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            XBuf::Borrowed(s) => s,
            XBuf::Pooled(v) => v,
        }
    }

    fn recycle(self, pool: &mut Arena) {
        if let XBuf::Pooled(v) = self {
            pool.give(v);
        }
    }
}

/// Pack-cache addressing for one dense layer's weights: the buf id of
/// the flat parameter buffer (θ or φ) and the tensor offset within it.
#[derive(Clone, Copy, Debug)]
pub struct DenseKey {
    pub src: u64,
    pub w_off: usize,
}

/// Saved residuals of one dense layer for its VJP.
///
/// Unlike the seed tape this owns **no weight copy** (the VJP contracts
/// against the cached transposed panels) and **no activation-output
/// copy** (the ReLU mask is read from wherever the output already
/// lives).  `x_orig` is the input as given — moved, not copied; `xq` is
/// the arena-allocated quantized copy under QAT (what the STE backward
/// contracts against); `z` holds GELU pre-activations.
pub struct DenseTape<'a> {
    x_orig: XBuf<'a>,
    xq: Vec<f32>,
    z: Vec<f32>,
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    quant: bool,
    key: DenseKey,
}

impl<'a> DenseTape<'a> {
    /// The layer input *as used* by the matmul (quantized under QAT).
    fn x_used(&self) -> &[f32] {
        if self.quant {
            &self.xq
        } else {
            self.x_orig.as_slice()
        }
    }

    /// The layer input as given (pre-quantization) — residual adds and
    /// downstream ReLU masks read this.
    pub fn x_orig(&self) -> &[f32] {
        self.x_orig.as_slice()
    }

    fn recycle(self, pool: &mut Arena) {
        self.x_orig.recycle(pool);
        pool.give(self.xq);
        pool.give(self.z);
    }
}

/// Training dense: returns the activation output (arena buffer) and the
/// VJP tape.  Bias and ReLU run fused inside the GEMM tile loop; GELU
/// training keeps the pre-activation like the seed (the tape needs it).
pub fn dense_train<'a>(
    x: XBuf<'a>,
    w: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    quant: bool,
    key: DenseKey,
    ctx: &mut Ctx,
) -> (Vec<f32>, DenseTape<'a>) {
    let xq = if quant {
        let mut q = ctx.pool.take(m * k);
        gemm::quantize_into(x.as_slice(), &mut q);
        q
    } else {
        Vec::new()
    };
    let xs = if quant { &xq[..] } else { x.as_slice() };
    let mut out = ctx.pool.take(m * n);
    let pan = ctx.packs.fwd(key.src, key.w_off, w, k, n, quant);
    let z = match act {
        Act::None | Act::Relu => {
            gemm::gemm_fwd(xs, pan, b, m, act, &mut out);
            Vec::new()
        }
        Act::Gelu => {
            let mut zb = ctx.pool.take(m * n);
            gemm::gemm_fwd(xs, pan, b, m, Act::None, &mut zb);
            for (o, &zv) in out.iter_mut().zip(&zb) {
                *o = gelu(zv);
            }
            zb
        }
    };
    (out, DenseTape { x_orig: x, xq, z, m, k, n, act, quant, key })
}

/// Dense VJP: activation rule into `dz`, then `dx = dz @ wᵀ` (packed
/// transpose), `dw += xᵀ @ dz` and `db += Σ_rows dz` accumulated
/// straight into `dparams` at `w_off`/`b_off` (register-summed from 0.0
/// per element, added once — the seed's fresh-buffer-then-accumulate
/// float order).  `relu_out` must be the layer's output when
/// `act == Relu`.  With `need_dx == false` the dx GEMM (and its
/// transposed pack) is skipped entirely — the seed computed and
/// discarded it for the embed layer.
pub fn dense_bwd(
    t: &DenseTape,
    dout: &[f32],
    relu_out: Option<&[f32]>,
    w: &[f32],
    dparams: &mut [f32],
    w_off: usize,
    b_off: usize,
    need_dx: bool,
    ctx: &mut Ctx,
) -> Vec<f32> {
    let (m, k, n) = (t.m, t.k, t.n);
    debug_assert_eq!(dout.len(), m * n);
    let mut dz_buf: Option<Vec<f32>> = match t.act {
        Act::None => None,
        Act::Relu => {
            let out = relu_out.expect("relu VJP needs the layer output");
            debug_assert_eq!(out.len(), m * n);
            let mut dz = ctx.pool.take(m * n);
            for ((d, &g), &o) in dz.iter_mut().zip(dout).zip(out) {
                *d = if o > 0.0 { g } else { 0.0 };
            }
            Some(dz)
        }
        Act::Gelu => {
            let mut dz = ctx.pool.take(m * n);
            for ((d, &g), &z) in dz.iter_mut().zip(dout).zip(&t.z) {
                *d = g * gelu_prime(z);
            }
            Some(dz)
        }
    };
    let dzs: &[f32] = dz_buf.as_deref().unwrap_or(dout);
    let dx = if need_dx {
        let pan = ctx.packs.bwd(t.key.src, t.key.w_off, w, k, n, t.quant);
        let mut dx = ctx.pool.take(m * k);
        gemm::gemm_dx(dzs, pan, m, &mut dx);
        dx
    } else {
        Vec::new()
    };
    gemm::gemm_dw_acc(t.x_used(), dzs, m, k, n, &mut dparams[w_off..w_off + k * n]);
    gemm::db_acc(dzs, m, n, &mut dparams[b_off..b_off + n]);
    if let Some(v) = dz_buf.take() {
        ctx.pool.give(v);
    }
    dx
}

// ---------------------------------------------------------------------------
// LayerNorm
// ---------------------------------------------------------------------------

pub struct LnTape {
    normed: Vec<f32>,
    inv_std: Vec<f32>,
    m: usize,
    h: usize,
}

impl LnTape {
    fn recycle(self, pool: &mut Arena) {
        pool.give(self.normed);
        pool.give(self.inv_std);
    }
}

fn layernorm_core(
    x: &[f32],
    s: &[f32],
    b: &[f32],
    m: usize,
    h: usize,
    out: &mut [f32],
    normed: &mut [f32],
    inv_std: &mut [f32],
) {
    for i in 0..m {
        let row = &x[i * h..(i + 1) * h];
        let mu = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let is = 1.0 / (var + LN_EPS).sqrt();
        inv_std[i] = is;
        for j in 0..h {
            let nv = (row[j] - mu) * is;
            normed[i * h + j] = nv;
            out[i * h + j] = nv * s[j] + b[j];
        }
    }
}

/// `out = normed(x) * s + b` per row; var is the biased mean of squares
/// (jnp.var), eps = 1e-5.  Allocating wrapper (tests); the model path
/// uses [`layernorm_fwd_pooled`].
pub fn layernorm_fwd(x: &[f32], s: &[f32], b: &[f32], m: usize, h: usize) -> (Vec<f32>, LnTape) {
    let mut out = vec![0.0f32; m * h];
    let mut normed = vec![0.0f32; m * h];
    let mut inv_std = vec![0.0f32; m];
    layernorm_core(x, s, b, m, h, &mut out, &mut normed, &mut inv_std);
    (out, LnTape { normed, inv_std, m, h })
}

fn layernorm_fwd_pooled(
    x: &[f32],
    s: &[f32],
    b: &[f32],
    m: usize,
    h: usize,
    pool: &mut Arena,
) -> (Vec<f32>, LnTape) {
    let mut out = pool.take(m * h);
    let mut normed = pool.take(m * h);
    let mut inv_std = pool.take(m);
    layernorm_core(x, s, b, m, h, &mut out, &mut normed, &mut inv_std);
    (out, LnTape { normed, inv_std, m, h })
}

/// Inference-only LayerNorm into a caller buffer: no tape.
fn layernorm_infer(x: &[f32], s: &[f32], b: &[f32], m: usize, h: usize, out: &mut [f32]) {
    for i in 0..m {
        let row = &x[i * h..(i + 1) * h];
        let mu = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let is = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..h {
            out[i * h + j] = (row[j] - mu) * is * s[j] + b[j];
        }
    }
}

fn layernorm_bwd_core(
    t: &LnTape,
    s: &[f32],
    dout: &[f32],
    dx: &mut [f32],
    ds: &mut [f32],
    db: &mut [f32],
) {
    let (m, h) = (t.m, t.h);
    for i in 0..m {
        let nrm = &t.normed[i * h..(i + 1) * h];
        let dor = &dout[i * h..(i + 1) * h];
        let mut mean_dn = 0.0f32;
        let mut mean_dn_n = 0.0f32;
        for j in 0..h {
            ds[j] += dor[j] * nrm[j];
            db[j] += dor[j];
            let dn = dor[j] * s[j];
            mean_dn += dn;
            mean_dn_n += dn * nrm[j];
        }
        mean_dn /= h as f32;
        mean_dn_n /= h as f32;
        let is = t.inv_std[i];
        for j in 0..h {
            let dn = dor[j] * s[j];
            dx[i * h + j] = is * (dn - mean_dn - nrm[j] * mean_dn_n);
        }
    }
}

/// LayerNorm VJP: returns (dx, ds, db).  Allocating wrapper (tests).
pub fn layernorm_bwd(t: &LnTape, s: &[f32], dout: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (m, h) = (t.m, t.h);
    let mut dx = vec![0.0f32; m * h];
    let mut ds = vec![0.0f32; h];
    let mut db = vec![0.0f32; h];
    layernorm_bwd_core(t, s, dout, &mut dx, &mut ds, &mut db);
    (dx, ds, db)
}

// ---------------------------------------------------------------------------
// losses
// ---------------------------------------------------------------------------

fn ce_core(logits: &[f32], y: &[i32], b: usize, c: usize, dl: &mut [f32]) -> f32 {
    debug_assert_eq!(logits.len(), b * c);
    debug_assert_eq!(y.len(), b);
    let mut loss = 0.0f32;
    let inv_b = 1.0 / b as f32;
    for i in 0..b {
        let row = &logits[i * c..(i + 1) * c];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let lse = mx + sum.ln();
        let yi = y[i] as usize;
        loss += lse - row[yi];
        let drow = &mut dl[i * c..(i + 1) * c];
        for j in 0..c {
            let p = (row[j] - lse).exp();
            drow[j] = (p - if j == yi { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    loss * inv_b
}

/// Mean cross-entropy over log-softmax rows; returns (loss, dlogits).
pub fn ce_loss_and_grad(logits: &[f32], y: &[i32], b: usize, c: usize) -> (f32, Vec<f32>) {
    let mut dl = vec![0.0f32; b * c];
    let loss = ce_core(logits, y, b, c, &mut dl);
    (loss, dl)
}

fn cosine_core(a: &[f32], target: &[f32], b: usize, h: usize, da: &mut [f32]) -> f32 {
    let mut total = 0.0f32;
    let inv_b = 1.0 / b as f32;
    for i in 0..b {
        let ar = &a[i * h..(i + 1) * h];
        let tr = &target[i * h..(i + 1) * h];
        let na_raw = ar.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let nt_raw = tr.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let na = na_raw.max(1e-8);
        let nt = nt_raw.max(1e-8);
        let mut dot = 0.0f32;
        for j in 0..h {
            dot += (ar[j] / na) * (tr[j] / nt);
        }
        total += dot;
        let dst = &mut da[i * h..(i + 1) * h];
        if na_raw > 1e-8 {
            // d/da of (â · t̂) = (t̂ - dot · â) / ||a||
            for j in 0..h {
                dst[j] = inv_b * (tr[j] / nt - dot * ar[j] / na) / na;
            }
        } else {
            // the norm floor is active: â = a / 1e-8, derivative is linear
            for j in 0..h {
                dst[j] = inv_b * (tr[j] / nt) / na;
            }
        }
    }
    total * inv_b
}

/// Batch-mean row cosine `mean_i cos(a_i, t_i)` with the target rows
/// treated as constants (SimSiam's stop-gradient); returns (cos, da).
/// Row norms are floored at 1e-8 like the python side.
pub fn cosine_mean_sg(a: &[f32], target: &[f32], b: usize, h: usize) -> (f32, Vec<f32>) {
    let mut da = vec![0.0f32; b * h];
    let cos = cosine_core(a, target, b, h, &mut da);
    (cos, da)
}

/// Linear CKA between (B, H) feature maps: `||YᵀX||_F² / (||XᵀX||_F ||YᵀY||_F)`.
pub fn cka(x: &[f32], y: &[f32], b: usize, h: usize) -> f32 {
    debug_assert_eq!(x.len(), b * h);
    debug_assert_eq!(y.len(), b * h);
    // gram(aᵀc) entries accumulated column-by-column; h×h is tiny here.
    let mut cross2 = 0.0f32;
    let mut selfx2 = 0.0f32;
    let mut selfy2 = 0.0f32;
    for p in 0..h {
        for q in 0..h {
            let mut yx = 0.0f32;
            let mut xx = 0.0f32;
            let mut yy = 0.0f32;
            for i in 0..b {
                let xv_p = x[i * h + p];
                let xv_q = x[i * h + q];
                let yv_p = y[i * h + p];
                let yv_q = y[i * h + q];
                yx += yv_p * xv_q;
                xx += xv_p * xv_q;
                yy += yv_p * yv_q;
            }
            cross2 += yx * yx;
            selfx2 += xx * xx;
            selfy2 += yy * yy;
        }
    }
    let denom = selfx2.sqrt() * selfy2.sqrt();
    cross2 / denom.max(1e-12)
}

// ---------------------------------------------------------------------------
// the model family
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    ReluRes,
    Bottleneck,
    PrelnGelu,
}

impl Kind {
    pub fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "relu_res" => Kind::ReluRes,
            "bottleneck" => Kind::Bottleneck,
            "preln_gelu" => Kind::PrelnGelu,
            other => anyhow::bail!("unknown model kind {other:?}"),
        })
    }
}

#[derive(Clone, Copy, Debug)]
struct BlockOff {
    ln_s: usize,
    ln_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
}

/// Manifest-bound executor for one model: flat-θ offsets + dimensions.
pub struct RefModel {
    pub kind: Kind,
    pub d: usize,
    pub h: usize,
    pub e: usize,
    pub blocks: usize,
    pub classes: usize,
    pub theta_len: usize,
    embed_w: usize,
    embed_b: usize,
    block_off: Vec<BlockOff>,
    head_w: usize,
    head_b: usize,
    /// (offset, len, unit) per tensor — lr-mask expansion.
    mask_segments: Vec<(usize, usize, usize)>,
}

enum BlockTape<'a> {
    ReluRes { d1: DenseTape<'a>, d2: DenseTape<'a> },
    Bottleneck { d1: DenseTape<'a>, d2: DenseTape<'a> },
    Preln { x_in: XBuf<'a>, ln: LnTape, d1: DenseTape<'a>, d2: DenseTape<'a> },
}

impl<'a> BlockTape<'a> {
    /// The block's *input* activation (= previous unit's output), which
    /// doubles as the previous unit's ReLU mask / residual operand.
    fn first_x(&self) -> &[f32] {
        match self {
            BlockTape::ReluRes { d1, .. } | BlockTape::Bottleneck { d1, .. } => d1.x_orig(),
            BlockTape::Preln { x_in, .. } => x_in.as_slice(),
        }
    }

    fn recycle(self, pool: &mut Arena) {
        match self {
            BlockTape::ReluRes { d1, d2 } | BlockTape::Bottleneck { d1, d2 } => {
                d1.recycle(pool);
                d2.recycle(pool);
            }
            BlockTape::Preln { x_in, ln, d1, d2 } => {
                x_in.recycle(pool);
                ln.recycle(pool);
                d1.recycle(pool);
                d2.recycle(pool);
            }
        }
    }
}

struct ModelTape<'a> {
    embed: DenseTape<'a>,
    blocks: Vec<BlockTape<'a>>,
    head: Option<DenseTape<'a>>,
}

impl<'a> ModelTape<'a> {
    fn recycle(self, pool: &mut Arena) {
        self.embed.recycle(pool);
        for b in self.blocks {
            b.recycle(pool);
        }
        if let Some(h) = self.head {
            h.recycle(pool);
        }
    }
}

impl RefModel {
    pub fn from_manifest(m: &ModelManifest) -> Result<RefModel> {
        let kind = Kind::parse(&m.kind)?;
        let find = |name: &str| -> Result<(usize, Vec<usize>)> {
            m.tensors
                .iter()
                .find(|t| t.name == name)
                .map(|t| (t.offset, t.shape.clone()))
                .ok_or_else(|| anyhow::anyhow!("{}: manifest lacks tensor {name:?}", m.name))
        };
        let (embed_w, ew_shape) = find("embed.w")?;
        anyhow::ensure!(
            ew_shape == vec![m.d, m.h],
            "{}: embed.w shape {ew_shape:?} != [{}, {}]",
            m.name,
            m.d,
            m.h
        );
        let (embed_b, _) = find("embed.b")?;
        let mut e = m.h;
        let mut block_off = Vec::with_capacity(m.blocks);
        for i in 1..=m.blocks {
            let p = format!("block{i}.");
            let (w1, w1_shape) = find(&format!("{p}w1"))?;
            anyhow::ensure!(w1_shape.len() == 2 && w1_shape[0] == m.h, "{}: bad w1 shape", m.name);
            e = w1_shape[1];
            let (b1, _) = find(&format!("{p}b1"))?;
            let (w2, _) = find(&format!("{p}w2"))?;
            let (b2, _) = find(&format!("{p}b2"))?;
            let (ln_s, ln_b) = if kind == Kind::PrelnGelu {
                (find(&format!("{p}ln_s"))?.0, find(&format!("{p}ln_b"))?.0)
            } else {
                (0, 0)
            };
            block_off.push(BlockOff { ln_s, ln_b, w1, b1, w2, b2 });
        }
        let (head_w, _) = find("head.w")?;
        let (head_b, _) = find("head.b")?;
        let mask_segments = m
            .tensors
            .iter()
            .map(|t| (t.offset, t.size(), t.unit))
            .collect();
        Ok(RefModel {
            kind,
            d: m.d,
            h: m.h,
            e,
            blocks: m.blocks,
            classes: m.classes,
            theta_len: m.theta_len,
            embed_w,
            embed_b,
            block_off,
            head_w,
            head_b,
            mask_segments,
        })
    }

    fn slice<'t>(&self, theta: &'t [f32], off: usize, len: usize) -> &'t [f32] {
        &theta[off..off + len]
    }

    fn key(&self, src: u64, w_off: usize) -> DenseKey {
        DenseKey { src, w_off }
    }

    // -- inference-mode forward (no tape, no quant) -------------------------

    /// One block forward; consumes `hcur` (arena) and returns the block
    /// output (arena).
    fn block_infer(
        &self,
        theta: &[f32],
        o: &BlockOff,
        hcur: Vec<f32>,
        b: usize,
        src: u64,
        ctx: &mut Ctx,
    ) -> Vec<f32> {
        let (h, e) = (self.h, self.e);
        match self.kind {
            Kind::ReluRes | Kind::Bottleneck => {
                let mut mid = ctx.pool.take(b * e);
                let pan1 = ctx.packs.fwd(src, o.w1, self.slice(theta, o.w1, h * e), h, e, false);
                gemm::gemm_fwd(&hcur, pan1, self.slice(theta, o.b1, e), b, Act::Relu, &mut mid);
                let mut out = ctx.pool.take(b * h);
                let pan2 = ctx.packs.fwd(src, o.w2, self.slice(theta, o.w2, e * h), e, h, false);
                gemm::gemm_fwd(&mid, pan2, self.slice(theta, o.b2, h), b, Act::None, &mut out);
                ctx.pool.give(mid);
                if self.kind == Kind::ReluRes {
                    for (ov, &a) in out.iter_mut().zip(&hcur) {
                        *ov = (a + *ov).max(0.0);
                    }
                } else {
                    for (ov, &a) in out.iter_mut().zip(&hcur) {
                        *ov = a + *ov;
                    }
                }
                ctx.pool.give(hcur);
                out
            }
            Kind::PrelnGelu => {
                let mut ln = ctx.pool.take(b * h);
                layernorm_infer(
                    &hcur,
                    self.slice(theta, o.ln_s, h),
                    self.slice(theta, o.ln_b, h),
                    b,
                    h,
                    &mut ln,
                );
                let mut mid = ctx.pool.take(b * e);
                let pan1 = ctx.packs.fwd(src, o.w1, self.slice(theta, o.w1, h * e), h, e, false);
                gemm::gemm_fwd(&ln, pan1, self.slice(theta, o.b1, e), b, Act::Gelu, &mut mid);
                ctx.pool.give(ln);
                let mut out = ctx.pool.take(b * h);
                let pan2 = ctx.packs.fwd(src, o.w2, self.slice(theta, o.w2, e * h), e, h, false);
                gemm::gemm_fwd(&mid, pan2, self.slice(theta, o.b2, h), b, Act::None, &mut out);
                ctx.pool.give(mid);
                for (ov, &a) in out.iter_mut().zip(&hcur) {
                    *ov = a + *ov;
                }
                ctx.pool.give(hcur);
                out
            }
        }
    }

    /// Embed forward into an arena buffer.
    fn embed_infer(&self, theta: &[f32], x: &[f32], b: usize, src: u64, ctx: &mut Ctx) -> Vec<f32> {
        let (d, h) = (self.d, self.h);
        let mut hcur = ctx.pool.take(b * h);
        let pan = ctx
            .packs
            .fwd(src, self.embed_w, self.slice(theta, self.embed_w, d * h), d, h, false);
        gemm::gemm_fwd(x, pan, self.slice(theta, self.embed_b, h), b, Act::Relu, &mut hcur);
        hcur
    }

    /// Forward pass: logits `[b, classes]` (escaping buffer — moved into
    /// the output literal by the backend).
    pub fn infer(&self, theta: &[f32], x: &[f32], b: usize, src: u64, ctx: &mut Ctx) -> Vec<f32> {
        let h = self.h;
        let mut hcur = self.embed_infer(theta, x, b, src, ctx);
        for o in &self.block_off {
            hcur = self.block_infer(theta, o, hcur, b, src, ctx);
        }
        let mut logits = vec![0.0f32; b * self.classes];
        let pan = ctx.packs.fwd(
            src,
            self.head_w,
            self.slice(theta, self.head_w, h * self.classes),
            h,
            self.classes,
            false,
        );
        gemm::gemm_fwd(
            &hcur,
            pan,
            self.slice(theta, self.head_b, self.classes),
            b,
            Act::None,
            &mut logits,
        );
        ctx.pool.give(hcur);
        logits
    }

    /// Pre-pack the forward panels of every dense layer for this θ
    /// buffer (the serving-side "install packs with the CWR bank" hook):
    /// after a warm call, steady-state inference on the same buf id
    /// never packs.
    pub fn warm_infer(&self, theta: &[f32], src: u64, ctx: &mut Ctx) {
        let (d, h, e) = (self.d, self.h, self.e);
        ctx.packs
            .fwd(src, self.embed_w, self.slice(theta, self.embed_w, d * h), d, h, false);
        for o in &self.block_off {
            ctx.packs.fwd(src, o.w1, self.slice(theta, o.w1, h * e), h, e, false);
            ctx.packs.fwd(src, o.w2, self.slice(theta, o.w2, e * h), e, h, false);
        }
        ctx.packs.fwd(
            src,
            self.head_w,
            self.slice(theta, self.head_w, h * self.classes),
            h,
            self.classes,
            false,
        );
    }

    /// Per-unit feature maps `[blocks+1, b, h]` (embed output + each block
    /// output; the head has no feature map).  Escaping buffer.
    pub fn features(&self, theta: &[f32], x: &[f32], b: usize, src: u64, ctx: &mut Ctx) -> Vec<f32> {
        let h = self.h;
        let mut out = Vec::with_capacity((self.blocks + 1) * b * h);
        let mut hcur = self.embed_infer(theta, x, b, src, ctx);
        out.extend_from_slice(&hcur);
        for o in &self.block_off {
            hcur = self.block_infer(theta, o, hcur, b, src, ctx);
            out.extend_from_slice(&hcur);
        }
        ctx.pool.give(hcur);
        out
    }

    // -- training-mode forward/backward -------------------------------------

    fn forward_train<'a>(
        &self,
        theta: &[f32],
        x: &'a [f32],
        b: usize,
        quant: bool,
        with_head: bool,
        src: u64,
        ctx: &mut Ctx,
    ) -> (Vec<f32>, ModelTape<'a>) {
        let (d, h, e) = (self.d, self.h, self.e);
        let (mut hcur, embed) = dense_train(
            XBuf::Borrowed(x),
            self.slice(theta, self.embed_w, d * h),
            self.slice(theta, self.embed_b, h),
            b,
            d,
            h,
            Act::Relu,
            quant,
            self.key(src, self.embed_w),
            ctx,
        );
        let mut blocks = Vec::with_capacity(self.blocks);
        for o in &self.block_off {
            match self.kind {
                Kind::ReluRes | Kind::Bottleneck => {
                    let (mid, d1) = dense_train(
                        XBuf::Pooled(hcur),
                        self.slice(theta, o.w1, h * e),
                        self.slice(theta, o.b1, e),
                        b,
                        h,
                        e,
                        Act::Relu,
                        quant,
                        self.key(src, o.w1),
                        ctx,
                    );
                    let (out, d2) = dense_train(
                        XBuf::Pooled(mid),
                        self.slice(theta, o.w2, e * h),
                        self.slice(theta, o.b2, h),
                        b,
                        e,
                        h,
                        Act::None,
                        quant,
                        self.key(src, o.w2),
                        ctx,
                    );
                    // residual add reads the block input from the tape
                    // (moved, not copied): h' = hcur + out (+ outer relu).
                    let mut hnew = ctx.pool.take(b * h);
                    let prev = d1.x_orig();
                    if self.kind == Kind::ReluRes {
                        for ((nv, &a), &v) in hnew.iter_mut().zip(prev).zip(&out) {
                            *nv = (a + v).max(0.0);
                        }
                        blocks.push(BlockTape::ReluRes { d1, d2 });
                    } else {
                        for ((nv, &a), &v) in hnew.iter_mut().zip(prev).zip(&out) {
                            *nv = a + v;
                        }
                        blocks.push(BlockTape::Bottleneck { d1, d2 });
                    }
                    ctx.pool.give(out);
                    hcur = hnew;
                }
                Kind::PrelnGelu => {
                    let (ln_out, ln) = layernorm_fwd_pooled(
                        &hcur,
                        self.slice(theta, o.ln_s, h),
                        self.slice(theta, o.ln_b, h),
                        b,
                        h,
                        ctx.pool,
                    );
                    let (mid, d1) = dense_train(
                        XBuf::Pooled(ln_out),
                        self.slice(theta, o.w1, h * e),
                        self.slice(theta, o.b1, e),
                        b,
                        h,
                        e,
                        Act::Gelu,
                        quant,
                        self.key(src, o.w1),
                        ctx,
                    );
                    let (out, d2) = dense_train(
                        XBuf::Pooled(mid),
                        self.slice(theta, o.w2, e * h),
                        self.slice(theta, o.b2, h),
                        b,
                        e,
                        h,
                        Act::None,
                        quant,
                        self.key(src, o.w2),
                        ctx,
                    );
                    let mut hnew = ctx.pool.take(b * h);
                    for ((nv, &a), &v) in hnew.iter_mut().zip(&hcur).zip(&out) {
                        *nv = a + v;
                    }
                    ctx.pool.give(out);
                    let x_in = XBuf::Pooled(hcur);
                    hcur = hnew;
                    blocks.push(BlockTape::Preln { x_in, ln, d1, d2 });
                }
            }
        }
        if with_head {
            let (logits, head) = dense_train(
                XBuf::Pooled(hcur),
                self.slice(theta, self.head_w, h * self.classes),
                self.slice(theta, self.head_b, self.classes),
                b,
                h,
                self.classes,
                Act::None,
                quant,
                self.key(src, self.head_w),
                ctx,
            );
            (logits, ModelTape { embed, blocks, head: Some(head) })
        } else {
            (hcur, ModelTape { embed, blocks, head: None })
        }
    }

    /// Reverse pass: accumulate ∂loss/∂θ into `dtheta` given the cotangent
    /// of the tape's output (`dout` = dlogits with a head, d_backbone
    /// features without) and `last_out`, the final backbone activation
    /// (the head's input, or the ssl projector's input) — needed because
    /// ReLU masks are read from downstream inputs, never copied.
    fn backward(
        &self,
        theta: &[f32],
        tape: &ModelTape,
        dout: &[f32],
        last_out: &[f32],
        dtheta: &mut [f32],
        ctx: &mut Ctx,
    ) {
        let h = self.h;
        let mut dh: Vec<f32>;
        if let Some(head) = &tape.head {
            dh = dense_bwd(
                head,
                dout,
                None,
                self.slice(theta, self.head_w, h * self.classes),
                dtheta,
                self.head_w,
                self.head_b,
                true,
                ctx,
            );
        } else {
            dh = ctx.pool.take(dout.len());
            dh.copy_from_slice(dout);
        }
        let nblocks = tape.blocks.len();
        for (bi, (o, bt)) in self.block_off.iter().zip(&tape.blocks).enumerate().rev() {
            // this block's *output* = the next unit's input
            let block_out: &[f32] = if bi + 1 < nblocks {
                tape.blocks[bi + 1].first_x()
            } else {
                last_out
            };
            match bt {
                BlockTape::ReluRes { d1, d2 } => {
                    // outer relu is jnp.maximum(sum, 0): ties route half.
                    let mut dsum = ctx.pool.take(dh.len());
                    for ((s, &g), &ov) in dsum.iter_mut().zip(&dh).zip(block_out) {
                        *s = if ov > 0.0 {
                            g
                        } else if ov == 0.0 {
                            0.5 * g
                        } else {
                            0.0
                        };
                    }
                    ctx.pool.give(std::mem::take(&mut dh));
                    let g2dx = dense_bwd(
                        d2,
                        &dsum,
                        None,
                        self.slice(theta, o.w2, self.e * h),
                        dtheta,
                        o.w2,
                        o.b2,
                        true,
                        ctx,
                    );
                    let g1dx = dense_bwd(
                        d1,
                        &g2dx,
                        Some(d2.x_orig()),
                        self.slice(theta, o.w1, h * self.e),
                        dtheta,
                        o.w1,
                        o.b1,
                        true,
                        ctx,
                    );
                    ctx.pool.give(g2dx);
                    for (s, &g) in dsum.iter_mut().zip(&g1dx) {
                        *s += g;
                    }
                    ctx.pool.give(g1dx);
                    dh = dsum;
                }
                BlockTape::Bottleneck { d1, d2 } => {
                    let g2dx = dense_bwd(
                        d2,
                        &dh,
                        None,
                        self.slice(theta, o.w2, self.e * h),
                        dtheta,
                        o.w2,
                        o.b2,
                        true,
                        ctx,
                    );
                    let g1dx = dense_bwd(
                        d1,
                        &g2dx,
                        Some(d2.x_orig()),
                        self.slice(theta, o.w1, h * self.e),
                        dtheta,
                        o.w1,
                        o.b1,
                        true,
                        ctx,
                    );
                    ctx.pool.give(g2dx);
                    for (s, &g) in dh.iter_mut().zip(&g1dx) {
                        *s += g;
                    }
                    ctx.pool.give(g1dx);
                }
                BlockTape::Preln { ln, d1, d2, .. } => {
                    let g2dx = dense_bwd(
                        d2,
                        &dh,
                        None,
                        self.slice(theta, o.w2, self.e * h),
                        dtheta,
                        o.w2,
                        o.b2,
                        true,
                        ctx,
                    );
                    let g1dx = dense_bwd(
                        d1,
                        &g2dx,
                        None,
                        self.slice(theta, o.w1, h * self.e),
                        dtheta,
                        o.w1,
                        o.b1,
                        true,
                        ctx,
                    );
                    ctx.pool.give(g2dx);
                    let mut dx_ln = ctx.pool.take(dh.len());
                    let mut ds = ctx.pool.take_zeroed(h);
                    let mut db = ctx.pool.take_zeroed(h);
                    layernorm_bwd_core(
                        ln,
                        self.slice(theta, o.ln_s, h),
                        &g1dx,
                        &mut dx_ln,
                        &mut ds,
                        &mut db,
                    );
                    ctx.pool.give(g1dx);
                    accumulate(dtheta, o.ln_s, &ds);
                    accumulate(dtheta, o.ln_b, &db);
                    ctx.pool.give(ds);
                    ctx.pool.give(db);
                    for (s, &g) in dh.iter_mut().zip(&dx_ln) {
                        *s += g;
                    }
                    ctx.pool.give(dx_ln);
                }
            }
        }
        // embed: dw/db only — the seed computed dx here and threw it away.
        let embed_out: &[f32] = tape
            .blocks
            .first()
            .map(BlockTape::first_x)
            .unwrap_or(last_out);
        let gdx = dense_bwd(
            &tape.embed,
            &dh,
            Some(embed_out),
            self.slice(theta, self.embed_w, self.d * h),
            dtheta,
            self.embed_w,
            self.embed_b,
            false,
            ctx,
        );
        debug_assert!(gdx.is_empty());
        drop(gdx);
        ctx.pool.give(dh);
    }

    /// Expand the per-unit lr mask over the flat gradient (mask *before*
    /// clip, exactly like `train_fn` in model.py — this is also what makes
    /// prefix truncation and lr-mask freezing produce identical surviving
    /// updates, so the `k` of a `train_k` segment never changes the math).
    fn apply_mask(&self, g: &mut [f32], lr_mask: &[f32]) {
        for &(off, len, unit) in &self.mask_segments {
            let mv = lr_mask[unit];
            if mv == 1.0 {
                continue;
            }
            for v in &mut g[off..off + len] {
                *v *= mv;
            }
        }
    }

    /// One SGD step (the `train_k` / `train_q_k` segments); returns
    /// `(θ', loss)` — θ' is an escaping buffer the backend moves into the
    /// output literal.
    pub fn train_step(
        &self,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
        b: usize,
        lr_mask: &[f32],
        lr: f32,
        quant: bool,
        src: u64,
        ctx: &mut Ctx,
    ) -> (Vec<f32>, f32) {
        let (logits, tape) = self.forward_train(theta, x, b, quant, true, src, ctx);
        let mut dlogits = ctx.pool.take(b * self.classes);
        let loss = ce_core(&logits, y, b, self.classes, &mut dlogits);
        let mut g = ctx.pool.take_zeroed(self.theta_len);
        let last_out = tape.head.as_ref().unwrap().x_orig();
        self.backward(theta, &tape, &dlogits, last_out, &mut g, ctx);
        self.apply_mask(&mut g, lr_mask);
        clip_global(&mut g, MAX_GRAD_NORM);
        let theta_new: Vec<f32> =
            theta.iter().zip(&g).map(|(&t, &gv)| t - lr * gv).collect();
        ctx.pool.give(logits);
        ctx.pool.give(dlogits);
        ctx.pool.give(g);
        tape.recycle(ctx.pool);
        (theta_new, loss)
    }

    /// One SimSiam step (the `ssl` segment); φ layout is
    /// `[proj.w (h,h), proj.b (h), pred.w (h,h), pred.b (h)]`.
    /// Returns `(θ', φ', loss)`.
    pub fn ssl_step(
        &self,
        theta: &[f32],
        phi: &[f32],
        x1: &[f32],
        x2: &[f32],
        b: usize,
        lr_mask: &[f32],
        lr: f32,
        theta_src: u64,
        phi_src: u64,
        ctx: &mut Ctx,
    ) -> (Vec<f32>, Vec<f32>, f32) {
        let h = self.h;
        let (proj_w, proj_b) = (0, h * h);
        let (pred_w, pred_b) = (h * h + h, 2 * h * h + h);
        debug_assert_eq!(phi.len(), 2 * h * h + 2 * h);

        let (bb1, tape1) = self.forward_train(theta, x1, b, false, false, theta_src, ctx);
        let (bb2, tape2) = self.forward_train(theta, x2, b, false, false, theta_src, ctx);
        let (z1, pj1) = dense_train(
            XBuf::Pooled(bb1),
            &phi[proj_w..proj_w + h * h],
            &phi[proj_b..proj_b + h],
            b,
            h,
            h,
            Act::None,
            false,
            DenseKey { src: phi_src, w_off: proj_w },
            ctx,
        );
        let (z2, pj2) = dense_train(
            XBuf::Pooled(bb2),
            &phi[proj_w..proj_w + h * h],
            &phi[proj_b..proj_b + h],
            b,
            h,
            h,
            Act::None,
            false,
            DenseKey { src: phi_src, w_off: proj_w },
            ctx,
        );
        let (p1, pd1) = dense_train(
            XBuf::Pooled(z1),
            &phi[pred_w..pred_w + h * h],
            &phi[pred_b..pred_b + h],
            b,
            h,
            h,
            Act::None,
            false,
            DenseKey { src: phi_src, w_off: pred_w },
            ctx,
        );
        let (p2, pd2) = dense_train(
            XBuf::Pooled(z2),
            &phi[pred_w..pred_w + h * h],
            &phi[pred_b..pred_b + h],
            b,
            h,
            h,
            Act::None,
            false,
            DenseKey { src: phi_src, w_off: pred_w },
            ctx,
        );

        // loss = -(cos(p1, sg(z2)) + cos(p2, sg(z1))) / 2
        let mut dp1 = ctx.pool.take(b * h);
        let mut dp2 = ctx.pool.take(b * h);
        let c1 = cosine_core(&p1, pd2.x_orig(), b, h, &mut dp1);
        let c2 = cosine_core(&p2, pd1.x_orig(), b, h, &mut dp2);
        let loss = -(c1 + c2) / 2.0;
        dp1.iter_mut().for_each(|v| *v *= -0.5);
        dp2.iter_mut().for_each(|v| *v *= -0.5);
        ctx.pool.give(p1);
        ctx.pool.give(p2);

        let mut gphi = ctx.pool.take_zeroed(phi.len());
        let mut gtheta = ctx.pool.take_zeroed(self.theta_len);
        // branch 1: p1 <- pred(z1) <- proj(bb1) <- backbone(x1)
        let g_pd1 = dense_bwd(
            &pd1,
            &dp1,
            None,
            &phi[pred_w..pred_w + h * h],
            &mut gphi,
            pred_w,
            pred_b,
            true,
            ctx,
        );
        let g_pj1 = dense_bwd(
            &pj1,
            &g_pd1,
            None,
            &phi[proj_w..proj_w + h * h],
            &mut gphi,
            proj_w,
            proj_b,
            true,
            ctx,
        );
        ctx.pool.give(g_pd1);
        self.backward(theta, &tape1, &g_pj1, pj1.x_orig(), &mut gtheta, ctx);
        ctx.pool.give(g_pj1);
        // branch 2: p2 <- pred(z2) <- proj(bb2) <- backbone(x2)
        let g_pd2 = dense_bwd(
            &pd2,
            &dp2,
            None,
            &phi[pred_w..pred_w + h * h],
            &mut gphi,
            pred_w,
            pred_b,
            true,
            ctx,
        );
        let g_pj2 = dense_bwd(
            &pj2,
            &g_pd2,
            None,
            &phi[proj_w..proj_w + h * h],
            &mut gphi,
            proj_w,
            proj_b,
            true,
            ctx,
        );
        ctx.pool.give(g_pd2);
        self.backward(theta, &tape2, &g_pj2, pj2.x_orig(), &mut gtheta, ctx);
        ctx.pool.give(g_pj2);
        ctx.pool.give(dp1);
        ctx.pool.give(dp2);

        self.apply_mask(&mut gtheta, lr_mask);
        clip_global(&mut gtheta, MAX_GRAD_NORM);
        clip_global(&mut gphi, MAX_GRAD_NORM);
        let theta_new: Vec<f32> =
            theta.iter().zip(&gtheta).map(|(&t, &g)| t - lr * g).collect();
        let phi_new: Vec<f32> =
            phi.iter().zip(&gphi).map(|(&p, &g)| p - lr * g).collect();
        ctx.pool.give(gtheta);
        ctx.pool.give(gphi);
        pd1.recycle(ctx.pool);
        pd2.recycle(ctx.pool);
        pj1.recycle(ctx.pool);
        pj2.recycle(ctx.pool);
        tape1.recycle(ctx.pool);
        tape2.recycle(ctx.pool);
        (theta_new, phi_new, loss)
    }
}

fn accumulate(dst: &mut [f32], off: usize, src: &[f32]) {
    for (o, &s) in dst[off..off + src.len()].iter_mut().zip(src) {
        *o += s;
    }
}

// ---------------------------------------------------------------------------
// tests — hand-derived VJPs checked against central finite differences,
// plus exact identities the packed tape path must satisfy
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    /// Fresh arena + pack cache for one kernel invocation.
    struct Rig {
        pool: Arena,
        packs: PackCache,
    }

    impl Rig {
        fn new() -> Rig {
            Rig { pool: Arena::new(), packs: PackCache::new() }
        }

        fn ctx(&mut self) -> Ctx<'_> {
            Ctx { pool: &mut self.pool, packs: &mut self.packs }
        }
    }

    fn randv(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    /// Dense forward through the packed path (no tape).
    fn dense_out(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize, act: Act) -> Vec<f32> {
        let pan = gemm::pack_w(w, k, n, false);
        let mut out = vec![0.0f32; m * n];
        gemm::gemm_fwd(x, &pan, b, m, act, &mut out);
        out
    }

    /// Scalar objective: sum of `cot * dense_out` (a fixed linear
    /// functional so the cotangent is the weight vector).
    fn dense_obj(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize, act: Act, cot: &[f32]) -> f32 {
        dense_out(x, w, b, m, k, n, act)
            .iter()
            .zip(cot)
            .map(|(&o, &c)| o * c)
            .sum()
    }

    /// Full dense VJP through the tape path; returns (dx, dw, db).
    fn dense_grads(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        act: Act,
        cot: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rig = Rig::new();
        let mut ctx = rig.ctx();
        let (out, tape) = dense_train(
            XBuf::Borrowed(x),
            w,
            b,
            m,
            k,
            n,
            act,
            false,
            DenseKey { src: 1, w_off: 0 },
            &mut ctx,
        );
        let mut dparams = vec![0.0f32; k * n + n];
        let dx = dense_bwd(&tape, cot, Some(&out), w, &mut dparams, 0, k * n, true, &mut ctx);
        let dw = dparams[..k * n].to_vec();
        let db = dparams[k * n..].to_vec();
        (dx, dw, db)
    }

    #[test]
    fn dense_relu_bwd_equals_masked_linear_bwd() {
        // exact identity (no finite differences across the kink): the ReLU
        // VJP is the linear VJP with the cotangent masked by `out > 0`.
        let (m, k, n) = (3, 4, 5);
        let mut rng = Pcg32::new(13, 3);
        let x = randv(&mut rng, m * k, 1.0);
        let w = randv(&mut rng, k * n, 0.5);
        let b = randv(&mut rng, n, 0.2);
        let cot = randv(&mut rng, m * n, 1.0);
        let out = dense_out(&x, &w, &b, m, k, n, Act::Relu);
        let z = dense_out(&x, &w, &b, m, k, n, Act::None);
        assert!(out.iter().zip(&z).all(|(&o, &zv)| o == zv.max(0.0)));
        let masked: Vec<f32> = cot
            .iter()
            .zip(&z)
            .map(|(&c, &zv)| if zv > 0.0 { c } else { 0.0 })
            .collect();
        let gr = dense_grads(&x, &w, &b, m, k, n, Act::Relu, &cot);
        let gn = dense_grads(&x, &w, &b, m, k, n, Act::None, &masked);
        assert_eq!(gr, gn);
    }

    #[test]
    fn dense_bwd_matches_finite_differences() {
        for act in [Act::None, Act::Gelu] {
            let (m, k, n) = (3, 4, 5);
            let mut rng = Pcg32::new(11, 3);
            let x = randv(&mut rng, m * k, 1.0);
            let w = randv(&mut rng, k * n, 0.5);
            let b = randv(&mut rng, n, 0.2);
            let cot = randv(&mut rng, m * n, 1.0);
            let (dx, dw, _db) = dense_grads(&x, &w, &b, m, k, n, act, &cot);
            let eps = 1e-3f32;
            for idx in 0..k * n {
                let mut wp = w.clone();
                let mut wm = w.clone();
                wp[idx] += eps;
                wm[idx] -= eps;
                let fd = (dense_obj(&x, &wp, &b, m, k, n, act, &cot)
                    - dense_obj(&x, &wm, &b, m, k, n, act, &cot))
                    / (2.0 * eps);
                assert!(
                    (fd - dw[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dw[{idx}]: fd {fd} vs {g}",
                    g = dw[idx]
                );
            }
            for idx in 0..m * k {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[idx] += eps;
                xm[idx] -= eps;
                let fd = (dense_obj(&xp, &w, &b, m, k, n, act, &cot)
                    - dense_obj(&xm, &w, &b, m, k, n, act, &cot))
                    / (2.0 * eps);
                assert!(
                    (fd - dx[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dx[{idx}]: fd {fd} vs {g}",
                    g = dx[idx]
                );
            }
        }
    }

    #[test]
    fn layernorm_bwd_matches_finite_differences() {
        let (m, h) = (2, 6);
        let mut rng = Pcg32::new(21, 5);
        let x = randv(&mut rng, m * h, 1.0);
        let s = randv(&mut rng, h, 0.5);
        let bb = randv(&mut rng, h, 0.3);
        let cot = randv(&mut rng, m * h, 1.0);
        let obj = |xv: &[f32]| -> f32 {
            let (out, _) = layernorm_fwd(xv, &s, &bb, m, h);
            out.iter().zip(&cot).map(|(&o, &c)| o * c).sum()
        };
        let (_, tape) = layernorm_fwd(&x, &s, &bb, m, h);
        let (dx, ds, db) = layernorm_bwd(&tape, &s, &cot);
        let eps = 1e-3f32;
        for idx in 0..m * h {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[idx] += eps;
            xm[idx] -= eps;
            let fd = (obj(&xp) - obj(&xm)) / (2.0 * eps);
            assert!(
                (fd - dx[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "dx[{idx}]: fd {fd} vs {}",
                dx[idx]
            );
        }
        // affine params: ds = Σ cot*normed, db = Σ cot (checked directly)
        for j in 0..h {
            let want_db: f32 = (0..m).map(|i| cot[i * h + j]).sum();
            assert!((db[j] - want_db).abs() < 1e-5);
        }
        assert_eq!(ds.len(), h);
    }

    #[test]
    fn layernorm_infer_matches_tape_forward() {
        let (m, h) = (3, 8);
        let mut rng = Pcg32::new(22, 6);
        let x = randv(&mut rng, m * h, 1.0);
        let s = randv(&mut rng, h, 0.5);
        let bb = randv(&mut rng, h, 0.3);
        let (want, _) = layernorm_fwd(&x, &s, &bb, m, h);
        let mut got = vec![0.0f32; m * h];
        layernorm_infer(&x, &s, &bb, m, h, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn ce_grad_matches_finite_differences() {
        let (b, c) = (4, 5);
        let mut rng = Pcg32::new(31, 7);
        let logits = randv(&mut rng, b * c, 2.0);
        let y: Vec<i32> = (0..b).map(|i| (i % c) as i32).collect();
        let (loss, dl) = ce_loss_and_grad(&logits, &y, b, c);
        assert!(loss > 0.0 && loss.is_finite());
        let eps = 1e-3f32;
        for idx in 0..b * c {
            let mut lp = logits.clone();
            let mut lm = logits.clone();
            lp[idx] += eps;
            lm[idx] -= eps;
            let fd = (ce_loss_and_grad(&lp, &y, b, c).0
                - ce_loss_and_grad(&lm, &y, b, c).0)
                / (2.0 * eps);
            assert!(
                (fd - dl[idx]).abs() < 1e-2 * (1.0 + fd.abs()),
                "dl[{idx}]: fd {fd} vs {}",
                dl[idx]
            );
        }
        // softmax-grad rows sum to ~0
        for i in 0..b {
            let s: f32 = dl[i * c..(i + 1) * c].iter().sum();
            assert!(s.abs() < 1e-5);
        }
    }

    #[test]
    fn cosine_grad_matches_finite_differences() {
        let (b, h) = (3, 6);
        let mut rng = Pcg32::new(41, 9);
        let a = randv(&mut rng, b * h, 1.0);
        let t = randv(&mut rng, b * h, 1.0);
        let (cos, da) = cosine_mean_sg(&a, &t, b, h);
        assert!(cos.abs() <= 1.0 + 1e-5);
        let eps = 1e-3f32;
        for idx in 0..b * h {
            let mut ap = a.clone();
            let mut am = a.clone();
            ap[idx] += eps;
            am[idx] -= eps;
            let fd = (cosine_mean_sg(&ap, &t, b, h).0
                - cosine_mean_sg(&am, &t, b, h).0)
                / (2.0 * eps);
            assert!(
                (fd - da[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "da[{idx}]: fd {fd} vs {}",
                da[idx]
            );
        }
    }

    #[test]
    fn gelu_prime_matches_finite_differences() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 3.0] {
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_prime(x)).abs() < 1e-3,
                "gelu'({x}): fd {fd} vs {}",
                gelu_prime(x)
            );
        }
    }

    #[test]
    fn clip_global_caps_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5 — exactly at the cap
        clip_global(&mut g, MAX_GRAD_NORM);
        assert_eq!(g, vec![3.0, 4.0]);
        let mut g = vec![30.0f32, 40.0]; // norm 50 -> scaled to 5
        clip_global(&mut g, MAX_GRAD_NORM);
        let norm = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((norm - 5.0).abs() < 1e-4);
    }

    #[test]
    fn cka_is_one_on_identical_features() {
        let mut rng = Pcg32::new(51, 2);
        let x = randv(&mut rng, 16 * 8, 1.0);
        let v = cka(&x, &x, 16, 8);
        assert!((v - 1.0).abs() < 1e-4, "cka(x,x) = {v}");
        let y = randv(&mut rng, 16 * 8, 1.0);
        let w = cka(&x, &y, 16, 8);
        assert!(w.is_finite() && w >= 0.0 && w < 1.0, "cka(x,y) = {w}");
    }

    #[test]
    fn qat_tape_contracts_against_quantized_tensors() {
        // STE: under quant, dw must equal xqᵀ·dz — i.e. the no-quant VJP
        // evaluated at the quantized tensors (bias untouched).
        let (m, k, n) = (4, 6, 7);
        let mut rng = Pcg32::new(61, 4);
        let x = randv(&mut rng, m * k, 1.0);
        let w = randv(&mut rng, k * n, 0.5);
        let b = randv(&mut rng, n, 0.2);
        let cot = randv(&mut rng, m * n, 1.0);
        let xq = super::super::naive::fake_quant(&x);
        let wq = super::super::naive::fake_quant(&w);

        let mut rig = Rig::new();
        let mut ctx = rig.ctx();
        let (out_q, tape_q) = dense_train(
            XBuf::Borrowed(&x),
            &w,
            &b,
            m,
            k,
            n,
            Act::None,
            true,
            DenseKey { src: 1, w_off: 0 },
            &mut ctx,
        );
        let mut dp_q = vec![0.0f32; k * n + n];
        let dx_q = dense_bwd(&tape_q, &cot, None, &w, &mut dp_q, 0, k * n, true, &mut ctx);

        let (out_r, tape_r) = dense_train(
            XBuf::Borrowed(&xq),
            &wq,
            &b,
            m,
            k,
            n,
            Act::None,
            false,
            DenseKey { src: 2, w_off: 0 },
            &mut ctx,
        );
        let mut dp_r = vec![0.0f32; k * n + n];
        let dx_r = dense_bwd(&tape_r, &cot, None, &wq, &mut dp_r, 0, k * n, true, &mut ctx);

        assert_eq!(out_q, out_r, "QAT forward != forward at quantized tensors");
        assert_eq!(dx_q, dx_r);
        assert_eq!(dp_q, dp_r);
    }
}
