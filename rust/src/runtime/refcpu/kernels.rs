//! Pure-Rust forward/backward kernels for the deployed model family.
//!
//! Implements, in plain f32 loops, the exact semantics the python side
//! lowers to HLO (see `python/compile/model.py` + `kernels/matmul.py`):
//! `act(x @ w + b)` dense layers with ReLU/tanh-GELU epilogues, the three
//! block kinds (`relu_res`, `bottleneck`, `preln_gelu`), LayerNorm, the
//! mean-CE loss with log-softmax, per-tensor symmetric fake-quantization
//! with a straight-through gradient, global-norm clipping at 5.0, the
//! SimSiam cosine loss, and the linear-CKA Gram statistic.
//!
//! Backward passes mirror the JAX `custom_vjp` rules one-to-one:
//! * dense ReLU uses the saved *output* mask (`dout * (out > 0)`);
//! * dense GELU pushes the cotangent through the tanh-approximation
//!   derivative at the saved pre-activation;
//! * the `relu_res` blocks' *outer* residual ReLU is `jnp.maximum`, whose
//!   tie case routes half the cotangent (`lax.max` JVP) — reproduced here
//!   so zero-initialized residual paths differentiate identically;
//! * fake-quant is a straight-through estimator: forward uses quantized
//!   values, backward treats the quantizer as identity, and downstream
//!   VJPs contract against the saved *quantized* tensors.
//!
//! Everything is sequential and allocation-order deterministic, so runs
//! are bit-identical across sweep worker counts.

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use crate::runtime::artifact::ModelManifest;

pub const MAX_GRAD_NORM: f32 = 5.0;
const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// elementwise pieces
// ---------------------------------------------------------------------------

/// tanh-approximation GELU (`jax.nn.gelu` with `approximate=True`).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    let u = C * (x + 0.044715 * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// d gelu / dx at pre-activation `x`.
pub fn gelu_prime(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Round half to even (numpy/jnp.round semantics, vs Rust's half-away).
fn round_ties_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            x.ceil()
        }
    } else {
        r
    }
}

/// Per-tensor symmetric 8-bit fake-quantization (forward values only; the
/// caller implements the straight-through gradient by saving the output).
pub fn fake_quant(v: &[f32]) -> Vec<f32> {
    let qmax = 127.0f32; // 2^(8-1) - 1
    let amax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = amax.max(1e-8) / qmax;
    v.iter()
        .map(|&x| round_ties_even(x / scale).clamp(-qmax, qmax) * scale)
        .collect()
}

/// In-place clip-by-global-norm (matches `_clip_global` in model.py).
pub fn clip_global(g: &mut [f32], max_norm: f32) {
    let norm = g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32;
    let scale = (max_norm / norm.max(1e-12)).min(1.0);
    if scale < 1.0 {
        for v in g.iter_mut() {
            *v *= scale;
        }
    }
}

// ---------------------------------------------------------------------------
// dense layer (act(x @ w + b)) with tape
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Gelu,
}

/// Saved residuals of one dense layer for its VJP: the input and weights
/// *as used* (quantized under QAT — that is what makes the backward a
/// straight-through estimator), plus the activation residual (`out` for
/// ReLU's mask, pre-activation `z` for GELU).
pub struct DenseTape {
    x: Vec<f32>,
    w: Vec<f32>,
    post: Vec<f32>,
    m: usize,
    k: usize,
    n: usize,
    act: Act,
}

pub struct DenseGrads {
    pub dx: Vec<f32>,
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x[i * k..(i + 1) * k];
        let dst = &mut out[i * n..(i + 1) * n];
        dst.copy_from_slice(b);
        for (t, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[t * n..(t + 1) * n];
            for (o, &wv) in dst.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// Inference-only dense: no tape, no quantization.
pub fn dense_infer(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize, act: Act) -> Vec<f32> {
    let mut out = matmul_bias(x, w, b, m, k, n);
    match act {
        Act::None => {}
        Act::Relu => out.iter_mut().for_each(|v| *v = v.max(0.0)),
        Act::Gelu => out.iter_mut().for_each(|v| *v = gelu(*v)),
    }
    out
}

/// Training dense: returns the activation output and the VJP tape.
pub fn dense_train(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    quant: bool,
) -> (Vec<f32>, DenseTape) {
    let (xq, wq) = if quant {
        (fake_quant(x), fake_quant(w))
    } else {
        (x.to_vec(), w.to_vec())
    };
    let z = matmul_bias(&xq, &wq, b, m, k, n);
    let (out, post) = match act {
        Act::None => (z, Vec::new()),
        Act::Relu => {
            let out: Vec<f32> = z.iter().map(|&v| v.max(0.0)).collect();
            (out.clone(), out)
        }
        Act::Gelu => {
            let out: Vec<f32> = z.iter().map(|&v| gelu(v)).collect();
            (out, z)
        }
    };
    (out, DenseTape { x: xq, w: wq, post, m, k, n, act })
}

/// Dense VJP: `dz` from the activation rule, then `dx = dz @ wᵀ`,
/// `dw = xᵀ @ dz`, `db = Σ_rows dz`.
pub fn dense_bwd(t: &DenseTape, dout: &[f32]) -> DenseGrads {
    let (m, k, n) = (t.m, t.k, t.n);
    debug_assert_eq!(dout.len(), m * n);
    let dz: Vec<f32> = match t.act {
        Act::None => dout.to_vec(),
        Act::Relu => dout
            .iter()
            .zip(&t.post)
            .map(|(&g, &o)| if o > 0.0 { g } else { 0.0 })
            .collect(),
        Act::Gelu => dout
            .iter()
            .zip(&t.post)
            .map(|(&g, &z)| g * gelu_prime(z))
            .collect(),
    };
    // dx[i,t] = Σ_j dz[i,j] * w[t,j]
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        let dzr = &dz[i * n..(i + 1) * n];
        let dst = &mut dx[i * k..(i + 1) * k];
        for tt in 0..k {
            let wrow = &t.w[tt * n..(tt + 1) * n];
            let mut acc = 0.0f32;
            for (g, wv) in dzr.iter().zip(wrow) {
                acc += g * wv;
            }
            dst[tt] = acc;
        }
    }
    // dw[t,j] = Σ_i x[i,t] * dz[i,j]
    let mut dw = vec![0.0f32; k * n];
    for i in 0..m {
        let xr = &t.x[i * k..(i + 1) * k];
        let dzr = &dz[i * n..(i + 1) * n];
        for (tt, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let dst = &mut dw[tt * n..(tt + 1) * n];
            for (o, &g) in dst.iter_mut().zip(dzr) {
                *o += xv * g;
            }
        }
    }
    let mut db = vec![0.0f32; n];
    for i in 0..m {
        for (o, &g) in db.iter_mut().zip(&dz[i * n..(i + 1) * n]) {
            *o += g;
        }
    }
    DenseGrads { dx, dw, db }
}

// ---------------------------------------------------------------------------
// LayerNorm
// ---------------------------------------------------------------------------

pub struct LnTape {
    normed: Vec<f32>,
    inv_std: Vec<f32>,
    m: usize,
    h: usize,
}

/// `out = normed(x) * s + b` per row; var is the biased mean of squares
/// (jnp.var), eps = 1e-5.
pub fn layernorm_fwd(x: &[f32], s: &[f32], b: &[f32], m: usize, h: usize) -> (Vec<f32>, LnTape) {
    let mut out = vec![0.0f32; m * h];
    let mut normed = vec![0.0f32; m * h];
    let mut inv_std = vec![0.0f32; m];
    for i in 0..m {
        let row = &x[i * h..(i + 1) * h];
        let mu = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let is = 1.0 / (var + LN_EPS).sqrt();
        inv_std[i] = is;
        for j in 0..h {
            let nv = (row[j] - mu) * is;
            normed[i * h + j] = nv;
            out[i * h + j] = nv * s[j] + b[j];
        }
    }
    (out, LnTape { normed, inv_std, m, h })
}

/// LayerNorm VJP: returns (dx, ds, db).
pub fn layernorm_bwd(t: &LnTape, s: &[f32], dout: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (m, h) = (t.m, t.h);
    let mut dx = vec![0.0f32; m * h];
    let mut ds = vec![0.0f32; h];
    let mut db = vec![0.0f32; h];
    for i in 0..m {
        let nrm = &t.normed[i * h..(i + 1) * h];
        let dor = &dout[i * h..(i + 1) * h];
        let mut mean_dn = 0.0f32;
        let mut mean_dn_n = 0.0f32;
        for j in 0..h {
            ds[j] += dor[j] * nrm[j];
            db[j] += dor[j];
            let dn = dor[j] * s[j];
            mean_dn += dn;
            mean_dn_n += dn * nrm[j];
        }
        mean_dn /= h as f32;
        mean_dn_n /= h as f32;
        let is = t.inv_std[i];
        for j in 0..h {
            let dn = dor[j] * s[j];
            dx[i * h + j] = is * (dn - mean_dn - nrm[j] * mean_dn_n);
        }
    }
    (dx, ds, db)
}

// ---------------------------------------------------------------------------
// losses
// ---------------------------------------------------------------------------

/// Mean cross-entropy over log-softmax rows; returns (loss, dlogits).
pub fn ce_loss_and_grad(logits: &[f32], y: &[i32], b: usize, c: usize) -> (f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * c);
    debug_assert_eq!(y.len(), b);
    let mut loss = 0.0f32;
    let mut dl = vec![0.0f32; b * c];
    let inv_b = 1.0 / b as f32;
    for i in 0..b {
        let row = &logits[i * c..(i + 1) * c];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let lse = mx + sum.ln();
        let yi = y[i] as usize;
        loss += lse - row[yi];
        let drow = &mut dl[i * c..(i + 1) * c];
        for j in 0..c {
            let p = (row[j] - lse).exp();
            drow[j] = (p - if j == yi { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    (loss * inv_b, dl)
}

/// Batch-mean row cosine `mean_i cos(a_i, t_i)` with the target rows
/// treated as constants (SimSiam's stop-gradient); returns (cos, da).
/// Row norms are floored at 1e-8 like the python side.
pub fn cosine_mean_sg(a: &[f32], target: &[f32], b: usize, h: usize) -> (f32, Vec<f32>) {
    let mut total = 0.0f32;
    let mut da = vec![0.0f32; b * h];
    let inv_b = 1.0 / b as f32;
    for i in 0..b {
        let ar = &a[i * h..(i + 1) * h];
        let tr = &target[i * h..(i + 1) * h];
        let na_raw = ar.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let nt_raw = tr.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let na = na_raw.max(1e-8);
        let nt = nt_raw.max(1e-8);
        let mut dot = 0.0f32;
        for j in 0..h {
            dot += (ar[j] / na) * (tr[j] / nt);
        }
        total += dot;
        let dst = &mut da[i * h..(i + 1) * h];
        if na_raw > 1e-8 {
            // d/da of (â · t̂) = (t̂ - dot · â) / ||a||
            for j in 0..h {
                dst[j] = inv_b * (tr[j] / nt - dot * ar[j] / na) / na;
            }
        } else {
            // the norm floor is active: â = a / 1e-8, derivative is linear
            for j in 0..h {
                dst[j] = inv_b * (tr[j] / nt) / na;
            }
        }
    }
    (total * inv_b, da)
}

/// Linear CKA between (B, H) feature maps: `||YᵀX||_F² / (||XᵀX||_F ||YᵀY||_F)`.
pub fn cka(x: &[f32], y: &[f32], b: usize, h: usize) -> f32 {
    debug_assert_eq!(x.len(), b * h);
    debug_assert_eq!(y.len(), b * h);
    // gram(aᵀc) entries accumulated column-by-column; h×h is tiny here.
    let mut cross2 = 0.0f32;
    let mut selfx2 = 0.0f32;
    let mut selfy2 = 0.0f32;
    for p in 0..h {
        for q in 0..h {
            let mut yx = 0.0f32;
            let mut xx = 0.0f32;
            let mut yy = 0.0f32;
            for i in 0..b {
                let xv_p = x[i * h + p];
                let xv_q = x[i * h + q];
                let yv_p = y[i * h + p];
                let yv_q = y[i * h + q];
                yx += yv_p * xv_q;
                xx += xv_p * xv_q;
                yy += yv_p * yv_q;
            }
            cross2 += yx * yx;
            selfx2 += xx * xx;
            selfy2 += yy * yy;
        }
    }
    let denom = selfx2.sqrt() * selfy2.sqrt();
    cross2 / denom.max(1e-12)
}

// ---------------------------------------------------------------------------
// the model family
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    ReluRes,
    Bottleneck,
    PrelnGelu,
}

impl Kind {
    pub fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "relu_res" => Kind::ReluRes,
            "bottleneck" => Kind::Bottleneck,
            "preln_gelu" => Kind::PrelnGelu,
            other => anyhow::bail!("unknown model kind {other:?}"),
        })
    }
}

#[derive(Clone, Copy, Debug)]
struct BlockOff {
    ln_s: usize,
    ln_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
}

/// Manifest-bound executor for one model: flat-θ offsets + dimensions.
pub struct RefModel {
    pub kind: Kind,
    pub d: usize,
    pub h: usize,
    pub e: usize,
    pub blocks: usize,
    pub classes: usize,
    pub theta_len: usize,
    embed_w: usize,
    embed_b: usize,
    block_off: Vec<BlockOff>,
    head_w: usize,
    head_b: usize,
    /// (offset, len, unit) per tensor — lr-mask expansion.
    mask_segments: Vec<(usize, usize, usize)>,
}

enum BlockTape {
    ReluRes { d1: DenseTape, d2: DenseTape, h_out: Vec<f32> },
    Bottleneck { d1: DenseTape, d2: DenseTape },
    Preln { ln: LnTape, d1: DenseTape, d2: DenseTape },
}

struct ModelTape {
    embed: DenseTape,
    blocks: Vec<BlockTape>,
    head: Option<DenseTape>,
}

impl RefModel {
    pub fn from_manifest(m: &ModelManifest) -> Result<RefModel> {
        let kind = Kind::parse(&m.kind)?;
        let find = |name: &str| -> Result<(usize, Vec<usize>)> {
            m.tensors
                .iter()
                .find(|t| t.name == name)
                .map(|t| (t.offset, t.shape.clone()))
                .ok_or_else(|| anyhow::anyhow!("{}: manifest lacks tensor {name:?}", m.name))
        };
        let (embed_w, ew_shape) = find("embed.w")?;
        anyhow::ensure!(
            ew_shape == vec![m.d, m.h],
            "{}: embed.w shape {ew_shape:?} != [{}, {}]",
            m.name,
            m.d,
            m.h
        );
        let (embed_b, _) = find("embed.b")?;
        let mut e = m.h;
        let mut block_off = Vec::with_capacity(m.blocks);
        for i in 1..=m.blocks {
            let p = format!("block{i}.");
            let (w1, w1_shape) = find(&format!("{p}w1"))?;
            anyhow::ensure!(w1_shape.len() == 2 && w1_shape[0] == m.h, "{}: bad w1 shape", m.name);
            e = w1_shape[1];
            let (b1, _) = find(&format!("{p}b1"))?;
            let (w2, _) = find(&format!("{p}w2"))?;
            let (b2, _) = find(&format!("{p}b2"))?;
            let (ln_s, ln_b) = if kind == Kind::PrelnGelu {
                (find(&format!("{p}ln_s"))?.0, find(&format!("{p}ln_b"))?.0)
            } else {
                (0, 0)
            };
            block_off.push(BlockOff { ln_s, ln_b, w1, b1, w2, b2 });
        }
        let (head_w, _) = find("head.w")?;
        let (head_b, _) = find("head.b")?;
        let mask_segments = m
            .tensors
            .iter()
            .map(|t| (t.offset, t.size(), t.unit))
            .collect();
        Ok(RefModel {
            kind,
            d: m.d,
            h: m.h,
            e,
            blocks: m.blocks,
            classes: m.classes,
            theta_len: m.theta_len,
            embed_w,
            embed_b,
            block_off,
            head_w,
            head_b,
            mask_segments,
        })
    }

    fn slice<'a>(&self, theta: &'a [f32], off: usize, len: usize) -> &'a [f32] {
        &theta[off..off + len]
    }

    // -- inference-mode forward (no tape, no quant) -------------------------

    fn block_infer(&self, theta: &[f32], o: &BlockOff, hcur: &[f32], b: usize) -> Vec<f32> {
        let (h, e) = (self.h, self.e);
        match self.kind {
            Kind::ReluRes | Kind::Bottleneck => {
                let mid = dense_infer(
                    hcur,
                    self.slice(theta, o.w1, h * e),
                    self.slice(theta, o.b1, e),
                    b,
                    h,
                    e,
                    Act::Relu,
                );
                let out = dense_infer(
                    &mid,
                    self.slice(theta, o.w2, e * h),
                    self.slice(theta, o.b2, h),
                    b,
                    e,
                    h,
                    Act::None,
                );
                if self.kind == Kind::ReluRes {
                    hcur.iter().zip(&out).map(|(&a, &v)| (a + v).max(0.0)).collect()
                } else {
                    hcur.iter().zip(&out).map(|(&a, &v)| a + v).collect()
                }
            }
            Kind::PrelnGelu => {
                let (ln, _) = layernorm_fwd(
                    hcur,
                    self.slice(theta, o.ln_s, h),
                    self.slice(theta, o.ln_b, h),
                    b,
                    h,
                );
                let mid = dense_infer(
                    &ln,
                    self.slice(theta, o.w1, h * e),
                    self.slice(theta, o.b1, e),
                    b,
                    h,
                    e,
                    Act::Gelu,
                );
                let out = dense_infer(
                    &mid,
                    self.slice(theta, o.w2, e * h),
                    self.slice(theta, o.b2, h),
                    b,
                    e,
                    h,
                    Act::None,
                );
                hcur.iter().zip(&out).map(|(&a, &v)| a + v).collect()
            }
        }
    }

    /// Forward pass: logits `[b, classes]`.
    pub fn infer(&self, theta: &[f32], x: &[f32], b: usize) -> Vec<f32> {
        let (d, h) = (self.d, self.h);
        let mut hcur = dense_infer(
            x,
            self.slice(theta, self.embed_w, d * h),
            self.slice(theta, self.embed_b, h),
            b,
            d,
            h,
            Act::Relu,
        );
        for o in &self.block_off {
            hcur = self.block_infer(theta, o, &hcur, b);
        }
        dense_infer(
            &hcur,
            self.slice(theta, self.head_w, h * self.classes),
            self.slice(theta, self.head_b, self.classes),
            b,
            h,
            self.classes,
            Act::None,
        )
    }

    /// Per-unit feature maps `[blocks+1, b, h]` (embed output + each block
    /// output; the head has no feature map).
    pub fn features(&self, theta: &[f32], x: &[f32], b: usize) -> Vec<f32> {
        let (d, h) = (self.d, self.h);
        let mut out = Vec::with_capacity((self.blocks + 1) * b * h);
        let mut hcur = dense_infer(
            x,
            self.slice(theta, self.embed_w, d * h),
            self.slice(theta, self.embed_b, h),
            b,
            d,
            h,
            Act::Relu,
        );
        out.extend_from_slice(&hcur);
        for o in &self.block_off {
            hcur = self.block_infer(theta, o, &hcur, b);
            out.extend_from_slice(&hcur);
        }
        out
    }

    // -- training-mode forward/backward -------------------------------------

    fn forward_train(
        &self,
        theta: &[f32],
        x: &[f32],
        b: usize,
        quant: bool,
        with_head: bool,
    ) -> (Vec<f32>, ModelTape) {
        let (d, h, e) = (self.d, self.h, self.e);
        let (mut hcur, embed) = dense_train(
            x,
            self.slice(theta, self.embed_w, d * h),
            self.slice(theta, self.embed_b, h),
            b,
            d,
            h,
            Act::Relu,
            quant,
        );
        let mut blocks = Vec::with_capacity(self.blocks);
        for o in &self.block_off {
            match self.kind {
                Kind::ReluRes | Kind::Bottleneck => {
                    let (mid, d1) = dense_train(
                        &hcur,
                        self.slice(theta, o.w1, h * e),
                        self.slice(theta, o.b1, e),
                        b,
                        h,
                        e,
                        Act::Relu,
                        quant,
                    );
                    let (out, d2) = dense_train(
                        &mid,
                        self.slice(theta, o.w2, e * h),
                        self.slice(theta, o.b2, h),
                        b,
                        e,
                        h,
                        Act::None,
                        quant,
                    );
                    if self.kind == Kind::ReluRes {
                        let h_out: Vec<f32> = hcur
                            .iter()
                            .zip(&out)
                            .map(|(&a, &v)| (a + v).max(0.0))
                            .collect();
                        hcur = h_out.clone();
                        blocks.push(BlockTape::ReluRes { d1, d2, h_out });
                    } else {
                        hcur = hcur.iter().zip(&out).map(|(&a, &v)| a + v).collect();
                        blocks.push(BlockTape::Bottleneck { d1, d2 });
                    }
                }
                Kind::PrelnGelu => {
                    let (ln_out, ln) = layernorm_fwd(
                        &hcur,
                        self.slice(theta, o.ln_s, h),
                        self.slice(theta, o.ln_b, h),
                        b,
                        h,
                    );
                    let (mid, d1) = dense_train(
                        &ln_out,
                        self.slice(theta, o.w1, h * e),
                        self.slice(theta, o.b1, e),
                        b,
                        h,
                        e,
                        Act::Gelu,
                        quant,
                    );
                    let (out, d2) = dense_train(
                        &mid,
                        self.slice(theta, o.w2, e * h),
                        self.slice(theta, o.b2, h),
                        b,
                        e,
                        h,
                        Act::None,
                        quant,
                    );
                    hcur = hcur.iter().zip(&out).map(|(&a, &v)| a + v).collect();
                    blocks.push(BlockTape::Preln { ln, d1, d2 });
                }
            }
        }
        if with_head {
            let (logits, head) = dense_train(
                &hcur,
                self.slice(theta, self.head_w, h * self.classes),
                self.slice(theta, self.head_b, self.classes),
                b,
                h,
                self.classes,
                Act::None,
                quant,
            );
            (logits, ModelTape { embed, blocks, head: Some(head) })
        } else {
            (hcur, ModelTape { embed, blocks, head: None })
        }
    }

    /// Reverse pass: accumulate ∂loss/∂θ into `dtheta` given the cotangent
    /// of the tape's output (`dout` = dlogits with a head, d_backbone
    /// features without).
    fn backward(&self, theta: &[f32], tape: &ModelTape, dout: &[f32], dtheta: &mut [f32]) {
        let h = self.h;
        let mut dh: Vec<f32>;
        if let Some(head) = &tape.head {
            let g = dense_bwd(head, dout);
            accumulate(dtheta, self.head_w, &g.dw);
            accumulate(dtheta, self.head_b, &g.db);
            dh = g.dx;
        } else {
            dh = dout.to_vec();
        }
        for (o, bt) in self.block_off.iter().zip(&tape.blocks).rev() {
            match bt {
                BlockTape::ReluRes { d1, d2, h_out } => {
                    // outer relu is jnp.maximum(sum, 0): ties route half.
                    let dsum: Vec<f32> = dh
                        .iter()
                        .zip(h_out)
                        .map(|(&g, &o)| {
                            if o > 0.0 {
                                g
                            } else if o == 0.0 {
                                0.5 * g
                            } else {
                                0.0
                            }
                        })
                        .collect();
                    let g2 = dense_bwd(d2, &dsum);
                    accumulate(dtheta, o.w2, &g2.dw);
                    accumulate(dtheta, o.b2, &g2.db);
                    let g1 = dense_bwd(d1, &g2.dx);
                    accumulate(dtheta, o.w1, &g1.dw);
                    accumulate(dtheta, o.b1, &g1.db);
                    dh = dsum.iter().zip(&g1.dx).map(|(&a, &b)| a + b).collect();
                }
                BlockTape::Bottleneck { d1, d2 } => {
                    let g2 = dense_bwd(d2, &dh);
                    accumulate(dtheta, o.w2, &g2.dw);
                    accumulate(dtheta, o.b2, &g2.db);
                    let g1 = dense_bwd(d1, &g2.dx);
                    accumulate(dtheta, o.w1, &g1.dw);
                    accumulate(dtheta, o.b1, &g1.db);
                    dh = dh.iter().zip(&g1.dx).map(|(&a, &b)| a + b).collect();
                }
                BlockTape::Preln { ln, d1, d2 } => {
                    let g2 = dense_bwd(d2, &dh);
                    accumulate(dtheta, o.w2, &g2.dw);
                    accumulate(dtheta, o.b2, &g2.db);
                    let g1 = dense_bwd(d1, &g2.dx);
                    accumulate(dtheta, o.w1, &g1.dw);
                    accumulate(dtheta, o.b1, &g1.db);
                    let (dx_ln, ds, db) =
                        layernorm_bwd(ln, self.slice(theta, o.ln_s, h), &g1.dx);
                    accumulate(dtheta, o.ln_s, &ds);
                    accumulate(dtheta, o.ln_b, &db);
                    dh = dh.iter().zip(&dx_ln).map(|(&a, &b)| a + b).collect();
                }
            }
        }
        let ge = dense_bwd(&tape.embed, &dh);
        accumulate(dtheta, self.embed_w, &ge.dw);
        accumulate(dtheta, self.embed_b, &ge.db);
    }

    /// Expand the per-unit lr mask over the flat gradient (mask *before*
    /// clip, exactly like `train_fn` in model.py — this is also what makes
    /// prefix truncation and lr-mask freezing produce identical surviving
    /// updates, so the `k` of a `train_k` segment never changes the math).
    fn apply_mask(&self, g: &mut [f32], lr_mask: &[f32]) {
        for &(off, len, unit) in &self.mask_segments {
            let mv = lr_mask[unit];
            if mv == 1.0 {
                continue;
            }
            for v in &mut g[off..off + len] {
                *v *= mv;
            }
        }
    }

    /// One SGD step (the `train_k` / `train_q_k` segments); returns
    /// `(θ', loss)`.
    pub fn train_step(
        &self,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
        b: usize,
        lr_mask: &[f32],
        lr: f32,
        quant: bool,
    ) -> (Vec<f32>, f32) {
        let (logits, tape) = self.forward_train(theta, x, b, quant, true);
        let (loss, dlogits) = ce_loss_and_grad(&logits, y, b, self.classes);
        let mut g = vec![0.0f32; self.theta_len];
        self.backward(theta, &tape, &dlogits, &mut g);
        self.apply_mask(&mut g, lr_mask);
        clip_global(&mut g, MAX_GRAD_NORM);
        let theta_new: Vec<f32> =
            theta.iter().zip(&g).map(|(&t, &gv)| t - lr * gv).collect();
        (theta_new, loss)
    }

    /// One SimSiam step (the `ssl` segment); φ layout is
    /// `[proj.w (h,h), proj.b (h), pred.w (h,h), pred.b (h)]`.
    /// Returns `(θ', φ', loss)`.
    pub fn ssl_step(
        &self,
        theta: &[f32],
        phi: &[f32],
        x1: &[f32],
        x2: &[f32],
        b: usize,
        lr_mask: &[f32],
        lr: f32,
    ) -> (Vec<f32>, Vec<f32>, f32) {
        let h = self.h;
        let (proj_w, proj_b) = (0, h * h);
        let (pred_w, pred_b) = (h * h + h, 2 * h * h + h);
        debug_assert_eq!(phi.len(), 2 * h * h + 2 * h);

        let (bb1, tape1) = self.forward_train(theta, x1, b, false, false);
        let (bb2, tape2) = self.forward_train(theta, x2, b, false, false);
        let (z1, pj1) = dense_train(
            &bb1, &phi[proj_w..proj_w + h * h], &phi[proj_b..proj_b + h],
            b, h, h, Act::None, false,
        );
        let (z2, pj2) = dense_train(
            &bb2, &phi[proj_w..proj_w + h * h], &phi[proj_b..proj_b + h],
            b, h, h, Act::None, false,
        );
        let (p1, pd1) = dense_train(
            &z1, &phi[pred_w..pred_w + h * h], &phi[pred_b..pred_b + h],
            b, h, h, Act::None, false,
        );
        let (p2, pd2) = dense_train(
            &z2, &phi[pred_w..pred_w + h * h], &phi[pred_b..pred_b + h],
            b, h, h, Act::None, false,
        );

        // loss = -(cos(p1, sg(z2)) + cos(p2, sg(z1))) / 2
        let (c1, dp1_cos) = cosine_mean_sg(&p1, &z2, b, h);
        let (c2, dp2_cos) = cosine_mean_sg(&p2, &z1, b, h);
        let loss = -(c1 + c2) / 2.0;
        let dp1: Vec<f32> = dp1_cos.iter().map(|&v| -0.5 * v).collect();
        let dp2: Vec<f32> = dp2_cos.iter().map(|&v| -0.5 * v).collect();

        let mut gphi = vec![0.0f32; phi.len()];
        let mut gtheta = vec![0.0f32; self.theta_len];
        // branch 1: p1 <- pred(z1) <- proj(bb1) <- backbone(x1)
        let g_pd1 = dense_bwd(&pd1, &dp1);
        accumulate(&mut gphi, pred_w, &g_pd1.dw);
        accumulate(&mut gphi, pred_b, &g_pd1.db);
        let g_pj1 = dense_bwd(&pj1, &g_pd1.dx);
        accumulate(&mut gphi, proj_w, &g_pj1.dw);
        accumulate(&mut gphi, proj_b, &g_pj1.db);
        self.backward(theta, &tape1, &g_pj1.dx, &mut gtheta);
        // branch 2: p2 <- pred(z2) <- proj(bb2) <- backbone(x2)
        let g_pd2 = dense_bwd(&pd2, &dp2);
        accumulate(&mut gphi, pred_w, &g_pd2.dw);
        accumulate(&mut gphi, pred_b, &g_pd2.db);
        let g_pj2 = dense_bwd(&pj2, &g_pd2.dx);
        accumulate(&mut gphi, proj_w, &g_pj2.dw);
        accumulate(&mut gphi, proj_b, &g_pj2.db);
        self.backward(theta, &tape2, &g_pj2.dx, &mut gtheta);

        self.apply_mask(&mut gtheta, lr_mask);
        clip_global(&mut gtheta, MAX_GRAD_NORM);
        clip_global(&mut gphi, MAX_GRAD_NORM);
        let theta_new: Vec<f32> =
            theta.iter().zip(&gtheta).map(|(&t, &g)| t - lr * g).collect();
        let phi_new: Vec<f32> =
            phi.iter().zip(&gphi).map(|(&p, &g)| p - lr * g).collect();
        (theta_new, phi_new, loss)
    }
}

fn accumulate(dst: &mut [f32], off: usize, src: &[f32]) {
    for (o, &s) in dst[off..off + src.len()].iter_mut().zip(src) {
        *o += s;
    }
}

// ---------------------------------------------------------------------------
// tests — hand-derived VJPs checked against central finite differences
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    /// Scalar objective: sum of `weights * dense_out` (a fixed linear
    /// functional so the cotangent is the weight vector).
    fn dense_obj(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize, act: Act, cot: &[f32]) -> f32 {
        dense_infer(x, w, b, m, k, n, act)
            .iter()
            .zip(cot)
            .map(|(&o, &c)| o * c)
            .sum()
    }

    #[test]
    fn dense_relu_bwd_equals_masked_linear_bwd() {
        // exact identity (no finite differences across the kink): the ReLU
        // VJP is the linear VJP with the cotangent masked by `out > 0`.
        let (m, k, n) = (3, 4, 5);
        let mut rng = Pcg32::new(13, 3);
        let x = randv(&mut rng, m * k, 1.0);
        let w = randv(&mut rng, k * n, 0.5);
        let b = randv(&mut rng, n, 0.2);
        let cot = randv(&mut rng, m * n, 1.0);
        let (out, tape_r) = dense_train(&x, &w, &b, m, k, n, Act::Relu, false);
        let (z, tape_n) = dense_train(&x, &w, &b, m, k, n, Act::None, false);
        assert!(out.iter().zip(&z).all(|(&o, &zv)| o == zv.max(0.0)));
        let masked: Vec<f32> = cot
            .iter()
            .zip(&z)
            .map(|(&c, &zv)| if zv > 0.0 { c } else { 0.0 })
            .collect();
        let gr = dense_bwd(&tape_r, &cot);
        let gn = dense_bwd(&tape_n, &masked);
        assert_eq!(gr.dx, gn.dx);
        assert_eq!(gr.dw, gn.dw);
        assert_eq!(gr.db, gn.db);
    }

    #[test]
    fn dense_bwd_matches_finite_differences() {
        for act in [Act::None, Act::Gelu] {
            let (m, k, n) = (3, 4, 5);
            let mut rng = Pcg32::new(11, 3);
            let x = randv(&mut rng, m * k, 1.0);
            let w = randv(&mut rng, k * n, 0.5);
            let b = randv(&mut rng, n, 0.2);
            let cot = randv(&mut rng, m * n, 1.0);
            let (_, tape) = dense_train(&x, &w, &b, m, k, n, act, false);
            let g = dense_bwd(&tape, &cot);
            let eps = 1e-3f32;
            for idx in 0..k * n {
                let mut wp = w.clone();
                let mut wm = w.clone();
                wp[idx] += eps;
                wm[idx] -= eps;
                let fd = (dense_obj(&x, &wp, &b, m, k, n, act, &cot)
                    - dense_obj(&x, &wm, &b, m, k, n, act, &cot))
                    / (2.0 * eps);
                assert!(
                    (fd - g.dw[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dw[{idx}]: fd {fd} vs {g}",
                    g = g.dw[idx]
                );
            }
            for idx in 0..m * k {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[idx] += eps;
                xm[idx] -= eps;
                let fd = (dense_obj(&xp, &w, &b, m, k, n, act, &cot)
                    - dense_obj(&xm, &w, &b, m, k, n, act, &cot))
                    / (2.0 * eps);
                assert!(
                    (fd - g.dx[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dx[{idx}]: fd {fd} vs {g}",
                    g = g.dx[idx]
                );
            }
        }
    }

    #[test]
    fn layernorm_bwd_matches_finite_differences() {
        let (m, h) = (2, 6);
        let mut rng = Pcg32::new(21, 5);
        let x = randv(&mut rng, m * h, 1.0);
        let s = randv(&mut rng, h, 0.5);
        let bb = randv(&mut rng, h, 0.3);
        let cot = randv(&mut rng, m * h, 1.0);
        let obj = |xv: &[f32]| -> f32 {
            let (out, _) = layernorm_fwd(xv, &s, &bb, m, h);
            out.iter().zip(&cot).map(|(&o, &c)| o * c).sum()
        };
        let (_, tape) = layernorm_fwd(&x, &s, &bb, m, h);
        let (dx, ds, db) = layernorm_bwd(&tape, &s, &cot);
        let eps = 1e-3f32;
        for idx in 0..m * h {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[idx] += eps;
            xm[idx] -= eps;
            let fd = (obj(&xp) - obj(&xm)) / (2.0 * eps);
            assert!(
                (fd - dx[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "dx[{idx}]: fd {fd} vs {}",
                dx[idx]
            );
        }
        // affine params: ds = Σ cot*normed, db = Σ cot (checked directly)
        for j in 0..h {
            let want_db: f32 = (0..m).map(|i| cot[i * h + j]).sum();
            assert!((db[j] - want_db).abs() < 1e-5);
        }
        assert_eq!(ds.len(), h);
    }

    #[test]
    fn ce_grad_matches_finite_differences() {
        let (b, c) = (4, 5);
        let mut rng = Pcg32::new(31, 7);
        let logits = randv(&mut rng, b * c, 2.0);
        let y: Vec<i32> = (0..b).map(|i| (i % c) as i32).collect();
        let (loss, dl) = ce_loss_and_grad(&logits, &y, b, c);
        assert!(loss > 0.0 && loss.is_finite());
        let eps = 1e-3f32;
        for idx in 0..b * c {
            let mut lp = logits.clone();
            let mut lm = logits.clone();
            lp[idx] += eps;
            lm[idx] -= eps;
            let fd = (ce_loss_and_grad(&lp, &y, b, c).0
                - ce_loss_and_grad(&lm, &y, b, c).0)
                / (2.0 * eps);
            assert!(
                (fd - dl[idx]).abs() < 1e-2 * (1.0 + fd.abs()),
                "dl[{idx}]: fd {fd} vs {}",
                dl[idx]
            );
        }
        // softmax-grad rows sum to ~0
        for i in 0..b {
            let s: f32 = dl[i * c..(i + 1) * c].iter().sum();
            assert!(s.abs() < 1e-5);
        }
    }

    #[test]
    fn cosine_grad_matches_finite_differences() {
        let (b, h) = (3, 6);
        let mut rng = Pcg32::new(41, 9);
        let a = randv(&mut rng, b * h, 1.0);
        let t = randv(&mut rng, b * h, 1.0);
        let (cos, da) = cosine_mean_sg(&a, &t, b, h);
        assert!(cos.abs() <= 1.0 + 1e-5);
        let eps = 1e-3f32;
        for idx in 0..b * h {
            let mut ap = a.clone();
            let mut am = a.clone();
            ap[idx] += eps;
            am[idx] -= eps;
            let fd = (cosine_mean_sg(&ap, &t, b, h).0
                - cosine_mean_sg(&am, &t, b, h).0)
                / (2.0 * eps);
            assert!(
                (fd - da[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "da[{idx}]: fd {fd} vs {}",
                da[idx]
            );
        }
    }

    #[test]
    fn gelu_prime_matches_finite_differences() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 3.0] {
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_prime(x)).abs() < 1e-3,
                "gelu'({x}): fd {fd} vs {}",
                gelu_prime(x)
            );
        }
    }

    #[test]
    fn clip_global_caps_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5 — exactly at the cap
        clip_global(&mut g, MAX_GRAD_NORM);
        assert_eq!(g, vec![3.0, 4.0]);
        let mut g = vec![30.0f32, 40.0]; // norm 50 -> scaled to 5
        clip_global(&mut g, MAX_GRAD_NORM);
        let norm = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((norm - 5.0).abs() < 1e-4);
    }

    #[test]
    fn fake_quant_is_idempotent_and_bounded() {
        let v = vec![-1.3f32, 0.0, 0.4, 2.7];
        let q = fake_quant(&v);
        let qq = fake_quant(&q);
        for (a, b) in q.iter().zip(&qq) {
            assert!((a - b).abs() < 1e-6);
        }
        let amax = 2.7f32;
        for (&orig, &quant) in v.iter().zip(&q) {
            assert!((orig - quant).abs() <= amax / 127.0 + 1e-6);
        }
    }

    #[test]
    fn cka_is_one_on_identical_features() {
        let mut rng = Pcg32::new(51, 2);
        let x = randv(&mut rng, 16 * 8, 1.0);
        let v = cka(&x, &x, 16, 8);
        assert!((v - 1.0).abs() < 1e-4, "cka(x,x) = {v}");
        let y = randv(&mut rng, 16 * 8, 1.0);
        let w = cka(&x, &y, 16, 8);
        assert!(w.is_finite() && w >= 0.0 && w < 1.0, "cka(x,y) = {w}");
    }
}
