//! The pre-PR-4 triple-loop dense kernels, kept verbatim as the **oracle**
//! the packed kernels in [`super::gemm`] are checked against
//! (`tests/refcpu_gemm.rs` asserts bit-equality over odd/degenerate
//! shapes, and `benches/hotpath.rs` reports the naive-vs-packed gap).
//!
//! Production code must not call into this module — the execution core
//! runs on the packed kernels; this is a test/bench reference only.

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use super::gemm::{gelu, gelu_prime, quant_elem, quant_scale, Act};

/// `out = x·w + b` — the seed implementation: per row, bias copy then
/// in-order k accumulation with the `xv == 0.0` skip.
pub fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x[i * k..(i + 1) * k];
        let dst = &mut out[i * n..(i + 1) * n];
        dst.copy_from_slice(b);
        for (t, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[t * n..(t + 1) * n];
            for (o, &wv) in dst.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// Per-tensor symmetric 8-bit fake-quantization (the seed `fake_quant`).
pub fn fake_quant(v: &[f32]) -> Vec<f32> {
    let scale = quant_scale(v);
    v.iter().map(|&x| quant_elem(x, scale)).collect()
}

/// Forward dense `act(x·w + b)`, optionally through fake-quantized
/// x and w (the seed `dense_train` forward with its separate activation
/// pass).
pub fn dense_fwd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    quant: bool,
) -> Vec<f32> {
    let (xq, wq) = if quant {
        (fake_quant(x), fake_quant(w))
    } else {
        (x.to_vec(), w.to_vec())
    };
    let mut out = matmul_bias(&xq, &wq, b, m, k, n);
    match act {
        Act::None => {}
        Act::Relu => out.iter_mut().for_each(|v| *v = v.max(0.0)),
        Act::Gelu => out.iter_mut().for_each(|v| *v = gelu(*v)),
    }
    out
}

/// Full dense VJP at `(x, w, b)` with cotangent `dout`: the seed
/// `dense_train` + `dense_bwd` composition (activation rule, then
/// `dx = dz·wᵀ`, `dw = xᵀ·dz`, `db = Σ_rows dz`, contracting against the
/// quantized tensors under QAT).
pub fn dense_vjp(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    quant: bool,
    dout: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dout.len(), m * n);
    let (xq, wq) = if quant {
        (fake_quant(x), fake_quant(w))
    } else {
        (x.to_vec(), w.to_vec())
    };
    let z = matmul_bias(&xq, &wq, b, m, k, n);
    let dz: Vec<f32> = match act {
        Act::None => dout.to_vec(),
        Act::Relu => dout
            .iter()
            .zip(&z)
            .map(|(&g, &zv)| if zv.max(0.0) > 0.0 { g } else { 0.0 })
            .collect(),
        Act::Gelu => dout
            .iter()
            .zip(&z)
            .map(|(&g, &zv)| g * gelu_prime(zv))
            .collect(),
    };
    let dx = dx_naive(&dz, &wq, m, k, n);
    let dw = dw_naive(&xq, &dz, m, k, n);
    let db = db_naive(&dz, m, n);
    (dx, dw, db)
}

/// `dx[i,t] = Σ_j dz[i,j] * w[t,j]` — the seed dx loop, standalone (the
/// like-for-like naive counterpart of `gemm::gemm_dx` for the benches).
pub fn dx_naive(dz: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        let dzr = &dz[i * n..(i + 1) * n];
        let dst = &mut dx[i * k..(i + 1) * k];
        for tt in 0..k {
            let wrow = &w[tt * n..(tt + 1) * n];
            let mut acc = 0.0f32;
            for (g, wv) in dzr.iter().zip(wrow) {
                acc += g * wv;
            }
            dst[tt] = acc;
        }
    }
    dx
}

/// `dw[t,j] = Σ_i x[i,t] * dz[i,j]` — the seed dw loop, standalone.
pub fn dw_naive(x: &[f32], dz: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; k * n];
    for i in 0..m {
        let xr = &x[i * k..(i + 1) * k];
        let dzr = &dz[i * n..(i + 1) * n];
        for (tt, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let dst = &mut dw[tt * n..(tt + 1) * n];
            for (o, &g) in dst.iter_mut().zip(dzr) {
                *o += xv * g;
            }
        }
    }
    dw
}

/// `db[j] = Σ_i dz[i,j]` — the seed db loop, standalone.
pub fn db_naive(dz: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; n];
    for i in 0..m {
        for (o, &g) in db.iter_mut().zip(&dz[i * n..(i + 1) * n]) {
            *o += g;
        }
    }
    db
}
