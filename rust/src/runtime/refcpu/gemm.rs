//! Packed, register-blocked GEMM kernels for the reference execution
//! core — **bit-identical** to the naive triple loops in
//! [`super::naive`], which stay in-tree as the oracle the property suite
//! (`tests/refcpu_gemm.rs`) checks against.
//!
//! # The bit-identity contract
//!
//! Every kernel keeps the reduction over the serial (k) dimension
//! **in-order per output element** and tiles only over the m/n output
//! dimensions, so each output element sees exactly the same sequence of
//! f32 additions as the naive loop:
//!
//! * `gemm_fwd` — `out = act(x·w + b)`: the accumulator starts at the
//!   bias (the naive `copy_from_slice(b)`), k-terms are added in
//!   ascending t order, and the naive loop's `xv == 0.0` skip is kept
//!   (skipping vs adding a signed-zero product can flip a result's zero
//!   sign, so the skip is part of the contract).  The bias load and the
//!   ReLU/GELU epilogue run inside the tile loop — no separate
//!   activation pass over the output.
//! * `gemm_dx` — `dx = dz·wᵀ`: j-serial per element, **no** zero skip
//!   (the naive dx loop has none).
//! * `gemm_dw_acc` — `dw += xᵀ·dz`: i-serial per element with the naive
//!   `x == 0.0` skip; the per-element sum is formed from 0.0 in
//!   registers and added to the destination once, matching the naive
//!   "fill a fresh buffer, then accumulate" order.
//!
//! Panels are padded to the register width [`NR`]; padded lanes compute
//! garbage that is never stored.
//!
//! # Packing and the generation-keyed cache
//!
//! Weights are packed once per *θ buffer* into row-panels (`pack_w`) and
//! transposed row-panels (`pack_wt`, for the dx kernel), cached in
//! [`PackCache`] keyed by `(Value::buf_id, tensor offset, direction,
//! quantized)`.  Buf ids change exactly when [`crate::model::Params`]'
//! generation does (the session re-marshals θ then), so packs invalidate
//! with the θ-literal cache and steady-state serving never re-packs.
//! For QAT, fake-quantization is fused into the pack (`quant = true`):
//! the panel stores quantized weights directly and `train_q` never
//! materializes a full `wq` copy.

#![allow(clippy::needless_range_loop)]

use std::collections::HashMap;

/// Register-block width (f32 lanes per panel column tile).
pub const NR: usize = 8;

#[inline]
fn panels_of(width: usize) -> usize {
    width.div_ceil(NR)
}

// ---------------------------------------------------------------------------
// elementwise primitives (epilogues + fake-quant, shared with the oracle)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Gelu,
}

/// tanh-approximation GELU (`jax.nn.gelu` with `approximate=True`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    let u = C * (x + 0.044715 * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// d gelu / dx at pre-activation `x`.
#[inline]
pub fn gelu_prime(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Round half to even (numpy/jnp.round semantics, vs Rust's half-away).
#[inline]
fn round_ties_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            x.ceil()
        }
    } else {
        r
    }
}

const QMAX: f32 = 127.0; // 2^(8-1) - 1

/// Per-tensor symmetric 8-bit scale (`amax / 127`, floored like jnp).
pub fn quant_scale(v: &[f32]) -> f32 {
    let amax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    amax.max(1e-8) / QMAX
}

/// One fake-quantized element at a precomputed scale.
#[inline]
pub fn quant_elem(x: f32, scale: f32) -> f32 {
    round_ties_even(x / scale).clamp(-QMAX, QMAX) * scale
}

/// Fake-quantize `src` into a reusable buffer (the activation side of
/// QAT; the weight side is fused into the pack step).
pub fn quantize_into(src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    let scale = quant_scale(src);
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = quant_elem(s, scale);
    }
}

// ---------------------------------------------------------------------------
// panel packing
// ---------------------------------------------------------------------------

/// A weight matrix repacked into contiguous `NR`-wide column panels.
///
/// `depth` is the serial (reduction) dimension, `width` the output
/// dimension the panels tile.  Panel `p` stores, row-major over the
/// depth index, the `NR` output columns `[p*NR, p*NR + NR)`, zero-padded
/// past `width`.
#[derive(Clone, Debug)]
pub struct Panels {
    data: Vec<f32>,
    depth: usize,
    width: usize,
}

impl Panels {
    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Packed bytes (capacity accounting for the cache).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Fill a (possibly recycled) buffer with panels; `buf` is cleared and
/// zero-resized so padded lanes are always zero.
fn pack_into(
    mut buf: Vec<f32>,
    depth: usize,
    width: usize,
    elem: impl Fn(usize, usize) -> f32, // (depth index, width index) -> value
) -> Panels {
    let np = panels_of(width);
    buf.clear();
    buf.resize(np * depth * NR, 0.0);
    for p in 0..np {
        let base = p * NR;
        let valid = NR.min(width - base);
        let pd = &mut buf[p * depth * NR..(p + 1) * depth * NR];
        for t in 0..depth {
            for r in 0..valid {
                pd[t * NR + r] = elem(t, base + r);
            }
        }
    }
    Panels { data: buf, depth, width }
}

/// Pack into `buf` (recycled pack storage or `Vec::new()`): forward
/// panels, or transposed (dx-kernel) panels, optionally with per-tensor
/// fake-quantization fused in.  Quantized transposed packs use the
/// *same* scale and values as the forward pack — the straight-through
/// backward contracts against exactly the quantized weights the forward
/// used.
fn pack_with(buf: Vec<f32>, w: &[f32], k: usize, n: usize, transposed: bool, quant: bool) -> Panels {
    debug_assert_eq!(w.len(), k * n);
    match (transposed, quant) {
        (false, false) => pack_into(buf, k, n, |t, j| w[t * n + j]),
        (false, true) => {
            let s = quant_scale(w);
            pack_into(buf, k, n, move |t, j| quant_elem(w[t * n + j], s))
        }
        (true, false) => pack_into(buf, n, k, |j, t| w[t * n + j]),
        (true, true) => {
            let s = quant_scale(w);
            pack_into(buf, n, k, move |j, t| quant_elem(w[t * n + j], s))
        }
    }
}

/// Pack `w` (k×n row-major) for the forward kernel; `quant` fuses
/// per-tensor fake-quantization into the pack.
pub fn pack_w(w: &[f32], k: usize, n: usize, quant: bool) -> Panels {
    pack_with(Vec::new(), w, k, n, false, quant)
}

/// Pack `wᵀ` (the dx kernel's operand) from `w` (k×n row-major): depth
/// becomes n, width becomes k.
pub fn pack_wt(w: &[f32], k: usize, n: usize, quant: bool) -> Panels {
    pack_with(Vec::new(), w, k, n, true, quant)
}

// ---------------------------------------------------------------------------
// kernels
// ---------------------------------------------------------------------------

/// `out[m×n] = act(x[m×k] · w + b)` over forward panels, bias and
/// activation fused into the tile loop.  Bit-identical to
/// `naive::matmul_bias` + a separate activation pass.
pub fn gemm_fwd(x: &[f32], pan: &Panels, b: &[f32], m: usize, act: Act, out: &mut [f32]) {
    let (k, n) = (pan.depth, pan.width);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let np = panels_of(n);
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for p in 0..np {
            let base = p * NR;
            let valid = NR.min(n - base);
            let pd = &pan.data[p * k * NR..(p + 1) * k * NR];
            let mut acc = [0.0f32; NR];
            acc[..valid].copy_from_slice(&b[base..base + valid]);
            for (t, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let w8 = &pd[t * NR..t * NR + NR];
                for r in 0..NR {
                    acc[r] += xv * w8[r];
                }
            }
            let dst = &mut orow[base..base + valid];
            match act {
                Act::None => dst.copy_from_slice(&acc[..valid]),
                Act::Relu => {
                    for (d, a) in dst.iter_mut().zip(&acc) {
                        *d = a.max(0.0);
                    }
                }
                Act::Gelu => {
                    for (d, a) in dst.iter_mut().zip(&acc) {
                        *d = gelu(*a);
                    }
                }
            }
        }
    }
}

/// `dx[m×k] = dz[m×n] · wᵀ` over transposed panels (`pack_wt`); j-serial
/// per element, no zero skip — bit-identical to the naive dx loop.
pub fn gemm_dx(dz: &[f32], pan: &Panels, m: usize, dx: &mut [f32]) {
    let (n, k) = (pan.depth, pan.width);
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(dx.len(), m * k);
    let np = panels_of(k);
    for i in 0..m {
        let dzr = &dz[i * n..(i + 1) * n];
        let orow = &mut dx[i * k..(i + 1) * k];
        for p in 0..np {
            let base = p * NR;
            let valid = NR.min(k - base);
            let pd = &pan.data[p * n * NR..(p + 1) * n * NR];
            let mut acc = [0.0f32; NR];
            for (j, &g) in dzr.iter().enumerate() {
                let w8 = &pd[j * NR..j * NR + NR];
                for r in 0..NR {
                    acc[r] += g * w8[r];
                }
            }
            orow[base..base + valid].copy_from_slice(&acc[..valid]);
        }
    }
}

/// `dw[k×n] += xᵀ[k×m] · dz[m×n]`: i-serial per element with the naive
/// `x == 0.0` skip.  The per-element sum is formed in registers from 0.0
/// and added to `dw` once — the naive "fresh dw buffer, then
/// `accumulate`" float order.
pub fn gemm_dw_acc(x: &[f32], dz: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    let np = panels_of(n);
    for t in 0..k {
        for p in 0..np {
            let base = p * NR;
            let valid = NR.min(n - base);
            if valid == NR {
                let mut acc = [0.0f32; NR];
                for i in 0..m {
                    let xv = x[i * k + t];
                    if xv == 0.0 {
                        continue;
                    }
                    let dzr = &dz[i * n + base..i * n + base + NR];
                    for r in 0..NR {
                        acc[r] += xv * dzr[r];
                    }
                }
                let dst = &mut dw[t * n + base..t * n + base + NR];
                for r in 0..NR {
                    dst[r] += acc[r];
                }
            } else {
                let mut acc = [0.0f32; NR];
                for i in 0..m {
                    let xv = x[i * k + t];
                    if xv == 0.0 {
                        continue;
                    }
                    let dzr = &dz[i * n + base..i * n + base + valid];
                    for (a, &g) in acc.iter_mut().zip(dzr) {
                        *a += xv * g;
                    }
                }
                let dst = &mut dw[t * n + base..t * n + base + valid];
                for (d, a) in dst.iter_mut().zip(&acc) {
                    *d += a;
                }
            }
        }
    }
}

/// `db[n] += Σ_rows dz`: i-serial per element, register-accumulated.
pub fn db_acc(dz: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(db.len(), n);
    let np = panels_of(n);
    for p in 0..np {
        let base = p * NR;
        let valid = NR.min(n - base);
        let mut acc = [0.0f32; NR];
        for i in 0..m {
            let dzr = &dz[i * n + base..i * n + base + valid];
            for (a, &g) in acc.iter_mut().zip(dzr) {
                *a += g;
            }
        }
        let dst = &mut db[base..base + valid];
        for (d, a) in dst.iter_mut().zip(&acc) {
            *d += a;
        }
    }
}

// ---------------------------------------------------------------------------
// generation-keyed pack cache
// ---------------------------------------------------------------------------

/// Distinct θ/φ source buffers tracked before the cache resets.  A run
/// touches a handful (live θ, serving θ, SimSiam φ, policy snapshots);
/// the cap only guards against pathological buf-id churn.
const PACK_SRC_CAP: usize = 12;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct PackKey {
    /// `Value::buf_id` of the buffer holding the weights (θ or φ).
    src: u64,
    /// Tensor offset of `w` within that buffer.
    off: usize,
    /// Transposed (dx-kernel) pack?
    transposed: bool,
    /// Fake-quant fused into the pack (QAT)?
    quant: bool,
}

/// Released pack buffers kept for reuse (per-generation re-packs in a
/// train loop recycle the previous generation's storage, so steady-state
/// training allocates no pack memory either).
const SPARE_CAP: usize = 64;

/// Packed-panel cache keyed by `(buf id, offset, direction, quant)`.
/// See the module docs for the invalidation contract.
#[derive(Default)]
pub struct PackCache {
    map: HashMap<PackKey, Panels>,
    /// entries per src buf id, maintained incrementally (the src cap
    /// check must not rescan the map on every per-generation pack miss).
    src_counts: HashMap<u64, usize>,
    spare: Vec<Vec<f32>>,
    built: u64,
    hits: u64,
}

impl PackCache {
    pub fn new() -> PackCache {
        PackCache::default()
    }

    fn get(
        &mut self,
        key: PackKey,
        w: &[f32],
        k: usize,
        n: usize,
    ) -> &Panels {
        if self.map.contains_key(&key) {
            self.hits += 1;
        } else {
            if !self.src_counts.contains_key(&key.src)
                && self.src_counts.len() >= PACK_SRC_CAP
            {
                let spare = &mut self.spare;
                for (_, p) in self.map.drain() {
                    if spare.len() < SPARE_CAP {
                        spare.push(p.data);
                    }
                }
                self.src_counts.clear();
            }
            let buf = self.spare.pop().unwrap_or_default();
            let pan = pack_with(buf, w, k, n, key.transposed, key.quant);
            self.built += 1;
            self.map.insert(key, pan);
            *self.src_counts.entry(key.src).or_insert(0) += 1;
        }
        self.map.get(&key).unwrap()
    }

    /// Forward panels for `w = buf[off .. off + k*n]`.
    pub fn fwd(&mut self, src: u64, off: usize, w: &[f32], k: usize, n: usize, quant: bool) -> &Panels {
        self.get(PackKey { src, off, transposed: false, quant }, w, k, n)
    }

    /// Transposed panels (dx kernel) for the same weights.
    pub fn bwd(&mut self, src: u64, off: usize, w: &[f32], k: usize, n: usize, quant: bool) -> &Panels {
        self.get(PackKey { src, off, transposed: true, quant }, w, k, n)
    }

    /// Drop every pack derived from buffer `src` (the session's
    /// generation-keyed invalidation hook calls this via
    /// [`crate::runtime::Backend::release`]), keeping the storage for the
    /// next generation's packs.
    pub fn release(&mut self, src: u64) {
        if self.src_counts.remove(&src).is_none() {
            return; // nothing packed from this buffer
        }
        let keys: Vec<PackKey> = self.map.keys().filter(|k| k.src == src).copied().collect();
        for key in keys {
            if let Some(p) = self.map.remove(&key) {
                if self.spare.len() < SPARE_CAP {
                    self.spare.push(p.data);
                }
            }
        }
    }

    /// Layer packs built since creation.
    pub fn built(&self) -> u64 {
        self.built
    }

    /// GEMM calls that found their panels already packed.
    pub fn hits(&self) -> u64 {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn pack_roundtrips_oddly_shaped_weights() {
        let (k, n) = (5, 11); // n not a multiple of NR
        let mut rng = Pcg32::new(3, 1);
        let w = randv(&mut rng, k * n);
        let pan = pack_w(&w, k, n, false);
        assert_eq!((pan.depth(), pan.width()), (k, n));
        let zeros = vec![0.0f32; n];
        // identity probe: x = e_t row picks out w row t exactly
        for t in 0..k {
            let mut x = vec![0.0f32; k];
            x[t] = 1.0;
            let mut out = vec![0.0f32; n];
            gemm_fwd(&x, &pan, &zeros, 1, Act::None, &mut out);
            assert_eq!(out, w[t * n..(t + 1) * n].to_vec(), "row {t}");
        }
    }

    #[test]
    fn transposed_pack_matches_forward_pack() {
        let (k, n) = (7, 9);
        let mut rng = Pcg32::new(4, 2);
        let w = randv(&mut rng, k * n);
        let pt = pack_wt(&w, k, n, false);
        assert_eq!((pt.depth(), pt.width()), (n, k));
        // dz = e_j row: dx must be w column j (= wᵀ row j)
        for j in 0..n {
            let mut dz = vec![0.0f32; n];
            dz[j] = 1.0;
            let mut dx = vec![0.0f32; k];
            gemm_dx(&dz, &pt, 1, &mut dx);
            let want: Vec<f32> = (0..k).map(|t| w[t * n + j]).collect();
            assert_eq!(dx, want, "col {j}");
        }
    }

    #[test]
    fn quant_pack_equals_elementwise_fake_quant() {
        let (k, n) = (6, 10);
        let mut rng = Pcg32::new(5, 3);
        let w = randv(&mut rng, k * n);
        let s = quant_scale(&w);
        let pan = pack_w(&w, k, n, true);
        let zeros = vec![0.0f32; n];
        for t in 0..k {
            let mut x = vec![0.0f32; k];
            x[t] = 1.0;
            let mut out = vec![0.0f32; n];
            gemm_fwd(&x, &pan, &zeros, 1, Act::None, &mut out);
            for j in 0..n {
                assert_eq!(
                    out[j].to_bits(),
                    quant_elem(w[t * n + j], s).to_bits(),
                    "({t},{j})"
                );
            }
        }
    }

    #[test]
    fn pack_cache_hits_same_source_and_releases() {
        let mut c = PackCache::new();
        let w = vec![1.0f32; 4 * 4];
        c.fwd(10, 0, &w, 4, 4, false);
        assert_eq!((c.built(), c.hits()), (1, 0));
        c.fwd(10, 0, &w, 4, 4, false);
        assert_eq!((c.built(), c.hits()), (1, 1));
        // different direction and quant are distinct packs
        c.bwd(10, 0, &w, 4, 4, false);
        c.fwd(10, 0, &w, 4, 4, true);
        assert_eq!(c.built(), 3);
        // a new source (new θ generation) re-packs
        c.fwd(11, 0, &w, 4, 4, false);
        assert_eq!(c.built(), 4);
        c.release(10);
        c.fwd(10, 0, &w, 4, 4, false);
        assert_eq!(c.built(), 5, "released packs must rebuild");
    }

    #[test]
    fn quantize_into_matches_scale_and_is_idempotent() {
        let v = vec![-1.3f32, 0.0, 0.4, 2.7];
        let mut q = vec![0.0f32; 4];
        quantize_into(&v, &mut q);
        let mut qq = vec![0.0f32; 4];
        quantize_into(&q, &mut qq);
        for (a, b) in q.iter().zip(&qq) {
            assert!((a - b).abs() < 1e-6);
        }
        for (&orig, &quant) in v.iter().zip(&q) {
            assert!((orig - quant).abs() <= 2.7 / 127.0 + 1e-6);
        }
    }
}
