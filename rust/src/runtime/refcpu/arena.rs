//! Scratch arena for the reference execution core.
//!
//! Every intermediate buffer of a forward/backward/train call —
//! activations, tapes, cotangents, quantized copies, the flat gradient —
//! used to be a fresh `Vec` per call.  The arena recycles them: buffers
//! are bucketed by exact length, `take` pops a recycled buffer (or
//! allocates once, on first use of that size), `give` returns it.  Since
//! each segment executes the same take/give sequence every call, the
//! steady state after one warm-up execute is **zero fresh allocations**
//! per call — asserted by `tests/perf_regression.rs` through the
//! [`crate::runtime::BackendPerf`] counter surface.
//!
//! Bit-identity note: recycling changes *where* a kernel writes, never
//! *what* it computes — buffers from [`Arena::take`] carry stale contents
//! under a fully-overwritten contract, and accumulation targets use
//! [`Arena::take_zeroed`], which matches the `vec![0.0; n]` the naive
//! kernels started from.

use std::collections::HashMap;

/// Length-bucketed free list of `f32` scratch buffers + counters.
#[derive(Default)]
pub struct Arena {
    buckets: HashMap<usize, Vec<Vec<f32>>>,
    fresh: u64,
    reuses: u64,
    bytes_reused: u64,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// A buffer of exactly `len` elements with **unspecified contents**.
    /// Callers must fully overwrite it (use [`Arena::take_zeroed`] for
    /// accumulation targets).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(v) = self.buckets.get_mut(&len).and_then(|b| b.pop()) {
            debug_assert_eq!(v.len(), len);
            self.reuses += 1;
            self.bytes_reused += 4 * len as u64;
            v
        } else {
            self.fresh += 1;
            vec![0.0; len]
        }
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take(len);
        v.iter_mut().for_each(|x| *x = 0.0);
        v
    }

    /// Return a buffer to its length bucket for reuse.
    pub fn give(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        self.buckets.entry(v.len()).or_default().push(v);
    }

    /// Fresh allocations performed (arena misses).
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh
    }

    /// Buffers served from the free list.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// Bytes handed out from recycled buffers.
    pub fn bytes_reused(&self) -> u64 {
        self.bytes_reused
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_take_of_a_size_reuses() {
        let mut a = Arena::new();
        let v = a.take(64);
        assert_eq!(v.len(), 64);
        assert_eq!(a.fresh_allocs(), 1);
        a.give(v);
        let w = a.take(64);
        assert_eq!(w.len(), 64);
        assert_eq!(a.fresh_allocs(), 1, "recycled buffer not reused");
        assert_eq!(a.reuses(), 1);
        assert_eq!(a.bytes_reused(), 256);
    }

    #[test]
    fn sizes_bucket_independently() {
        let mut a = Arena::new();
        let v = a.take(8);
        a.give(v);
        let w = a.take(16); // different size: fresh
        assert_eq!(a.fresh_allocs(), 2);
        a.give(w);
        let _ = a.take(8);
        let _ = a.take(16);
        assert_eq!(a.fresh_allocs(), 2);
        assert_eq!(a.reuses(), 2);
    }

    #[test]
    fn take_zeroed_clears_stale_contents() {
        let mut a = Arena::new();
        let mut v = a.take(4);
        v.fill(7.0);
        a.give(v);
        let z = a.take_zeroed(4);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn empty_buffers_are_not_pooled() {
        let mut a = Arena::new();
        a.give(Vec::new());
        let v = a.take(0);
        assert_eq!(a.fresh_allocs(), 1);
        assert!(v.is_empty());
    }
}
