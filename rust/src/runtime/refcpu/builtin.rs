//! Built-in model family: the manifest aot.py would emit, synthesized in
//! pure Rust so the reference backend runs with **no artifact directory at
//! all** (the CI case).
//!
//! Mirrors `python/compile/model.py` exactly: the four deployed proxies
//! (res50 / mbv2 / deit / bert), the flat-θ layout
//! `[embed, block_1..L, head]` with per-block `(ln_s, ln_b,) w1, b1, w2,
//! b2` tensors, the paper-scale per-unit cost anchors (embed 7%, head 2%,
//! blocks splitting the rest with later blocks heavier), and the artifact
//! segment names (`<model>_infer`, `<model>_train_<k>`, …).
//!
//! θ0 follows the same init rules as `init_theta`: biases and `ln_b` zero,
//! `ln_s` one, residual-exit `w2` zero (ReZero — the fresh model is
//! numerically tame at any depth), every other weight He-style
//! `N(0, 2/fan_in)`.  Draws come from a [`Pcg32`] seeded by the model
//! name, so θ0 is deterministic per model across processes and worker
//! threads.  (With an artifact directory present the reference backend
//! loads aot.py's manifest + θ0 binaries instead, for cross-backend
//! parity.)

use std::collections::BTreeMap;

use crate::rng::Pcg32;
use crate::runtime::artifact::{
    ArtifactNames, HeadInfo, Manifest, ModelManifest, PaperUnit, Segment,
    TensorInfo,
};

const BATCH_TRAIN: usize = 16;
const BATCH_INFER: usize = 64;
const BATCH_PROBE: usize = 16;

struct Spec {
    name: &'static str,
    d: usize,
    h: usize,
    blocks: usize,
    classes: usize,
    kind: &'static str,
    expansion: usize,
    paper_fwd_gflops: f64,
    paper_params_mb: f64,
    quant: bool,
    ssl: bool,
}

fn specs() -> Vec<Spec> {
    vec![
        Spec {
            name: "res50", d: 128, h: 64, blocks: 8, classes: 50,
            kind: "relu_res", expansion: 1,
            paper_fwd_gflops: 4.1, paper_params_mb: 97.8,
            quant: true, ssl: true,
        },
        Spec {
            name: "mbv2", d: 128, h: 48, blocks: 6, classes: 50,
            kind: "bottleneck", expansion: 2,
            paper_fwd_gflops: 0.31, paper_params_mb: 13.4,
            quant: false, ssl: true,
        },
        Spec {
            name: "deit", d: 128, h: 56, blocks: 6, classes: 50,
            kind: "preln_gelu", expansion: 2,
            paper_fwd_gflops: 1.26, paper_params_mb: 21.8,
            quant: false, ssl: true,
        },
        Spec {
            name: "bert", d: 128, h: 64, blocks: 4, classes: 20,
            kind: "preln_gelu", expansion: 2,
            paper_fwd_gflops: 22.4, paper_params_mb: 419.0,
            quant: false, ssl: false,
        },
    ]
}

/// Flat-θ layout of one spec (mirrors `layout()` in model.py).
fn layout(s: &Spec) -> Vec<TensorInfo> {
    let e = s.h * s.expansion;
    let mut tensors = Vec::new();
    let mut off = 0usize;
    let mut add = |name: String, shape: Vec<usize>, unit: usize, off: &mut usize| {
        let size: usize = shape.iter().product();
        tensors.push(TensorInfo { name, shape, unit, offset: *off });
        *off += size;
    };
    add("embed.w".into(), vec![s.d, s.h], 0, &mut off);
    add("embed.b".into(), vec![s.h], 0, &mut off);
    for i in 1..=s.blocks {
        if s.kind == "preln_gelu" {
            add(format!("block{i}.ln_s"), vec![s.h], i, &mut off);
            add(format!("block{i}.ln_b"), vec![s.h], i, &mut off);
        }
        add(format!("block{i}.w1"), vec![s.h, e], i, &mut off);
        add(format!("block{i}.b1"), vec![e], i, &mut off);
        add(format!("block{i}.w2"), vec![e, s.h], i, &mut off);
        add(format!("block{i}.b2"), vec![s.h], i, &mut off);
    }
    let head_unit = s.blocks + 1;
    add("head.w".into(), vec![s.h, s.classes], head_unit, &mut off);
    add("head.b".into(), vec![s.classes], head_unit, &mut off);
    tensors
}

fn unit_segments(tensors: &[TensorInfo], units: usize) -> Vec<Segment> {
    (0..units)
        .map(|u| {
            let ts: Vec<&TensorInfo> =
                tensors.iter().filter(|t| t.unit == u).collect();
            let lo = ts.iter().map(|t| t.offset).min().unwrap();
            let hi = ts.iter().map(|t| t.offset + t.size()).max().unwrap();
            Segment { offset: lo, len: hi - lo }
        })
        .collect()
}

/// Paper-scale per-unit cost anchors (embed 7%, head 2%, blocks split the
/// rest with weight `1 + i/L`).
fn paper_units(s: &Spec) -> Vec<PaperUnit> {
    let l = s.blocks;
    let fwd_total = s.paper_fwd_gflops * 1e9;
    let bytes_total = s.paper_params_mb * 1e6;
    let (embed_frac, head_frac) = (0.07, 0.02);
    let rest = 1.0 - embed_frac - head_frac;
    let ws: Vec<f64> = (1..=l).map(|i| 1.0 + i as f64 / l as f64).collect();
    let wsum: f64 = ws.iter().sum();
    let mut fracs = vec![embed_frac];
    fracs.extend(ws.iter().map(|w| rest * w / wsum));
    fracs.push(head_frac);
    fracs
        .iter()
        .map(|f| PaperUnit { fwd_flops: fwd_total * f, param_bytes: bytes_total * f })
        .collect()
}

fn model_manifest(s: &Spec) -> ModelManifest {
    let tensors = layout(s);
    let units = s.blocks + 2;
    let theta_len = tensors.iter().map(|t| t.size()).sum();
    let head_w = tensors.iter().find(|t| t.name == "head.w").unwrap();
    let head_b = tensors.iter().find(|t| t.name == "head.b").unwrap();
    let head = HeadInfo {
        w_offset: head_w.offset,
        w_shape: [s.h, s.classes],
        b_offset: head_b.offset,
        classes: s.classes,
    };
    let train: Vec<String> =
        (0..units).map(|k| format!("{}_train_{k}", s.name)).collect();
    let train_q: Vec<String> = if s.quant {
        (0..units).map(|k| format!("{}_train_q_{k}", s.name)).collect()
    } else {
        vec![]
    };
    let artifacts = ArtifactNames {
        infer: format!("{}_infer", s.name),
        features: format!("{}_features", s.name),
        train,
        train_q,
        ssl: s.ssl.then(|| format!("{}_ssl", s.name)),
        ssl_phi_len: if s.ssl { 2 * s.h * s.h + 2 * s.h } else { 0 },
    };
    ModelManifest {
        name: s.name.to_string(),
        d: s.d,
        h: s.h,
        blocks: s.blocks,
        classes: s.classes,
        units,
        kind: s.kind.to_string(),
        theta_len,
        batch_train: BATCH_TRAIN,
        batch_infer: BATCH_INFER,
        batch_probe: BATCH_PROBE,
        unit_segments: unit_segments(&tensors, units),
        head,
        paper_units: paper_units(s),
        tensors,
        artifacts,
    }
}

/// The full built-in manifest (models + cka segments per feature width).
pub fn manifest() -> Manifest {
    let mut models = BTreeMap::new();
    let mut cka = BTreeMap::new();
    for s in specs() {
        cka.insert(s.h, format!("cka_{}", s.h));
        models.insert(s.name.to_string(), model_manifest(&s));
    }
    Manifest { models, cka }
}

fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// He/ReZero init over a tensor list (the init_theta rules).
fn init_over(tensors: &[(String, Vec<usize>)], seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, 0x7E7A);
    let mut out = Vec::new();
    for (name, shape) in tensors {
        let size: usize = shape.iter().product();
        if name.ends_with(".b")
            || name.ends_with(".b1")
            || name.ends_with(".b2")
            || name.ends_with(".ln_b")
        {
            out.extend(std::iter::repeat(0.0f32).take(size));
        } else if name.ends_with(".ln_s") {
            out.extend(std::iter::repeat(1.0f32).take(size));
        } else if name.ends_with(".w2") {
            // ReZero: residual branches start as identity.
            out.extend(std::iter::repeat(0.0f32).take(size));
        } else {
            let fan_in = shape[0] as f32;
            let std = (2.0 / fan_in).sqrt();
            out.extend((0..size).map(|_| std * rng.normal()));
        }
    }
    out
}

/// Deterministic θ0 for a built-in model.
pub fn theta0(m: &ModelManifest) -> Vec<f32> {
    let tensors: Vec<(String, Vec<usize>)> = m
        .tensors
        .iter()
        .map(|t| (t.name.clone(), t.shape.clone()))
        .collect();
    init_over(&tensors, fnv1a(&m.name) ^ 0x17)
}

/// Deterministic φ0 (SimSiam projector/predictor) for a built-in model.
pub fn phi0(m: &ModelManifest) -> Vec<f32> {
    let h = m.h;
    let tensors = vec![
        ("proj.w".to_string(), vec![h, h]),
        ("proj.b".to_string(), vec![h]),
        ("pred.w".to_string(), vec![h, h]),
        ("pred.b".to_string(), vec![h]),
    ];
    init_over(&tensors, fnv1a(&m.name) ^ 0x18)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_is_consistent() {
        let m = manifest();
        assert_eq!(m.models.len(), 4);
        for (name, mm) in &m.models {
            assert_eq!(mm.units, mm.blocks + 2);
            assert_eq!(mm.artifacts.train.len(), mm.units);
            assert_eq!(mm.unit_segments.len(), mm.units);
            // segments tile θ contiguously
            let mut off = 0;
            for s in &mm.unit_segments {
                assert_eq!(s.offset, off, "{name}: segment gap");
                off += s.len;
            }
            assert_eq!(off, mm.theta_len, "{name}: segments != theta_len");
            assert_eq!(theta0(mm).len(), mm.theta_len);
            assert!(m.cka.contains_key(&mm.h));
            if mm.artifacts.ssl.is_some() {
                assert_eq!(phi0(mm).len(), mm.artifacts.ssl_phi_len);
            }
        }
        // paper-unit fractions reassemble the headline totals
        assert!((m.models["res50"].paper_fwd_flops() / 4.1e9 - 1.0).abs() < 1e-6);
        assert!((m.models["mbv2"].paper_param_bytes() / 13.4e6 - 1.0).abs() < 1e-6);
        // quant artifacts are res50-only, ssl excludes bert (aot.py rules)
        assert!(!m.models["res50"].artifacts.train_q.is_empty());
        assert!(m.models["mbv2"].artifacts.train_q.is_empty());
        assert!(m.models["bert"].artifacts.ssl.is_none());
        assert!(m.models["deit"].artifacts.ssl.is_some());
    }

    #[test]
    fn theta0_is_deterministic_and_rezero() {
        let m = manifest();
        let mm = m.models.get("mbv2").unwrap();
        let a = theta0(mm);
        let b = theta0(mm);
        assert_eq!(a, b);
        // w2 tensors (residual exits) start at zero; embed.w does not
        let w2 = mm.tensors.iter().find(|t| t.name == "block1.w2").unwrap();
        assert!(a[w2.offset..w2.offset + w2.size()].iter().all(|&v| v == 0.0));
        let ew = mm.tensors.iter().find(|t| t.name == "embed.w").unwrap();
        assert!(a[ew.offset..ew.offset + ew.size()].iter().any(|&v| v != 0.0));
        // different models draw different θ0
        let other = theta0(m.models.get("res50").unwrap());
        assert_ne!(a[0], other[0]);
    }
}
