//! Deterministic fault injection at the execute boundary.
//!
//! [`FaultyBackend`] decorates any [`Backend`] and injects three failure
//! modes, all drawn from a seeded [`Pcg32`] stream (same discipline as
//! `rng.rs` — runs are exactly reproducible from `(run seed, fault
//! seed)`, independent of sweep worker count or wall clock):
//!
//! * **execute errors** — `execute()` returns `Err` with probability
//!   `exec` per call; `burst:N` makes each fault *persistent* for N
//!   consecutive calls (a transient glitch vs a wedged executor),
//! * **marshal errors** — same for `marshal_f32`/`marshal_i32`,
//! * **latency spikes** — successful executes accumulate `spike_s`
//!   virtual seconds with probability `spike`; the serving engine drains
//!   them via [`Backend::take_injected_delay_s`] and charges them through
//!   `DeviceModel`, so spikes cost *virtual* time, never wall clock.
//!
//! The spec grammar (`--faults`, `ETUNER_FAULTS`) is comma-separated
//! `key:value` pairs: `exec:0.05,marshal:0.01,spike:0.02x0.5,burst:3`
//! (5% execute faults, 1% marshal faults, 2% of executes spike by 0.5
//! virtual seconds, faults wedge for 3 consecutive calls).  `none` or the
//! empty string disables everything.
//!
//! [`FaultPlan::none()`] is a true zero-cost passthrough: `sim::run_config`
//! only constructs the decorator when the plan is enabled, so the default
//! configuration executes the exact same code as before this module
//! existed and its `Report::fingerprint` is bit-identical.

use std::cell::RefCell;
use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::rng::Pcg32;

use super::artifact::Manifest;
use super::backend::{Backend, BackendPerf, FaultStats, Value};

/// Salt mixed into the fault RNG seed so the fault stream never collides
/// with the simulation's data/arrival streams for the same run seed.
const FAULT_SEED_SALT: u64 = 0xFA17_0B5E_77ED_C0DE;

/// A seeded, declarative fault schedule (see the module docs for the
/// spec grammar).  `Default` is [`FaultPlan::none`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Per-call probability that `execute` fails.
    pub exec_rate: f64,
    /// Per-call probability that `marshal_f32`/`marshal_i32` fails.
    pub marshal_rate: f64,
    /// Per-successful-execute probability of a latency spike.
    pub spike_rate: f64,
    /// Virtual seconds added per spike.
    pub spike_s: f64,
    /// Consecutive calls each fault persists for (1 = transient).
    pub burst: u32,
    /// Extra seed mixed into the fault RNG (`--fault-seed`).
    pub seed: u64,
    /// Crash (kill the run) at the Nth fine-tuning round boundary
    /// (`crash:after-round-N`; 0 = off).
    pub crash_after_round: u64,
    /// Crash at the first round boundary with virtual time >= this
    /// (`crash:t=S`; negative = off).
    pub crash_t: f64,
    /// Per-round-boundary crash probability, drawn from a dedicated
    /// seeded stream (`crash:R`; 0 = off).
    pub crash_rate: f64,
    /// Flip one bit in the payload of the Nth checkpoint record written
    /// (1-based; `ckpt-flip:N`; 0 = off) — recovery must detect the bad
    /// checksum and fall back.
    pub ckpt_flip: u64,
    /// Truncate the Nth checkpoint record mid-write (1-based;
    /// `ckpt-torn:N`; 0 = off) — a torn write recovery must skip.
    pub ckpt_torn: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The empty plan: nothing is ever injected.
    pub fn none() -> FaultPlan {
        FaultPlan {
            exec_rate: 0.0,
            marshal_rate: 0.0,
            spike_rate: 0.0,
            spike_s: 0.0,
            burst: 1,
            seed: 0,
            crash_after_round: 0,
            crash_t: -1.0,
            crash_rate: 0.0,
            ckpt_flip: 0,
            ckpt_torn: 0,
        }
    }

    /// True if any *backend* fault mode can fire.  `sim::run_config` wraps
    /// the backend only when this holds — a disabled plan costs nothing.
    /// Crash/corruption points live in the simulation and checkpoint
    /// writer respectively, not in [`FaultyBackend`], so they are
    /// deliberately excluded here: a crash-only plan constructs no
    /// backend decorator.
    pub fn enabled(&self) -> bool {
        self.exec_rate > 0.0 || self.marshal_rate > 0.0 || self.spike_rate > 0.0
    }

    /// True if any crash point can fire (evaluated by the simulation at
    /// round boundaries).
    pub fn crash_enabled(&self) -> bool {
        self.crash_after_round > 0 || self.crash_t >= 0.0 || self.crash_rate > 0.0
    }

    /// True if checkpoint-file corruption is scheduled (applied by the
    /// checkpoint writer as records are framed).
    pub fn corruption_enabled(&self) -> bool {
        self.ckpt_flip > 0 || self.ckpt_torn > 0
    }

    /// Parse the `--faults` spec grammar (module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        let spec = spec.trim();
        if spec.is_empty() || spec.eq_ignore_ascii_case("none") {
            return Ok(plan);
        }
        for part in spec.split(',') {
            let part = part.trim();
            let (key, val) = part.split_once(':').ok_or_else(|| {
                anyhow::anyhow!(
                    "bad fault spec part {part:?} (expected key:value)"
                )
            })?;
            match key.to_ascii_lowercase().as_str() {
                "exec" => plan.exec_rate = parse_rate(val, "exec")?,
                "marshal" => plan.marshal_rate = parse_rate(val, "marshal")?,
                "spike" => {
                    let (rate, secs) = val.split_once('x').ok_or_else(|| {
                        anyhow::anyhow!(
                            "bad spike spec {val:?} (expected RATExSECONDS, \
                             e.g. spike:0.01x0.5)"
                        )
                    })?;
                    plan.spike_rate = parse_rate(rate, "spike")?;
                    plan.spike_s = secs.parse().map_err(|_| {
                        anyhow::anyhow!("bad spike seconds {secs:?}")
                    })?;
                    if plan.spike_s < 0.0 {
                        bail!("spike seconds must be >= 0, got {}", plan.spike_s);
                    }
                }
                "burst" => {
                    plan.burst = val.parse().map_err(|_| {
                        anyhow::anyhow!("bad burst count {val:?}")
                    })?;
                    if plan.burst == 0 {
                        bail!("burst must be >= 1 (1 = transient)");
                    }
                }
                "seed" => {
                    plan.seed = val.parse().map_err(|_| {
                        anyhow::anyhow!("bad fault seed {val:?}")
                    })?;
                }
                "crash" => {
                    if let Some(n) = val.strip_prefix("after-round-") {
                        plan.crash_after_round = n.parse().map_err(|_| {
                            anyhow::anyhow!("bad crash round {n:?}")
                        })?;
                        if plan.crash_after_round == 0 {
                            bail!("crash:after-round-N needs N >= 1");
                        }
                    } else if let Some(s) = val.strip_prefix("t=") {
                        plan.crash_t = s.parse().map_err(|_| {
                            anyhow::anyhow!("bad crash time {s:?}")
                        })?;
                        if plan.crash_t < 0.0 {
                            bail!("crash:t=S needs S >= 0, got {}", plan.crash_t);
                        }
                    } else {
                        plan.crash_rate = parse_rate(val, "crash")?;
                    }
                }
                "ckpt-flip" => {
                    plan.ckpt_flip = val.parse().map_err(|_| {
                        anyhow::anyhow!("bad ckpt-flip record index {val:?}")
                    })?;
                    if plan.ckpt_flip == 0 {
                        bail!("ckpt-flip:N is 1-based (N >= 1)");
                    }
                }
                "ckpt-torn" => {
                    plan.ckpt_torn = val.parse().map_err(|_| {
                        anyhow::anyhow!("bad ckpt-torn record index {val:?}")
                    })?;
                    if plan.ckpt_torn == 0 {
                        bail!("ckpt-torn:N is 1-based (N >= 1)");
                    }
                }
                other => bail!(
                    "unknown fault spec key {other:?} \
                     (expected exec|marshal|spike|burst|seed|crash|\
                     ckpt-flip|ckpt-torn)"
                ),
            }
        }
        Ok(plan)
    }

    /// Render back to the spec grammar (logs, tables).
    pub fn spec(&self) -> String {
        if !self.enabled() && !self.crash_enabled() && !self.corruption_enabled()
        {
            return "none".into();
        }
        let mut parts = Vec::new();
        if self.exec_rate > 0.0 {
            parts.push(format!("exec:{}", self.exec_rate));
        }
        if self.marshal_rate > 0.0 {
            parts.push(format!("marshal:{}", self.marshal_rate));
        }
        if self.spike_rate > 0.0 {
            parts.push(format!("spike:{}x{}", self.spike_rate, self.spike_s));
        }
        if self.burst > 1 {
            parts.push(format!("burst:{}", self.burst));
        }
        if self.crash_after_round > 0 {
            parts.push(format!("crash:after-round-{}", self.crash_after_round));
        }
        if self.crash_t >= 0.0 {
            parts.push(format!("crash:t={}", self.crash_t));
        }
        if self.crash_rate > 0.0 {
            parts.push(format!("crash:{}", self.crash_rate));
        }
        if self.ckpt_flip > 0 {
            parts.push(format!("ckpt-flip:{}", self.ckpt_flip));
        }
        if self.ckpt_torn > 0 {
            parts.push(format!("ckpt-torn:{}", self.ckpt_torn));
        }
        parts.join(",")
    }
}

fn parse_rate(s: &str, key: &str) -> Result<f64> {
    let r: f64 = s
        .parse()
        .map_err(|_| anyhow::anyhow!("bad {key} rate {s:?}"))?;
    if !(0.0..=1.0).contains(&r) {
        bail!("{key} rate must be in [0, 1], got {r}");
    }
    Ok(r)
}

/// The fault plan from `ETUNER_FAULTS` / `ETUNER_FAULT_SEED`, or
/// [`FaultPlan::none`] when unset.  Cached for the process lifetime so
/// `RunConfig::quickstart` stays cheap in sweep loops; `make ci-faults`
/// sets these to run the whole tier-1 suite under a fixed plan.
pub fn env_plan() -> FaultPlan {
    static PLAN: OnceLock<FaultPlan> = OnceLock::new();
    *PLAN.get_or_init(|| {
        let mut plan = match std::env::var("ETUNER_FAULTS") {
            Ok(s) => FaultPlan::parse(&s).unwrap_or_else(|e| {
                crate::trace::note(format_args!(
                    "[etuner] ignoring bad ETUNER_FAULTS: {e}"
                ));
                FaultPlan::none()
            }),
            Err(_) => FaultPlan::none(),
        };
        if let Ok(s) = std::env::var("ETUNER_FAULT_SEED") {
            match s.parse() {
                Ok(v) => plan.seed = v,
                Err(_) => crate::trace::note(format_args!(
                    "[etuner] ignoring bad ETUNER_FAULT_SEED {s:?}"
                )),
            }
        }
        plan
    })
}

struct FaultState {
    rng: Pcg32,
    /// Remaining calls the current execute fault persists for.
    exec_burst_left: u32,
    /// Remaining calls the current marshal fault persists for.
    marshal_burst_left: u32,
    /// Injected virtual latency not yet drained by the engine.
    pending_delay_s: f64,
    stats: FaultStats,
}

/// Fault-injecting decorator over any backend (see the module docs).
///
/// Borrows the inner backend for the duration of one simulation run —
/// `sim::run_config` constructs it on the stack per run, seeded from
/// `(cfg.seed, plan.seed)`, so the injected fault sequence is a pure
/// function of the config and identical no matter which sweep worker
/// executes the run.
pub struct FaultyBackend<'a> {
    inner: &'a dyn Backend,
    plan: FaultPlan,
    st: RefCell<FaultState>,
}

impl<'a> FaultyBackend<'a> {
    /// Wrap `inner`, seeding the fault stream from the run seed and the
    /// plan's own seed.
    pub fn new(inner: &'a dyn Backend, plan: FaultPlan, run_seed: u64) -> Self {
        let seed = run_seed
            ^ plan.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ FAULT_SEED_SALT;
        FaultyBackend {
            inner,
            plan,
            st: RefCell::new(FaultState {
                rng: Pcg32::new(seed, 0xFA17),
                exec_burst_left: 0,
                marshal_burst_left: 0,
                pending_delay_s: 0.0,
                stats: FaultStats::default(),
            }),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide whether an execute call faults (burst continuation or a
    /// fresh draw); spikes only charge on calls that will succeed.
    fn execute_fault(&self, name: &str) -> Result<()> {
        let mut st = self.st.borrow_mut();
        if st.exec_burst_left > 0 {
            st.exec_burst_left -= 1;
            st.stats.exec_faults += 1;
            bail!("injected fault: execute({name}) failed (burst)");
        }
        if self.plan.exec_rate > 0.0 && st.rng.f64() < self.plan.exec_rate {
            st.exec_burst_left = self.plan.burst.saturating_sub(1);
            st.stats.exec_faults += 1;
            bail!("injected fault: execute({name}) failed (transient)");
        }
        if self.plan.spike_rate > 0.0 && st.rng.f64() < self.plan.spike_rate {
            st.stats.latency_spikes += 1;
            st.stats.spike_s_total += self.plan.spike_s;
            st.pending_delay_s += self.plan.spike_s;
        }
        Ok(())
    }

    fn marshal_fault(&self, what: &str) -> Result<()> {
        let mut st = self.st.borrow_mut();
        if st.marshal_burst_left > 0 {
            st.marshal_burst_left -= 1;
            st.stats.marshal_faults += 1;
            bail!("injected fault: marshal({what}) failed (burst)");
        }
        if self.plan.marshal_rate > 0.0 && st.rng.f64() < self.plan.marshal_rate
        {
            st.marshal_burst_left = self.plan.burst.saturating_sub(1);
            st.stats.marshal_faults += 1;
            bail!("injected fault: marshal({what}) failed (transient)");
        }
        Ok(())
    }
}

impl Backend for FaultyBackend<'_> {
    fn name(&self) -> &'static str {
        // transparent: reports and logs show the real executor.
        self.inner.name()
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn executions(&self) -> u64 {
        self.inner.executions()
    }

    fn marshal_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        self.marshal_fault("f32")?;
        self.inner.marshal_f32(data, shape)
    }

    fn marshal_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        self.marshal_fault("i32")?;
        self.inner.marshal_i32(data, shape)
    }

    fn execute(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.execute_fault(name)?;
        self.inner.execute(name, inputs)
    }

    fn theta0(&self, model: &str) -> Result<Vec<f32>> {
        self.inner.theta0(model)
    }

    fn phi0(&self, model: &str) -> Result<Vec<f32>> {
        self.inner.phi0(model)
    }

    fn perf(&self) -> BackendPerf {
        self.inner.perf()
    }

    fn fault_stats(&self) -> FaultStats {
        self.st.borrow().stats
    }

    fn take_injected_delay_s(&self) -> f64 {
        std::mem::take(&mut self.st.borrow_mut().pending_delay_s)
    }

    /// Snapshot the fault stream for checkpointing: RNG state, burst
    /// counters, undrained spike delay, and the cumulative stats.  Fixed
    /// 64-byte little-endian layout; [`fault_state_load`] is the inverse.
    ///
    /// [`fault_state_load`]: Backend::fault_state_load
    fn fault_state_save(&self) -> Option<Vec<u8>> {
        let st = self.st.borrow();
        let (rs, ri) = st.rng.state();
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(&rs.to_le_bytes());
        out.extend_from_slice(&ri.to_le_bytes());
        out.extend_from_slice(&st.exec_burst_left.to_le_bytes());
        out.extend_from_slice(&st.marshal_burst_left.to_le_bytes());
        out.extend_from_slice(&st.pending_delay_s.to_le_bytes());
        out.extend_from_slice(&st.stats.exec_faults.to_le_bytes());
        out.extend_from_slice(&st.stats.marshal_faults.to_le_bytes());
        out.extend_from_slice(&st.stats.latency_spikes.to_le_bytes());
        out.extend_from_slice(&st.stats.spike_s_total.to_le_bytes());
        Some(out)
    }

    fn fault_state_load(&self, bytes: &[u8]) {
        if bytes.len() != 64 {
            return; // foreign/truncated blob: leave the fresh state alone.
        }
        let u64_at = |i: usize| {
            u64::from_le_bytes(bytes[i..i + 8].try_into().unwrap())
        };
        let u32_at = |i: usize| {
            u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap())
        };
        let mut st = self.st.borrow_mut();
        st.rng = Pcg32::from_state(u64_at(0), u64_at(8));
        st.exec_burst_left = u32_at(16);
        st.marshal_burst_left = u32_at(20);
        st.pending_delay_s = f64::from_bits(u64_at(24));
        st.stats.exec_faults = u64_at(32);
        st.stats.marshal_faults = u64_at(40);
        st.stats.latency_spikes = u64_at(48);
        st.stats.spike_s_total = f64::from_bits(u64_at(56));
    }

    fn warm(&self, segment: &str, theta: &Value) -> Result<()> {
        self.inner.warm(segment, theta)
    }

    fn release(&self, buf_id: u64) {
        self.inner.release(buf_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_round_trips() {
        let p = FaultPlan::parse("exec:0.05,marshal:0.01,spike:0.02x0.5,burst:3")
            .unwrap();
        assert_eq!(p.exec_rate, 0.05);
        assert_eq!(p.marshal_rate, 0.01);
        assert_eq!(p.spike_rate, 0.02);
        assert_eq!(p.spike_s, 0.5);
        assert_eq!(p.burst, 3);
        assert!(p.enabled());
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);
    }

    #[test]
    fn none_is_default_and_disabled() {
        assert_eq!(FaultPlan::parse("none").unwrap(), FaultPlan::none());
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::none());
        assert_eq!(FaultPlan::default(), FaultPlan::none());
        assert!(!FaultPlan::none().enabled());
        assert_eq!(FaultPlan::none().spec(), "none");
    }

    #[test]
    fn spec_rejects_nonsense() {
        assert!(FaultPlan::parse("exec:1.5").is_err());
        assert!(FaultPlan::parse("exec:-0.1").is_err());
        assert!(FaultPlan::parse("spike:0.1").is_err()); // missing xSECONDS
        assert!(FaultPlan::parse("burst:0").is_err());
        assert!(FaultPlan::parse("warp:0.1").is_err());
        assert!(FaultPlan::parse("exec").is_err());
    }

    #[test]
    fn injection_sequence_is_seed_deterministic() {
        let inner = crate::testkit::refcpu_backend();
        let plan = FaultPlan::parse("marshal:0.5").unwrap();
        let trial = |seed: u64| -> Vec<bool> {
            let fb = FaultyBackend::new(inner.as_ref(), plan, seed);
            (0..64)
                .map(|_| fb.marshal_f32(&[1.0], &[1]).is_err())
                .collect()
        };
        assert_eq!(trial(7), trial(7), "same seed, same fault sequence");
        assert_ne!(trial(7), trial(8), "different seeds diverge");
        let faults = trial(7).iter().filter(|&&f| f).count();
        assert!((16..=48).contains(&faults), "rate ~0.5, got {faults}/64");
    }

    #[test]
    fn burst_faults_persist_for_n_calls() {
        let inner = crate::testkit::refcpu_backend();
        let mut plan = FaultPlan::parse("marshal:0.05,burst:4").unwrap();
        plan.seed = 3;
        let fb = FaultyBackend::new(inner.as_ref(), plan, 1);
        let outcomes: Vec<bool> = (0..256)
            .map(|_| fb.marshal_f32(&[1.0], &[1]).is_err())
            .collect();
        // every fault must open a run of exactly `burst` consecutive
        // failures (two adjacent bursts merge into a longer run, so check
        // run lengths are multiples of nothing — simply ≥ burst).
        let mut i = 0;
        let mut saw_burst = false;
        while i < outcomes.len() {
            if outcomes[i] {
                let start = i;
                while i < outcomes.len() && outcomes[i] {
                    i += 1;
                }
                if i < outcomes.len() {
                    // complete run: length must be ≥ burst (merged runs
                    // can only be longer).
                    assert!(
                        i - start >= 4,
                        "fault run of {} < burst 4 at {start}",
                        i - start
                    );
                    saw_burst = true;
                }
            } else {
                i += 1;
            }
        }
        assert!(saw_burst, "no complete fault burst observed in 256 calls");
        assert!(fb.fault_stats().marshal_faults >= 4);
    }

    #[test]
    fn crash_grammar_round_trips_and_stays_out_of_enabled() {
        let p = FaultPlan::parse("crash:after-round-3").unwrap();
        assert_eq!(p.crash_after_round, 3);
        assert!(p.crash_enabled() && !p.enabled());
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);

        let p = FaultPlan::parse("crash:t=120.5").unwrap();
        assert_eq!(p.crash_t, 120.5);
        assert!(p.crash_enabled() && !p.enabled());
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);

        let p = FaultPlan::parse("crash:0.25,seed:9").unwrap();
        assert_eq!(p.crash_rate, 0.25);
        assert_eq!(p.seed, 9);
        assert!(p.crash_enabled() && !p.enabled());

        // combined with backend faults both gates hold
        let p = FaultPlan::parse("exec:0.1,crash:after-round-2").unwrap();
        assert!(p.enabled() && p.crash_enabled());
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);
    }

    #[test]
    fn corruption_grammar_round_trips() {
        let p = FaultPlan::parse("ckpt-flip:2").unwrap();
        assert_eq!(p.ckpt_flip, 2);
        assert!(p.corruption_enabled());
        assert!(!p.enabled() && !p.crash_enabled());
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);

        let p = FaultPlan::parse("ckpt-torn:1,crash:after-round-4").unwrap();
        assert_eq!(p.ckpt_torn, 1);
        assert!(p.corruption_enabled() && p.crash_enabled());
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);
    }

    #[test]
    fn crash_grammar_rejects_nonsense() {
        assert!(FaultPlan::parse("crash:after-round-0").is_err());
        assert!(FaultPlan::parse("crash:after-round-x").is_err());
        assert!(FaultPlan::parse("crash:t=-5").is_err());
        assert!(FaultPlan::parse("crash:1.5").is_err());
        assert!(FaultPlan::parse("ckpt-flip:0").is_err());
        assert!(FaultPlan::parse("ckpt-torn:0").is_err());
    }

    #[test]
    fn fault_state_round_trip_resumes_the_stream_bit_identically() {
        let inner = crate::testkit::refcpu_backend();
        let plan = FaultPlan::parse("marshal:0.5,spike:0.3x0.1,burst:2")
            .unwrap();
        let fb = FaultyBackend::new(inner.as_ref(), plan, 11);
        // advance mid-burst so every field is non-trivial
        for _ in 0..13 {
            let _ = fb.marshal_f32(&[1.0], &[1]);
            let _ = fb.execute("nonexistent-segment", &[]);
        }
        let blob = fb.fault_state_save().expect("faulty backend saves state");
        let stats0 = fb.fault_stats();
        let tail: Vec<bool> =
            (0..64).map(|_| fb.marshal_f32(&[1.0], &[1]).is_err()).collect();

        let fb2 = FaultyBackend::new(inner.as_ref(), plan, 999); // wrong seed
        fb2.fault_state_load(&blob);
        assert_eq!(fb2.fault_stats(), stats0, "stats restored");
        let tail2: Vec<bool> =
            (0..64).map(|_| fb2.marshal_f32(&[1.0], &[1]).is_err()).collect();
        assert_eq!(tail, tail2, "restored stream replays identically");
        assert_eq!(fb2.fault_stats(), fb.fault_stats());
    }

    #[test]
    fn spikes_accumulate_and_drain_virtual_time() {
        let inner = crate::testkit::refcpu_backend();
        let plan = FaultPlan::parse("spike:1x0.25").unwrap();
        let fb = FaultyBackend::new(inner.as_ref(), plan, 1);
        // spike draws happen on execute; use a real tiny segment via
        // fault bookkeeping only (execute_fault is private — drive it
        // through the trait with a bogus segment that will error *after*
        // fault bookkeeping in the inner backend).
        let _ = fb.execute("nonexistent-segment", &[]);
        let _ = fb.execute("nonexistent-segment", &[]);
        assert_eq!(fb.fault_stats().latency_spikes, 2);
        assert!((fb.fault_stats().spike_s_total - 0.5).abs() < 1e-12);
        assert!((fb.take_injected_delay_s() - 0.5).abs() < 1e-12);
        assert_eq!(fb.take_injected_delay_s(), 0.0, "drain empties");
    }
}
