//! Host-side literal: shape + typed data, including a real **tuple**
//! representation.
//!
//! This is the interchange value of the backend-neutral execute boundary:
//! [`crate::runtime::RefCpuBackend`] consumes and produces `HostLiteral`s
//! directly, and builds without the `xla` cargo feature alias the inert
//! PJRT stub's `Literal` to this exact type — so the marshalling layer,
//! its caches, and multi-output (tuple) segment plumbing are testable on
//! any machine.
//!
//! Historically the stub's `Literal::to_tuple` returned a flat
//! `Err(NO_XLA)`, which made multi-output segments unrepresentable on the
//! host.  `HostLiteral` fixes that: [`HostLiteral::tuple`] builds a tuple
//! literal and [`HostLiteral::to_tuple`] decomposes one (and *only* one —
//! calling it on an array literal is still an error, mirroring XLA).

use std::fmt;

/// Error type standing in for `xla::Error` on the host; only `Debug` is
/// needed by the `map_err(|e| anyhow!("..: {e:?}"))` call sites.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

/// Element storage of one host literal.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Multi-output segments (train/ssl steps) return tuples.
    Tuple(Vec<HostLiteral>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }
}

/// Conversion glue so `HostLiteral::vec1` / `to_vec` stay generic like the
/// real xla crate's `NativeType`-bounded methods.
pub trait NativeType: Sized + Copy {
    fn wrap(data: &[Self]) -> Data;
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Data {
        Data::F32(data.to_vec())
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error::new("literal is not f32")),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Data {
        Data::I32(data.to_vec())
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error> {
        match data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error::new("literal is not i32")),
        }
    }
}

/// Host literal: shape + typed data (arrays and tuples).
#[derive(Clone, Debug, PartialEq)]
pub struct HostLiteral {
    dims: Vec<i64>,
    data: Data,
}

/// Shape view matching `xla::ArrayShape`'s `dims()` accessor.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl HostLiteral {
    /// Rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> HostLiteral {
        HostLiteral { dims: vec![data.len() as i64], data: T::wrap(data) }
    }

    /// f32 literal with an explicit shape (`[]` = rank-0 scalar).
    pub fn f32(data: &[f32], shape: &[usize]) -> Result<HostLiteral, Error> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        HostLiteral::vec1(data).reshape(&dims)
    }

    /// f32 literal taking ownership of the buffer (no copy) — the
    /// reference executor moves large outputs (θ′) straight into the
    /// literal instead of round-tripping them through a fresh `Vec`.
    pub fn f32_owned(data: Vec<f32>, shape: &[usize]) -> Result<HostLiteral, Error> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let n: i64 = dims.iter().product();
        if n as usize != data.len() {
            return Err(Error::new(format!(
                "shape {shape:?} does not hold {} elements",
                data.len()
            )));
        }
        Ok(HostLiteral { dims, data: Data::F32(data) })
    }

    /// i32 literal with an explicit shape.
    pub fn i32(data: &[i32], shape: &[usize]) -> Result<HostLiteral, Error> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        HostLiteral::vec1(data).reshape(&dims)
    }

    /// Tuple literal over already-built elements (the host representation
    /// of a multi-output segment's return value).
    pub fn tuple(elems: Vec<HostLiteral>) -> HostLiteral {
        HostLiteral {
            dims: vec![elems.len() as i64],
            data: Data::Tuple(elems),
        }
    }

    pub fn is_tuple(&self) -> bool {
        matches!(self.data, Data::Tuple(_))
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<HostLiteral, Error> {
        if self.is_tuple() {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(HostLiteral { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        if self.is_tuple() {
            return Err(Error::new("tuple literal has no array shape"));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data)
    }

    /// Borrowed f32 view (zero-copy read for the reference executor).
    pub fn f32_slice(&self) -> Result<&[f32], Error> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(Error::new("literal is not f32")),
        }
    }

    /// Borrowed i32 view.
    pub fn i32_slice(&self) -> Result<&[i32], Error> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(Error::new("literal is not i32")),
        }
    }

    /// Shape as `usize` dims (arrays only).
    pub fn shape(&self) -> Result<Vec<usize>, Error> {
        if self.is_tuple() {
            return Err(Error::new("tuple literal has no array shape"));
        }
        Ok(self.dims.iter().map(|&d| d as usize).collect())
    }

    /// Decompose a tuple literal into its elements.  Errors on array
    /// literals (mirroring XLA, where `DecomposeTuple` requires a tuple).
    pub fn to_tuple(&self) -> Result<Vec<HostLiteral>, Error> {
        match &self.data {
            Data::Tuple(elems) => Ok(elems.clone()),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_shape_and_data() {
        let l = HostLiteral::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn owned_literal_moves_without_copy_and_checks_shape() {
        let l = HostLiteral::f32_owned(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(HostLiteral::f32_owned(vec![1.0; 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_literal_has_empty_dims() {
        let s = HostLiteral::f32(&[7.5], &[]).unwrap();
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn tuple_roundtrips_elements() {
        let a = HostLiteral::f32(&[1.0, 2.0], &[2]).unwrap();
        let b = HostLiteral::i32(&[3, 4, 5], &[3]).unwrap();
        let t = HostLiteral::tuple(vec![a.clone(), b.clone()]);
        assert!(t.is_tuple());
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert_eq!(elems[1].to_vec::<i32>().unwrap(), vec![3, 4, 5]);
    }

    #[test]
    fn tuple_of_tuples_nests() {
        let inner = HostLiteral::tuple(vec![HostLiteral::vec1(&[1.0f32])]);
        let outer =
            HostLiteral::tuple(vec![inner, HostLiteral::vec1(&[2i32])]);
        let elems = outer.to_tuple().unwrap();
        assert!(elems[0].is_tuple());
        assert_eq!(elems[0].to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn array_literal_is_not_a_tuple() {
        let l = HostLiteral::vec1(&[1.0f32]);
        assert!(l.to_tuple().is_err());
        let t = HostLiteral::tuple(vec![l]);
        assert!(t.array_shape().is_err());
        assert!(t.reshape(&[1]).is_err());
        assert!(t.shape().is_err());
    }
}
