//! Observability decorator for the execute boundary.
//!
//! [`TracingBackend`] mirrors [`FaultyBackend`](super::FaultyBackend)'s
//! shape — a stack-constructed, single-run decorator over `&dyn Backend` —
//! but injects nothing: it records a [`crate::trace::Lane::Backend`] span
//! per `execute` / `marshal` / `warm` call, annotated with the
//! [`BackendPerf`] counter *deltas* the call produced (panel packs, pack
//! cache hits, scratch arena traffic) plus injected-fault markers.
//!
//! Composition order matters and is fixed by `sim::run_config`:
//! `TracingBackend` wraps *outside* `FaultyBackend`, so an injected
//! execute error or latency spike passes through this layer and lands in
//! the timeline (`ok:0`, `spikes:n` annotations) exactly like a real
//! backend failure would.
//!
//! Backend calls are instantaneous in *virtual* time (their cost is
//! modeled separately by `DeviceModel`), so spans are stamped at the
//! tracer's current virtual clock ([`crate::trace::Tracer::set_now`],
//! advanced by the engine/scheduler layers) with zero duration — the
//! lane shows *when* in the schedule the boundary was crossed and what
//! each crossing did, not a wall-clock cost.
//!
//! With a [`Tracer::disabled`] handle the decorator is a pure
//! passthrough; `sim::run_config` additionally skips constructing it at
//! all unless tracing is on, so the default path is byte-for-byte the
//! PR 6 composition.

use anyhow::Result;

use crate::trace::{Lane, Tracer};

use super::artifact::Manifest;
use super::backend::{Backend, BackendPerf, FaultStats, Value};

/// Span-recording decorator over any backend (see the module docs).
pub struct TracingBackend<'a> {
    inner: &'a dyn Backend,
    tracer: Tracer,
}

impl<'a> TracingBackend<'a> {
    pub fn new(inner: &'a dyn Backend, tracer: Tracer) -> Self {
        TracingBackend { inner, tracer }
    }

    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Record one boundary crossing: a zero-duration span at the current
    /// virtual time carrying the perf/fault counter deltas of the call.
    fn record(
        &self,
        name: &'static str,
        p0: BackendPerf,
        f0: FaultStats,
        ok: bool,
    ) {
        if !self.tracer.on() {
            return;
        }
        let p1 = self.inner.perf();
        let f1 = self.inner.fault_stats();
        let t = self.tracer.now();
        self.tracer.span(
            Lane::Backend,
            name,
            t,
            t,
            &[
                ("ok", if ok { 1.0 } else { 0.0 }),
                ("gemm_packs", (p1.gemm_packs - p0.gemm_packs) as f64),
                (
                    "gemm_pack_hits",
                    (p1.gemm_pack_hits - p0.gemm_pack_hits) as f64,
                ),
                (
                    "scratch_allocs",
                    (p1.scratch_allocs - p0.scratch_allocs) as f64,
                ),
                (
                    "spikes",
                    (f1.latency_spikes - f0.latency_spikes) as f64,
                ),
                (
                    "faults",
                    ((f1.exec_faults + f1.marshal_faults)
                        - (f0.exec_faults + f0.marshal_faults))
                        as f64,
                ),
            ],
        );
    }
}

impl Backend for TracingBackend<'_> {
    fn name(&self) -> &'static str {
        // transparent: reports and logs show the real executor.
        self.inner.name()
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn executions(&self) -> u64 {
        self.inner.executions()
    }

    fn marshal_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        let (p0, f0) = (self.inner.perf(), self.inner.fault_stats());
        let r = self.inner.marshal_f32(data, shape);
        self.record("marshal_f32", p0, f0, r.is_ok());
        r
    }

    fn marshal_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        let (p0, f0) = (self.inner.perf(), self.inner.fault_stats());
        let r = self.inner.marshal_i32(data, shape);
        self.record("marshal_i32", p0, f0, r.is_ok());
        r
    }

    fn execute(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let (p0, f0) = (self.inner.perf(), self.inner.fault_stats());
        let r = self.inner.execute(name, inputs);
        self.record("execute", p0, f0, r.is_ok());
        r
    }

    fn theta0(&self, model: &str) -> Result<Vec<f32>> {
        self.inner.theta0(model)
    }

    fn phi0(&self, model: &str) -> Result<Vec<f32>> {
        self.inner.phi0(model)
    }

    fn perf(&self) -> BackendPerf {
        self.inner.perf()
    }

    fn fault_stats(&self) -> FaultStats {
        self.inner.fault_stats()
    }

    fn take_injected_delay_s(&self) -> f64 {
        self.inner.take_injected_delay_s()
    }

    fn fault_state_save(&self) -> Option<Vec<u8>> {
        self.inner.fault_state_save()
    }

    fn fault_state_load(&self, bytes: &[u8]) {
        self.inner.fault_state_load(bytes)
    }

    fn warm(&self, segment: &str, theta: &Value) -> Result<()> {
        let (p0, f0) = (self.inner.perf(), self.inner.fault_stats());
        let r = self.inner.warm(segment, theta);
        self.record("pack", p0, f0, r.is_ok());
        r
    }

    fn release(&self, buf_id: u64) {
        self.inner.release(buf_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Kind;

    #[test]
    fn disabled_tracer_is_pure_passthrough() {
        let inner = crate::testkit::refcpu_backend();
        let tb = TracingBackend::new(inner.as_ref(), Tracer::disabled());
        assert_eq!(tb.name(), "refcpu");
        let v = tb.marshal_f32(&[1.0, 2.0], &[2]).unwrap();
        assert_eq!(v.read_f32().unwrap(), vec![1.0, 2.0]);
        assert!(!tb.tracer().on());
        assert!(tb.tracer().events().is_empty());
    }

    #[test]
    fn records_backend_lane_spans_with_deltas() {
        let inner = crate::testkit::refcpu_backend();
        let tracer = Tracer::enabled(64);
        let tb = TracingBackend::new(inner.as_ref(), tracer.clone());
        tracer.set_now(3.5);
        tb.marshal_f32(&[1.0], &[1]).unwrap();
        let _ = tb.execute("nonexistent-segment", &[]);
        let evs = tracer.events();
        assert_eq!(evs.len(), 2);
        assert!(evs.iter().all(|e| e.lane == Lane::Backend));
        assert!(evs.iter().all(|e| e.kind == Kind::Span));
        assert_eq!(evs[0].name, "marshal_f32");
        assert!((evs[0].t0 - 3.5).abs() < 1e-12);
        assert_eq!(evs[1].name, "execute");
        let ok = |e: &crate::trace::Event| {
            e.args()
                .iter()
                .find(|&&(k, _)| k == "ok")
                .map(|&(_, v)| v)
        };
        assert_eq!(ok(&evs[0]), Some(1.0));
        assert_eq!(ok(&evs[1]), Some(0.0), "failed execute marked ok:0");
    }

    #[test]
    fn injected_faults_show_in_the_timeline() {
        use super::super::faults::{FaultPlan, FaultyBackend};
        let inner = crate::testkit::refcpu_backend();
        let plan = FaultPlan::parse("marshal:1").unwrap();
        let fb = FaultyBackend::new(inner.as_ref(), plan, 1);
        let tracer = Tracer::enabled(64);
        // tracing composes OUTSIDE the fault layer
        let tb = TracingBackend::new(&fb, tracer.clone());
        assert!(tb.marshal_f32(&[1.0], &[1]).is_err());
        let evs = tracer.events();
        assert_eq!(evs.len(), 1);
        let get = |k: &str| {
            evs[0]
                .args()
                .iter()
                .find(|&&(n, _)| n == k)
                .map(|&(_, v)| v)
        };
        assert_eq!(get("ok"), Some(0.0));
        assert_eq!(get("faults"), Some(1.0), "injected fault visible");
    }
}
