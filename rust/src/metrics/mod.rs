//! Run metrics: average inference accuracy (the paper's headline accuracy
//! metric), cost ledger snapshots, and traces used by the figure
//! reproductions.

pub mod hist;

use crate::coordinator::simfreeze::CkaSample;
use crate::cost::energy::CostBreakdown;

use hist::HistRegistry;

/// One served inference request.
#[derive(Clone, Copy, Debug)]
pub struct RequestRecord {
    pub t: f64,
    pub scenario: usize,
    pub accuracy: f32,
    /// model staleness: batches buffered but not yet trained on when served.
    pub stale_batches: usize,
    /// end-to-end latency (queueing delay + batched service time), virtual
    /// seconds.  Serving-engine accounting: excluded from
    /// [`Report::fingerprint`] like the perf counters.
    pub latency_s: f64,
    /// requests that shared this request's padded execute (1 = unbatched).
    pub batch_requests: usize,
    /// requests still queued when this one was served.
    pub queue_depth: usize,
    /// served from a *stale* resident bank while the circuit breaker was
    /// open (fault-recovery accounting, excluded from
    /// [`Report::fingerprint`] like the latency fields).
    pub degraded: bool,
}

/// Per-scenario latency digest (serving-engine accounting, excluded from
/// [`Report::fingerprint`]): mixed-scenario load means one scenario's
/// burst can starve another's tail, which the global percentiles hide.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ScenarioLatency {
    pub scenario: usize,
    pub requests: u64,
    pub mean_ms: f64,
    pub p95_ms: f64,
    pub max_ms: f64,
    /// served requests whose completion passed their own deadline.
    pub deadline_misses: u64,
}

/// One fine-tuning round.
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub t: f64,
    pub scenario: usize,
    pub batches: usize,
    pub iterations: u64,
    pub batches_needed: usize,
    pub val_acc: f64,
    pub frozen_units: usize,
}

/// Full result of one continual-learning run.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub model: String,
    pub benchmark: String,
    pub tune_policy: String,
    pub freeze_policy: String,
    pub seed: u64,
    /// arithmetic mean of per-request accuracies (paper §II).
    pub avg_inference_accuracy: f64,
    pub energy: CostBreakdown,
    pub rounds: u64,
    pub train_iterations: u64,
    pub train_tflops: f64,
    pub cka_tflops: f64,
    pub scenario_changes_detected: u64,
    pub requests: Vec<RequestRecord>,
    pub round_log: Vec<RoundRecord>,
    /// training memory at the first and last round (Fig. 10), bytes.
    pub memory_begin_bytes: f64,
    pub memory_end_bytes: f64,
    /// wallclock spent in PJRT executions (real, not simulated), seconds.
    pub wall_exec_s: f64,
    /// per-layer CKA observations (populated when `keep_cka_trace` is set).
    pub cka_trace: Vec<CkaSample>,
    /// zero-copy instrumentation (host-side plumbing, *not* part of the
    /// scientific result — excluded from [`Report::fingerprint`]):
    /// θ host→literal marshals performed by the session.
    pub theta_marshals: u64,
    /// θ literal-cache hits (calls that skipped the marshal).
    pub theta_cache_hits: u64,
    /// serving-θ rebuilds (full copy + bank install).
    pub serving_rebuilds: u64,
    /// requests served straight from the cached serving θ.
    pub serving_hits: u64,
    /// execution-core counters from [`crate::runtime::Backend::perf`]
    /// (packed-weight cache + scratch arena; like the counters above,
    /// excluded from [`Report::fingerprint`]):
    /// weight panels packed by the backend.
    pub gemm_packs: u64,
    /// GEMM calls that reused an already-packed panel.
    pub gemm_pack_hits: u64,
    /// scratch buffers allocated fresh (arena misses).
    pub scratch_allocs: u64,
    /// scratch buffers served from the arena free list.
    pub scratch_reuses: u64,
    /// bytes handed out from recycled scratch buffers.
    pub scratch_bytes_reused: u64,
    /// serving-engine accounting (like the zero-copy counters above, this
    /// block is excluded from [`Report::fingerprint`]: the engine is
    /// plumbing around the scientific output, and with `batch_window_s ==
    /// 0` the scientific fields must stay bit-identical to the seed):
    /// latency percentiles over all served requests, milliseconds.
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_mean_ms: f64,
    pub latency_max_ms: f64,
    /// the SLO the run was accounted against, milliseconds.
    pub slo_ms: f64,
    /// requests whose latency exceeded the SLO.
    pub slo_violations: u64,
    /// padded artifact executions performed by the serving engine.
    pub serve_executes: u64,
    /// mean requests coalesced per execute (1.0 when batching never engaged).
    pub avg_batch_requests: f64,
    /// deepest the request queue ever got.
    pub peak_queue_depth: u64,
    /// fine-tuning rounds the scheduler deferred under serving backlog.
    pub rounds_deferred: u64,
    /// control-plane accounting (PR 5; like every serving field above,
    /// excluded from [`Report::fingerprint`] — the default configuration
    /// never sheds a request, so the drop counters are zero there and
    /// the scientific fields stay bit-identical to the seed; the policy
    /// name, per-scenario digests, and deadline misses are populated in
    /// every run):
    /// the queue ordering the run used (`"fifo"` / `"edf"`).
    pub queue_policy: String,
    /// requests shed at arrival, all reasons.
    pub requests_dropped: u64,
    /// ... because the queue held `--max-queue` requests.
    pub drops_queue_full: u64,
    /// ... because the deadline was infeasible even on an idle device.
    pub drops_slo_infeasible: u64,
    /// served requests whose completion passed their own deadline.
    pub deadline_misses: u64,
    /// resident serving-θ banks LRU-evicted (`--bank-capacity` pressure).
    pub bank_evictions: u64,
    /// most serving-θ banks ever resident at once.
    pub banks_peak_resident: u64,
    /// per-scenario latency digests (ascending scenario order).
    pub per_scenario_latency: Vec<ScenarioLatency>,
    /// fault-injection + recovery accounting (PR 6; excluded from
    /// [`Report::fingerprint`] like every serving counter above — with
    /// `FaultPlan::none()` all of these are zero and the scientific
    /// fields stay bit-identical):
    /// execute errors injected by the fault harness.
    pub faults_injected_exec: u64,
    /// marshal errors injected by the fault harness.
    pub faults_injected_marshal: u64,
    /// virtual-time latency spikes injected.
    pub faults_injected_spikes: u64,
    /// total virtual seconds of injected spike latency.
    pub fault_delay_injected_s: f64,
    /// batch execute retries performed by the serving engine.
    pub serve_retries: u64,
    /// flushes that exhausted their retries (group requeued, error
    /// absorbed by the recovery layer).
    pub serve_flush_failures: u64,
    /// times the circuit breaker tripped open.
    pub breaker_trips: u64,
    /// requests served from a stale resident bank while the breaker was
    /// open.
    pub degraded_serves: u64,
    /// requests shed at serve time because the breaker was open and no
    /// stale bank could stand in.
    pub drops_backend_unavailable: u64,
    /// fine-tuning rounds rolled back to the last good θ generation after
    /// a mid-round failure.
    pub round_rollbacks: u64,
    /// fleet routing accounting (PR 8; excluded from
    /// [`Report::fingerprint`] like every serving counter above — a fleet
    /// of one routes everything to engine 0 and the scientific fields
    /// stay bit-identical to the engine-only control plane):
    /// serving engines in the fleet (`--fleet`; 1 = no fleet).
    pub fleet_engines: u64,
    /// requests routed to an engine whose bank mirror held their scenario.
    pub fleet_routed_affinity: u64,
    /// requests routed least-loaded (no affinity holder, or affinity off).
    pub fleet_routed_least_loaded: u64,
    /// queue-full verdicts converted into a retry on another engine.
    pub fleet_cross_engine_retries: u64,
    /// hot-scenario rebalances (second bank warm-installed elsewhere).
    pub fleet_rebalances: u64,
    /// crash-durability accounting (PR 9; excluded from
    /// [`Report::fingerprint`] like every counter above — with
    /// checkpointing disabled (the default) all four are zero and the
    /// scientific fields stay bit-identical to the seed; a resumed run
    /// legitimately differs in them from its uncrashed reference):
    /// snapshot + journal records written to the checkpoint directory.
    pub checkpoints_written: u64,
    /// total bytes of checkpoint records written.
    pub checkpoint_bytes: u64,
    /// times this run's state was restored from a checkpoint (1 for a
    /// resumed run, 0 otherwise).
    pub checkpoint_restores: u64,
    /// recovery fallbacks: a newer checkpoint record failed its checksum
    /// (torn write / bit flip) and an earlier good record was used.
    pub checkpoint_fallbacks: u64,
    /// time-in-state accounting (PR 7 observability; excluded from
    /// [`Report::fingerprint`] like every serving counter above — it is a
    /// pure readout of the device schedule): virtual seconds the device
    /// spent executing serving batches.
    pub time_serving_s: f64,
    /// virtual seconds the device spent in fine-tuning rounds.
    pub time_tuning_s: f64,
    /// virtual seconds of the horizon spent idle (horizon − serving −
    /// tuning, clamped at 0 when the final drain runs past the horizon).
    pub time_idle_s: f64,
    /// mergeable latency/queue-depth/batch-size distributions
    /// ([`hist::HistRegistry`], PR 7).  Observability-only and excluded
    /// from [`Report::fingerprint`]; [`average`] merges registries across
    /// seeds in report order, which is deterministic.
    pub hists: HistRegistry,
}

impl Report {
    pub fn summary(&self) -> String {
        format!(
            "{}/{} tune={} freeze={} seed={}: acc {:.2}% time {:.0}s energy {:.2}Wh rounds {} iters {}",
            self.model,
            self.benchmark,
            self.tune_policy,
            self.freeze_policy,
            self.seed,
            self.avg_inference_accuracy * 100.0,
            self.energy.total_s(),
            self.energy.total_wh(),
            self.rounds,
            self.train_iterations,
        )
    }

    pub fn finish(&mut self) {
        if !self.requests.is_empty() {
            self.avg_inference_accuracy = self
                .requests
                .iter()
                .map(|r| r.accuracy as f64)
                .sum::<f64>()
                / self.requests.len() as f64;
        }
    }

    /// FNV-1a digest over every *scientific* field at full bit precision.
    /// Excludes wall-clock time, the zero-copy instrumentation counters,
    /// and the serving-engine accounting (latency/batch/SLO fields), which
    /// legitimately differ between runs that must otherwise be
    /// bit-identical (cache on/off, 1 vs N sweep workers, engine vs
    /// direct serving with `batch_window_s == 0`).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.str(&self.model);
        h.str(&self.benchmark);
        h.str(&self.tune_policy);
        h.str(&self.freeze_policy);
        h.u64(self.seed);
        h.f64(self.avg_inference_accuracy);
        for v in [
            self.energy.init_s,
            self.energy.loadsave_s,
            self.energy.compute_s,
            self.energy.init_j,
            self.energy.loadsave_j,
            self.energy.compute_j,
        ] {
            h.f64(v);
        }
        h.u64(self.rounds);
        h.u64(self.train_iterations);
        h.f64(self.train_tflops);
        h.f64(self.cka_tflops);
        h.u64(self.scenario_changes_detected);
        h.u64(self.requests.len() as u64);
        for r in &self.requests {
            h.f64(r.t);
            h.u64(r.scenario as u64);
            h.f64(r.accuracy as f64);
            h.u64(r.stale_batches as u64);
        }
        h.u64(self.round_log.len() as u64);
        for r in &self.round_log {
            h.f64(r.t);
            h.u64(r.scenario as u64);
            h.u64(r.batches as u64);
            h.u64(r.iterations);
            h.u64(r.batches_needed as u64);
            h.f64(r.val_acc);
            h.u64(r.frozen_units as u64);
        }
        h.f64(self.memory_begin_bytes);
        h.f64(self.memory_end_bytes);
        h.u64(self.cka_trace.len() as u64);
        for s in &self.cka_trace {
            h.u64(s.iteration);
            h.u64(s.layer as u64);
            h.f64(s.cka as f64);
        }
        h.finish()
    }
}

/// Tiny FNV-1a hasher (no external crates offline).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.bytes(&v.to_bits().to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
        self.bytes(&[0xff]); // delimiter
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Mean of reports over seeds (the paper averages 5 runs).
pub fn average(reports: &[Report]) -> Report {
    assert!(!reports.is_empty());
    let mut out = reports[0].clone();
    let n = reports.len() as f64;
    out.avg_inference_accuracy =
        reports.iter().map(|r| r.avg_inference_accuracy).sum::<f64>() / n;
    let mut acc = CostBreakdown::default();
    for r in reports {
        acc.add(&r.energy);
    }
    out.energy = CostBreakdown {
        init_s: acc.init_s / n,
        loadsave_s: acc.loadsave_s / n,
        compute_s: acc.compute_s / n,
        init_j: acc.init_j / n,
        loadsave_j: acc.loadsave_j / n,
        compute_j: acc.compute_j / n,
    };
    out.rounds = (reports.iter().map(|r| r.rounds).sum::<u64>() as f64 / n) as u64;
    out.train_iterations =
        (reports.iter().map(|r| r.train_iterations).sum::<u64>() as f64 / n) as u64;
    out.train_tflops = reports.iter().map(|r| r.train_tflops).sum::<f64>() / n;
    out.cka_tflops = reports.iter().map(|r| r.cka_tflops).sum::<f64>() / n;
    out.memory_begin_bytes =
        reports.iter().map(|r| r.memory_begin_bytes).sum::<f64>() / n;
    out.memory_end_bytes =
        reports.iter().map(|r| r.memory_end_bytes).sum::<f64>() / n;
    out.latency_p50_ms = reports.iter().map(|r| r.latency_p50_ms).sum::<f64>() / n;
    out.latency_p95_ms = reports.iter().map(|r| r.latency_p95_ms).sum::<f64>() / n;
    out.latency_p99_ms = reports.iter().map(|r| r.latency_p99_ms).sum::<f64>() / n;
    out.latency_mean_ms =
        reports.iter().map(|r| r.latency_mean_ms).sum::<f64>() / n;
    out.latency_max_ms = reports.iter().map(|r| r.latency_max_ms).sum::<f64>() / n;
    let mean_u64 = |f: fn(&Report) -> u64| -> u64 {
        (reports.iter().map(f).sum::<u64>() as f64 / n) as u64
    };
    out.slo_violations = mean_u64(|r| r.slo_violations);
    out.serve_executes = mean_u64(|r| r.serve_executes);
    out.avg_batch_requests =
        reports.iter().map(|r| r.avg_batch_requests).sum::<f64>() / n;
    out.rounds_deferred = mean_u64(|r| r.rounds_deferred);
    out.peak_queue_depth = mean_u64(|r| r.peak_queue_depth);
    out.requests_dropped = mean_u64(|r| r.requests_dropped);
    out.drops_queue_full = mean_u64(|r| r.drops_queue_full);
    out.drops_slo_infeasible = mean_u64(|r| r.drops_slo_infeasible);
    out.deadline_misses = mean_u64(|r| r.deadline_misses);
    out.bank_evictions = mean_u64(|r| r.bank_evictions);
    out.banks_peak_resident = mean_u64(|r| r.banks_peak_resident);
    out.faults_injected_exec = mean_u64(|r| r.faults_injected_exec);
    out.faults_injected_marshal = mean_u64(|r| r.faults_injected_marshal);
    out.faults_injected_spikes = mean_u64(|r| r.faults_injected_spikes);
    out.fault_delay_injected_s =
        reports.iter().map(|r| r.fault_delay_injected_s).sum::<f64>() / n;
    out.serve_retries = mean_u64(|r| r.serve_retries);
    out.serve_flush_failures = mean_u64(|r| r.serve_flush_failures);
    out.breaker_trips = mean_u64(|r| r.breaker_trips);
    out.degraded_serves = mean_u64(|r| r.degraded_serves);
    out.drops_backend_unavailable = mean_u64(|r| r.drops_backend_unavailable);
    out.round_rollbacks = mean_u64(|r| r.round_rollbacks);
    // fleet_engines is configuration, not an outcome: carried over from
    // reports[0] by the clone above, like queue_policy.
    out.fleet_routed_affinity = mean_u64(|r| r.fleet_routed_affinity);
    out.fleet_routed_least_loaded = mean_u64(|r| r.fleet_routed_least_loaded);
    out.fleet_cross_engine_retries =
        mean_u64(|r| r.fleet_cross_engine_retries);
    out.fleet_rebalances = mean_u64(|r| r.fleet_rebalances);
    out.checkpoints_written = mean_u64(|r| r.checkpoints_written);
    out.checkpoint_bytes = mean_u64(|r| r.checkpoint_bytes);
    out.checkpoint_restores = mean_u64(|r| r.checkpoint_restores);
    out.checkpoint_fallbacks = mean_u64(|r| r.checkpoint_fallbacks);
    out.time_serving_s = reports.iter().map(|r| r.time_serving_s).sum::<f64>() / n;
    out.time_tuning_s = reports.iter().map(|r| r.time_tuning_s).sum::<f64>() / n;
    out.time_idle_s = reports.iter().map(|r| r.time_idle_s).sum::<f64>() / n;
    // histograms merge (not average): the merged distribution over all
    // seeds, folded in report order so the result is deterministic.
    let mut hists = HistRegistry::new();
    for r in reports {
        hists.merge(&r.hists);
    }
    out.hists = hists;
    out.per_scenario_latency = average_scenario_latency(reports);
    out.seed = u64::MAX; // marker: averaged
    out
}

/// Merge per-scenario latency digests across seeds: each scenario's entry
/// averages over the reports that observed it.
fn average_scenario_latency(reports: &[Report]) -> Vec<ScenarioLatency> {
    let mut scenarios: Vec<usize> = reports
        .iter()
        .flat_map(|r| r.per_scenario_latency.iter().map(|s| s.scenario))
        .collect();
    scenarios.sort_unstable();
    scenarios.dedup();
    scenarios
        .into_iter()
        .map(|scenario| {
            let entries: Vec<&ScenarioLatency> = reports
                .iter()
                .filter_map(|r| {
                    r.per_scenario_latency.iter().find(|s| s.scenario == scenario)
                })
                .collect();
            let k = entries.len() as f64;
            ScenarioLatency {
                scenario,
                requests: (entries.iter().map(|e| e.requests).sum::<u64>() as f64
                    / k) as u64,
                mean_ms: entries.iter().map(|e| e.mean_ms).sum::<f64>() / k,
                p95_ms: entries.iter().map(|e| e.p95_ms).sum::<f64>() / k,
                max_ms: entries.iter().map(|e| e.max_ms).sum::<f64>() / k,
                deadline_misses: (entries
                    .iter()
                    .map(|e| e.deadline_misses)
                    .sum::<u64>() as f64
                    / k) as u64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(t: f64, accuracy: f32, stale_batches: usize) -> RequestRecord {
        RequestRecord {
            t,
            scenario: 1,
            accuracy,
            stale_batches,
            latency_s: 0.0,
            batch_requests: 1,
            queue_depth: 0,
            degraded: false,
        }
    }

    #[test]
    fn finish_computes_mean_accuracy() {
        let mut r = Report::default();
        for a in [0.5, 0.7, 0.9] {
            r.requests.push(record(0.0, a, 0));
        }
        r.finish();
        assert!((r.avg_inference_accuracy - 0.7).abs() < 1e-6);
    }

    #[test]
    fn average_is_elementwise_mean() {
        let mut a = Report::default();
        a.avg_inference_accuracy = 0.6;
        a.energy.compute_j = 100.0;
        a.rounds = 10;
        let mut b = Report::default();
        b.avg_inference_accuracy = 0.8;
        b.energy.compute_j = 200.0;
        b.rounds = 20;
        let m = average(&[a, b]);
        assert!((m.avg_inference_accuracy - 0.7).abs() < 1e-9);
        assert!((m.energy.compute_j - 150.0).abs() < 1e-9);
        assert_eq!(m.rounds, 15);
    }

    #[test]
    fn average_merges_per_scenario_latency_by_scenario() {
        let mut a = Report::default();
        a.requests_dropped = 4;
        a.per_scenario_latency = vec![
            ScenarioLatency { scenario: 0, requests: 10, mean_ms: 2.0, ..Default::default() },
            ScenarioLatency { scenario: 2, requests: 6, mean_ms: 8.0, ..Default::default() },
        ];
        let mut b = Report::default();
        b.requests_dropped = 2;
        b.per_scenario_latency = vec![ScenarioLatency {
            scenario: 0,
            requests: 20,
            mean_ms: 4.0,
            ..Default::default()
        }];
        let m = average(&[a, b]);
        assert_eq!(m.requests_dropped, 3);
        assert_eq!(m.per_scenario_latency.len(), 2);
        assert_eq!(m.per_scenario_latency[0].scenario, 0);
        assert_eq!(m.per_scenario_latency[0].requests, 15);
        assert!((m.per_scenario_latency[0].mean_ms - 3.0).abs() < 1e-9);
        // scenario 2 only appeared in one report: averaged over presence
        assert_eq!(m.per_scenario_latency[1].requests, 6);
        assert!((m.per_scenario_latency[1].mean_ms - 8.0).abs() < 1e-9);
    }

    #[test]
    fn fingerprint_ignores_wall_clock_and_perf_counters() {
        let mut a = Report::default();
        a.avg_inference_accuracy = 0.5;
        a.requests.push(record(1.0, 0.5, 2));
        let mut b = a.clone();
        b.wall_exec_s = 99.0;
        b.theta_marshals = 7;
        b.theta_cache_hits = 3;
        b.serving_rebuilds = 1;
        b.serving_hits = 40;
        b.gemm_packs = 14;
        b.gemm_pack_hits = 900;
        b.scratch_allocs = 30;
        b.scratch_reuses = 5000;
        b.scratch_bytes_reused = 1 << 20;
        // serving-engine accounting is plumbing, not scientific output
        b.latency_p50_ms = 12.0;
        b.latency_p99_ms = 80.0;
        b.slo_ms = 250.0;
        b.slo_violations = 5;
        b.serve_executes = 33;
        b.avg_batch_requests = 3.2;
        b.peak_queue_depth = 9;
        b.rounds_deferred = 2;
        b.requests[0].latency_s = 0.125;
        b.requests[0].batch_requests = 4;
        b.requests[0].queue_depth = 3;
        // control-plane accounting (PR 5) is likewise excluded
        b.queue_policy = "edf".into();
        b.requests_dropped = 6;
        b.drops_queue_full = 4;
        b.drops_slo_infeasible = 2;
        b.deadline_misses = 3;
        b.bank_evictions = 7;
        b.banks_peak_resident = 4;
        b.per_scenario_latency.push(ScenarioLatency {
            scenario: 1,
            requests: 10,
            mean_ms: 5.0,
            p95_ms: 9.0,
            max_ms: 12.0,
            deadline_misses: 1,
        });
        // fault-injection + recovery accounting (PR 6) is also excluded
        b.faults_injected_exec = 12;
        b.faults_injected_marshal = 2;
        b.faults_injected_spikes = 5;
        b.fault_delay_injected_s = 2.5;
        b.serve_retries = 8;
        b.serve_flush_failures = 3;
        b.breaker_trips = 1;
        b.degraded_serves = 6;
        b.drops_backend_unavailable = 2;
        b.round_rollbacks = 1;
        b.requests[0].degraded = true;
        // time-in-state + histogram registry (PR 7) are also excluded
        b.time_serving_s = 120.0;
        b.time_tuning_s = 300.0;
        b.time_idle_s = 600.0;
        b.hists.record("serve/latency_ms", 12.5);
        // fleet routing accounting (PR 8) is also excluded
        b.fleet_engines = 4;
        b.fleet_routed_affinity = 120;
        b.fleet_routed_least_loaded = 30;
        b.fleet_cross_engine_retries = 5;
        b.fleet_rebalances = 2;
        // crash-durability accounting (PR 9) is also excluded
        b.checkpoints_written = 9;
        b.checkpoint_bytes = 1 << 16;
        b.checkpoint_restores = 1;
        b.checkpoint_fallbacks = 1;
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = a.clone();
        c.requests[0].accuracy = 0.5000001;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = a.clone();
        d.rounds += 1;
        assert_ne!(a.fingerprint(), d.fingerprint());
    }

    /// Compile-time-ish fingerprint audit: this destructuring has NO `..`
    /// rest pattern, so adding a field to `Report` fails to compile until
    /// this test names it.  When that happens, decide explicitly which
    /// side of the fingerprint the new field belongs on:
    ///
    /// * **scientific output** → hash it in [`Report::fingerprint`] and
    ///   add it to the INCLUDED list below;
    /// * **observability/plumbing** (latency, counters, traces,
    ///   histograms, time-in-state) → leave `fingerprint()` alone and
    ///   exercise it in `fingerprint_ignores_wall_clock_and_perf_counters`
    ///   so a future change can't silently start hashing it.
    ///
    /// That contract is what keeps tracing on/off runs — and sweep worker
    /// counts, cache settings, fault layers with `none` plans —
    /// bit-identical.
    #[test]
    fn report_field_census_is_exhaustive() {
        #[rustfmt::skip]
        let Report {
            // INCLUDED in fingerprint() — scientific fields:
            model: _, benchmark: _, tune_policy: _, freeze_policy: _,
            seed: _, avg_inference_accuracy: _, energy: _, rounds: _,
            train_iterations: _, train_tflops: _, cka_tflops: _,
            scenario_changes_detected: _, requests, round_log: _,
            memory_begin_bytes: _, memory_end_bytes: _, cka_trace: _,
            // EXCLUDED — wall clock:
            wall_exec_s: _,
            // EXCLUDED — zero-copy instrumentation (PR 1/2):
            theta_marshals: _, theta_cache_hits: _, serving_rebuilds: _,
            serving_hits: _,
            // EXCLUDED — execution-core counters (PR 4):
            gemm_packs: _, gemm_pack_hits: _, scratch_allocs: _,
            scratch_reuses: _, scratch_bytes_reused: _,
            // EXCLUDED — serving-engine accounting (PR 2/5):
            latency_p50_ms: _, latency_p95_ms: _, latency_p99_ms: _,
            latency_mean_ms: _, latency_max_ms: _, slo_ms: _,
            slo_violations: _, serve_executes: _, avg_batch_requests: _,
            peak_queue_depth: _, rounds_deferred: _, queue_policy: _,
            requests_dropped: _, drops_queue_full: _,
            drops_slo_infeasible: _, deadline_misses: _, bank_evictions: _,
            banks_peak_resident: _, per_scenario_latency: _,
            // EXCLUDED — fault injection + recovery (PR 6):
            faults_injected_exec: _, faults_injected_marshal: _,
            faults_injected_spikes: _, fault_delay_injected_s: _,
            serve_retries: _, serve_flush_failures: _, breaker_trips: _,
            degraded_serves: _, drops_backend_unavailable: _,
            round_rollbacks: _,
            // EXCLUDED — observability (PR 7):
            time_serving_s: _, time_tuning_s: _, time_idle_s: _, hists: _,
            // EXCLUDED — fleet routing (PR 8):
            fleet_engines: _, fleet_routed_affinity: _,
            fleet_routed_least_loaded: _, fleet_cross_engine_retries: _,
            fleet_rebalances: _,
            // EXCLUDED — crash durability (PR 9):
            checkpoints_written: _, checkpoint_bytes: _,
            checkpoint_restores: _, checkpoint_fallbacks: _,
        } = Report::default();
        // Per-request records feed the fingerprint partially: t/scenario/
        // accuracy/stale_batches hash, the serving fields don't.  Same
        // exhaustive treatment.
        let RequestRecord {
            // INCLUDED:
            t: _, scenario: _, accuracy: _, stale_batches: _,
            // EXCLUDED (serving accounting):
            latency_s: _, batch_requests: _, queue_depth: _, degraded: _,
        } = RequestRecord {
            t: 0.0,
            scenario: 0,
            accuracy: 0.0,
            stale_batches: 0,
            latency_s: 0.0,
            batch_requests: 1,
            queue_depth: 0,
            degraded: false,
        };
        let _ = requests;
    }
}
