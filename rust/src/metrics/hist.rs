//! Log-bucketed, mergeable histograms for latency / queue-depth /
//! batch-size distributions.
//!
//! Two representations live side by side in one [`Histogram`]:
//!
//! * **log₂ buckets** — 64 power-of-two buckets keyed off the f64
//!   exponent bits (exactly `floor(log2 v)`, no libm rounding, so bucket
//!   assignment is bit-deterministic across platforms).  These are what
//!   makes histograms *mergeable*: [`ParallelSweeper`] workers can record
//!   independently and the coordinator adds counts.
//! * **exact samples** — the full sample vector, kept because the repo's
//!   percentile contract is *nearest-rank over the exact samples* (the
//!   sorted-`Vec` math that used to live in `serve/latency.rs`).  Request
//!   counts per run are small (10²–10⁴), so this costs little and keeps
//!   p50/p95/p99 **bit-identical** to the pre-histogram values — asserted
//!   by `serve/latency.rs` and `tests/trace.rs`.
//!
//! Merging concatenates samples in caller order and adds bucket counts;
//! both are deterministic, so sweep merges are reproducible regardless of
//! worker count (workers are joined and merged in cell order).
//!
//! [`ParallelSweeper`]: crate::sim::ParallelSweeper

use std::collections::BTreeMap;

/// Number of log₂ buckets (covers f64 exponents -32..=31 after clamping).
pub const BUCKETS: usize = 64;

/// Exponent of the smallest non-underflow bucket: values below
/// 2^MIN_EXP land in bucket 0.
const MIN_EXP: i64 = -32;

/// Bucket index for a sample: `floor(log2 v)` via the raw exponent bits
/// (deterministic — no transcendental calls), clamped into range.
/// Non-positive and non-finite-small values land in bucket 0.
fn bucket_of(v: f64) -> usize {
    if v <= 0.0 || !v.is_finite() {
        return 0;
    }
    let exp = ((v.to_bits() >> 52) & 0x7ff) as i64 - 1023;
    (exp - MIN_EXP).clamp(0, BUCKETS as i64 - 1) as usize
}

/// Lower edge of bucket `i` (for rendering / debugging).
pub fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        (2.0f64).powi((i as i64 + MIN_EXP) as i32)
    }
}

/// A mergeable distribution: log₂ bucket counts plus the exact samples.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>,
    samples: Vec<f64>,
    max: f64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
        }
        self.buckets[bucket_of(v)] += 1;
        self.samples.push(v);
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Mean over a canonically sorted copy: summation order is then a
    /// function of the sample *multiset*, so merged histograms produce
    /// the same mean regardless of record interleaving — and it matches
    /// the old ledger, which also summed its sorted copy.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted.iter().sum::<f64>() / sorted.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// The exact samples in record order.  The checkpoint codec persists
    /// these and reconstructs the histogram by re-recording them in order,
    /// which rebuilds identical buckets/max by construction.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Nearest-rank index for percentile `p` over `n` samples — the exact
    /// formula the sorted-`Vec` ledger used.
    fn rank(p: f64, n: usize) -> usize {
        let r = ((p / 100.0) * n as f64).ceil() as usize;
        r.clamp(1, n) - 1
    }

    /// Nearest-rank percentile over the **exact** samples (0 when empty).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[Self::rank(p, sorted.len())]
    }

    /// Non-empty `(bucket_lo, count)` pairs in ascending bucket order.
    pub fn bucket_counts(&self) -> Vec<(f64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lo(i), c))
            .collect()
    }

    /// A rescaled copy (`v * factor` per sample, re-bucketed) — used to
    /// publish second-resolution ledgers in milliseconds.
    pub fn scaled(&self, factor: f64) -> Histogram {
        let mut out = Histogram::new();
        for &v in &self.samples {
            out.record(v * factor);
        }
        out
    }

    /// Fold `other` into `self`: bucket counts add, samples concatenate in
    /// caller order (deterministic merges require a deterministic caller
    /// order — the sweeper merges in cell order).
    pub fn merge(&mut self, other: &Histogram) {
        if other.samples.is_empty() {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
        }
        for (b, &c) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += c;
        }
        self.samples.extend_from_slice(&other.samples);
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

/// Named histogram registry carried on [`crate::metrics::Report`]
/// (fingerprint-excluded).  Keys are slash-scoped:
/// `serve/latency_ms`, `serve/latency_ms/s<scenario>`,
/// `serve/queue_depth`, `serve/batch_rows`, `tune/round_s`,
/// `tune/round_batches`.  `BTreeMap` keeps iteration — and therefore
/// merge and render order — deterministic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistRegistry {
    hists: BTreeMap<String, Histogram>,
}

impl HistRegistry {
    pub fn new() -> HistRegistry {
        HistRegistry::default()
    }

    /// Mutable handle to the named histogram, created on first use.
    pub fn hist(&mut self, key: &str) -> &mut Histogram {
        self.hists.entry(key.to_string()).or_default()
    }

    /// Record one sample into the named histogram.
    pub fn record(&mut self, key: &str, v: f64) {
        self.hist(key).record(v);
    }

    /// Insert (replace) a fully built histogram under `key`.
    pub fn insert(&mut self, key: &str, h: Histogram) {
        self.hists.insert(key.to_string(), h);
    }

    pub fn get(&self, key: &str) -> Option<&Histogram> {
        self.hists.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.hists.keys().map(|k| k.as_str())
    }

    pub fn is_empty(&self) -> bool {
        self.hists.is_empty()
    }

    pub fn len(&self) -> usize {
        self.hists.len()
    }

    /// Key-wise merge (union of keys, [`Histogram::merge`] on overlap).
    pub fn merge(&mut self, other: &HistRegistry) {
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.hists.insert(k.clone(), h.clone());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The old ledger's math, kept verbatim as the parity oracle.
    fn sorted_vec_percentile(samples: &[f64], p: f64) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let r = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[r.clamp(1, sorted.len()) - 1]
    }

    #[test]
    fn percentiles_match_sorted_vec_exactly() {
        let mut h = Histogram::new();
        let mut samples = Vec::new();
        // deterministic ugly sequence with ties and wide dynamic range
        let mut x = 1.0f64;
        for i in 0..257 {
            x = (x * 1.618 + i as f64 * 0.001) % 37.0 + 1e-4;
            h.record(x);
            samples.push(x);
        }
        for p in [50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(
                h.percentile(p).to_bits(),
                sorted_vec_percentile(&samples, p).to_bits(),
                "p{p} must be bit-identical to the sorted-Vec math"
            );
        }
        assert_eq!(h.count(), 257);
    }

    #[test]
    fn bucket_assignment_is_exact_log2() {
        let mut h = Histogram::new();
        h.record(1.0); // 2^0 -> bucket 32
        h.record(1.5);
        h.record(2.0); // 2^1 -> bucket 33
        h.record(0.5); // 2^-1 -> bucket 31
        h.record(0.0); // bucket 0
        let counts = h.bucket_counts();
        let get = |lo: f64| {
            counts.iter().find(|&&(l, _)| l == lo).map(|&(_, c)| c)
        };
        assert_eq!(get(0.0), Some(1));
        assert_eq!(get(0.5), Some(1));
        assert_eq!(get(1.0), Some(2));
        assert_eq!(get(2.0), Some(1));
    }

    #[test]
    fn merge_is_order_deterministic_and_count_preserving() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Vec::new();
        for i in 0..40 {
            let v = (i as f64 * 0.37) % 5.0 + 0.01;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        let mut merged = a.clone();
        merged.merge(&b);
        all.extend(
            (0..40)
                .filter(|i| i % 2 == 0)
                .map(|i| (i as f64 * 0.37) % 5.0 + 0.01),
        );
        all.extend(
            (0..40)
                .filter(|i| i % 2 == 1)
                .map(|i| (i as f64 * 0.37) % 5.0 + 0.01),
        );
        assert_eq!(merged.count(), 40);
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(
                merged.percentile(p).to_bits(),
                sorted_vec_percentile(&all, p).to_bits()
            );
        }
        // bucket totals add
        let total: u64 =
            merged.bucket_counts().iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 40);
    }

    #[test]
    fn registry_merges_key_union() {
        let mut a = HistRegistry::new();
        a.record("serve/latency_ms", 10.0);
        a.record("serve/queue_depth", 3.0);
        let mut b = HistRegistry::new();
        b.record("serve/latency_ms", 20.0);
        b.record("tune/round_s", 7.0);
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.get("serve/latency_ms").unwrap().count(), 2);
        assert_eq!(a.get("tune/round_s").unwrap().count(), 1);
    }

    #[test]
    fn empty_histogram_is_benign() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert!(h.is_empty());
        let mut m = Histogram::new();
        m.merge(&h);
        assert!(m.is_empty());
    }
}
