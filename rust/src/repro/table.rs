//! Tiny table/CSV writer for experiment outputs.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// Column-aligned text table + CSV writer.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "ragged row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print to stdout and save CSV under `results/<name>.csv`.
    pub fn emit(&self, results_dir: &Path, name: &str) -> Result<()> {
        println!("{}", self.render());
        std::fs::create_dir_all(results_dir)?;
        std::fs::write(results_dir.join(format!("{name}.csv")), self.to_csv())?;
        Ok(())
    }
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn pct(v: f64) -> String {
    format!("{:.2}", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("long_header"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn ragged_row_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
