//! The experiment registry: `etuner repro <id>` regenerates one paper
//! table/figure.  Workloads are scaled to this testbed (see EXPERIMENTS.md
//! §Setup); the *shape* of each result — who wins, by what factor, where
//! crossovers sit — is the reproduction target.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use crate::data::arrival::ArrivalKind;
use crate::data::benchmarks::Benchmark;
use crate::metrics::Report;
use crate::sim::{ParallelSweeper, RunConfig};

use super::table::{f1, f2, pct, Table};

/// All experiment ids with a one-line description.
pub fn list() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig3", "time & energy breakdown of immediate fine-tuning"),
        ("fig4", "validation-accuracy saturation across rounds (2 scenarios)"),
        ("fig5", "per-layer CKA variation as fine-tuning proceeds"),
        ("fig8", "overall fine-tuning execution time (normalized)"),
        ("fig9", "overall fine-tuning energy (normalized)"),
        ("tab2", "average inference accuracy (methods x models x benchmarks)"),
        ("tab3", "whole-process computation TFLOPs (NC)"),
        ("fig10", "training memory at begin vs end of continual learning"),
        ("fig11", "convergence speed: Immed vs ETuner in one scenario"),
        ("fig12", "LazyTune case study: batches_needed trace"),
        ("tab4", "NLP workload (bert / 20News)"),
        ("tab5", "SOTA comparison (Egeria/SlimFit/RigL/Ekya + LazyTune)"),
        ("fig13", "sensitivity: number of inference requests"),
        ("fig14", "sensitivity: arrival distributions"),
        ("fig15", "sensitivity: CKA stability threshold"),
        ("tab6", "semi-supervised learning (10% labels)"),
        ("tab7", "static lazy strategies S1-S4 vs LazyTune"),
        ("tab8", "compatibility with 8-bit quantization"),
        ("abl-decay", "ablation: log vs exponential vs additive decay (§IV-A2)"),
        ("abl-interval", "ablation: SimFreeze probe interval"),
        ("abl-oracle", "ablation: energy-score detector vs oracle boundaries"),
    ]
}

/// Experiment-wide defaults: seeds + request count are overridable from the
/// CLI (`--seeds`, `--requests`) to trade runtime for variance.
#[derive(Clone, Debug)]
pub struct ReproOpts {
    pub seeds: Vec<u64>,
    pub n_requests: usize,
    pub results_dir: std::path::PathBuf,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            seeds: vec![1, 2],
            n_requests: 200,
            results_dir: "results".into(),
        }
    }
}

pub fn run_experiment(sw: &ParallelSweeper, id: &str, opts: &ReproOpts) -> Result<()> {
    match id {
        "fig3" => fig3(sw, opts),
        "fig4" => fig4(sw, opts),
        "fig5" => fig5(sw, opts),
        "fig8" | "fig9" | "tab2" => fig8_9_tab2(sw, opts),
        "tab3" | "fig10" => tab3_fig10(sw, opts),
        "fig11" => fig11(sw, opts),
        "fig12" => fig12(sw, opts),
        "tab4" => tab4(sw, opts),
        "tab5" => tab5(sw, opts),
        "fig13" => fig13(sw, opts),
        "fig14" => fig14(sw, opts),
        "fig15" => fig15(sw, opts),
        "tab6" => tab6(sw, opts),
        "tab7" => tab7(sw, opts),
        "tab8" => tab8(sw, opts),
        "abl-decay" => abl_decay(sw, opts),
        "abl-interval" => abl_interval(sw, opts),
        "abl-oracle" => abl_oracle(sw, opts),
        "all" => {
            for (id, _) in list() {
                if id == "fig9" || id == "tab2" || id == "fig10" {
                    continue; // produced jointly with fig8/tab3
                }
                run_experiment(sw, id, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other:?} (try `list`)"),
    }
}

fn cfg(model: &str, b: Benchmark, opts: &ReproOpts) -> RunConfig {
    let mut c = RunConfig::quickstart(model, b);
    c.n_requests = opts.n_requests;
    c
}

/// The four methods of the main grid (paper Figs. 8/9, Table II).
fn methods() -> Vec<(&'static str, TunePolicyKind, FreezePolicyKind)> {
    vec![
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("LazyTune", TunePolicyKind::LazyTune, FreezePolicyKind::None),
        ("SimFreeze", TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ]
}

fn run_cfg(sw: &ParallelSweeper, c: &RunConfig, opts: &ReproOpts) -> Result<Report> {
    Ok(sw.run_averaged(c, &opts.seeds)?.0)
}

// ---------------------------------------------------------------------------
// Fig. 3 — time/energy breakdown of immediate fine-tuning
// ---------------------------------------------------------------------------

fn fig3(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Fig 3: breakdown of immediate fine-tuning (NC)",
        &["model", "init%t", "load/save%t", "compute%t", "init%e",
          "load/save%e", "compute%e", "time_s", "energy_Wh"],
    );
    for model in ["res50", "mbv2", "deit"] {
        let c = cfg(model, Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
        let r = run_cfg(sw, &c, opts)?;
        let e = &r.energy;
        let ts = e.total_s();
        let tj = e.total_j();
        t.row(vec![
            model.into(),
            pct(e.init_s / ts),
            pct(e.loadsave_s / ts),
            pct(e.compute_s / ts),
            pct(e.init_j / tj),
            pct(e.loadsave_j / tj),
            pct(e.compute_j / tj),
            f1(ts),
            f2(e.total_wh()),
        ]);
    }
    t.emit(&opts.results_dir, "fig3")
}

// ---------------------------------------------------------------------------
// Fig. 4 — accuracy saturation across fine-tuning rounds
// ---------------------------------------------------------------------------

fn fig4(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Fig 4: validation accuracy over rounds (scenarios 2-3, Immed.)",
        &["model", "round", "scenario", "val_acc%"],
    );
    for model in ["res50", "mbv2"] {
        let c = cfg(model, Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None)
            .with_seed(opts.seeds[0]);
        let r = crate::sim::Simulation::new(sw.runtime(), c)?.run()?;
        for (i, rr) in r
            .round_log
            .iter()
            .filter(|rr| rr.scenario <= 2)
            .enumerate()
        {
            t.row(vec![
                model.into(),
                format!("{i}"),
                format!("{}", rr.scenario),
                pct(rr.val_acc),
            ]);
        }
    }
    t.emit(&opts.results_dir, "fig4")
}

// ---------------------------------------------------------------------------
// Fig. 5 — CKA variation curves
// ---------------------------------------------------------------------------

fn fig5(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut c = cfg("res50", Benchmark::Nc, opts)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze)
        .with_seed(opts.seeds[0]);
    c.keep_cka_trace = true;
    c.cka_th = 0.0; // observe without freezing so full curves are traced
    let report = crate::sim::Simulation::new(sw.runtime(), c)?.run()?;
    let mut t = Table::new(
        "Fig 5: CKA of selected layers over fine-tuning (res50, NC)",
        &["iteration", "layer", "cka"],
    );
    let picks = [0usize, 2, 4, 6, 8];
    for s in &report.cka_trace {
        if picks.contains(&s.layer) {
            t.row(vec![
                format!("{}", s.iteration),
                format!("{}", s.layer),
                format!("{:.4}", s.cka),
            ]);
        }
    }
    t.emit(&opts.results_dir, "fig5")
}

// ---------------------------------------------------------------------------
// Figs. 8/9 + Table II — the main grid
// ---------------------------------------------------------------------------

fn fig8_9_tab2(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let benches = [
        Benchmark::Nc,
        Benchmark::Nic79,
        Benchmark::Nic391,
        Benchmark::SCifar10,
    ];
    let mut t8 = Table::new(
        "Fig 8: overall fine-tuning time, normalized to Immed.",
        &["model", "benchmark", "Immed.", "LazyTune", "SimFreeze", "ETuner"],
    );
    let mut t9 = Table::new(
        "Fig 9: overall fine-tuning energy, normalized to Immed.",
        &["model", "benchmark", "Immed.", "LazyTune", "SimFreeze", "ETuner"],
    );
    let mut t2 = Table::new(
        "Table II: average inference accuracy (%)",
        &["model", "benchmark", "Immed.", "LazyTune", "SimFreeze", "ETuner"],
    );
    // whole grid as one flat job list: every (model, benchmark, method,
    // seed) run lands on the sweeper's work queue at once, so the worker
    // pool stays busy across cell boundaries.
    let models = ["res50", "mbv2", "deit"];
    let mut cfgs = Vec::new();
    for model in models {
        for b in benches {
            for (_, tune, freeze) in methods() {
                cfgs.push(cfg(model, b, opts).with_policies(tune, freeze));
            }
        }
    }
    let reports = sw.run_averaged_many(&cfgs, &opts.seeds)?;
    let mut cells = reports.iter();
    for model in models {
        for b in benches {
            let mut times = vec![];
            let mut energies = vec![];
            let mut accs = vec![];
            for _ in methods() {
                let r = cells.next().expect("grid cell");
                times.push(r.energy.total_s());
                energies.push(r.energy.total_j());
                accs.push(r.avg_inference_accuracy);
            }
            let norm = |v: &[f64]| -> Vec<String> {
                v.iter().map(|x| f2(x / v[0])).collect()
            };
            let mut row8 = vec![model.to_string(), b.name().to_string()];
            row8.extend(norm(&times));
            t8.row(row8);
            let mut row9 = vec![model.to_string(), b.name().to_string()];
            row9.extend(norm(&energies));
            t9.row(row9);
            let mut row2 = vec![model.to_string(), b.name().to_string()];
            row2.extend(accs.iter().map(|a| pct(*a)));
            t2.row(row2);
        }
    }
    t8.emit(&opts.results_dir, "fig8")?;
    t9.emit(&opts.results_dir, "fig9")?;
    t2.emit(&opts.results_dir, "tab2")
}

// ---------------------------------------------------------------------------
// Table III + Fig. 10 — computation & memory
// ---------------------------------------------------------------------------

fn tab3_fig10(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t3 = Table::new(
        "Table III: computation of the whole NC process (paper-scale TFLOPs)",
        &["model", "Immed.", "ETuner", "reduction%"],
    );
    let mut t10 = Table::new(
        "Fig 10: training memory begin vs end (paper-scale MB)",
        &["model", "method", "begin_MB", "end_MB", "reduction%"],
    );
    for model in ["res50", "mbv2"] {
        let ci = cfg(model, Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None);
        let ri = run_cfg(sw, &ci, opts)?;
        let ce = cfg(model, Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
        let re = run_cfg(sw, &ce, opts)?;
        t3.row(vec![
            model.into(),
            f1(ri.train_tflops),
            f1(re.train_tflops + re.cka_tflops),
            pct(1.0 - (re.train_tflops + re.cka_tflops) / ri.train_tflops),
        ]);
        for (name, r) in [("Immed.", &ri), ("ETuner", &re)] {
            t10.row(vec![
                model.into(),
                name.into(),
                f1(r.memory_begin_bytes / 1e6),
                f1(r.memory_end_bytes / 1e6),
                pct(1.0 - r.memory_end_bytes / r.memory_begin_bytes.max(1.0)),
            ]);
        }
    }
    t3.emit(&opts.results_dir, "tab3")?;
    t10.emit(&opts.results_dir, "fig10")
}

// ---------------------------------------------------------------------------
// Fig. 11 — convergence speed
// ---------------------------------------------------------------------------

fn fig11(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Fig 11: convergence within scenario 2 (res50, NC)",
        &["method", "round_in_scenario", "val_acc%"],
    );
    for (name, tune, freeze) in [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
    ] {
        let c = cfg("res50", Benchmark::Nc, opts)
            .with_policies(tune, freeze)
            .with_seed(opts.seeds[0]);
        let r = crate::sim::Simulation::new(sw.runtime(), c)?.run()?;
        for (i, rr) in r
            .round_log
            .iter()
            .filter(|rr| rr.scenario == 1)
            .enumerate()
        {
            t.row(vec![name.into(), format!("{i}"), pct(rr.val_acc)]);
        }
    }
    t.emit(&opts.results_dir, "fig11")
}

// ---------------------------------------------------------------------------
// Fig. 12 — LazyTune case study
// ---------------------------------------------------------------------------

fn fig12(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let c = cfg("res50", Benchmark::Nc, opts)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::None)
        .with_seed(opts.seeds[0]);
    let r = crate::sim::Simulation::new(sw.runtime(), c)?.run()?;
    let mut t = Table::new(
        "Fig 12: batches_needed trace (res50, NC, scenarios 2-3)",
        &["t", "scenario", "batches_needed", "batches_merged", "val_acc%"],
    );
    for rr in r.round_log.iter().filter(|rr| rr.scenario <= 2) {
        t.row(vec![
            f1(rr.t),
            format!("{}", rr.scenario),
            format!("{}", rr.batches_needed),
            format!("{}", rr.batches),
            pct(rr.val_acc),
        ]);
    }
    t.emit(&opts.results_dir, "fig12")
}

// ---------------------------------------------------------------------------
// Table IV — NLP workload
// ---------------------------------------------------------------------------

fn tab4(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Table IV: NLP workload (bert, 20News)",
        &["method", "accuracy%", "time_min", "energy_Wh"],
    );
    for (name, tune, freeze) in methods() {
        let c = cfg("bert", Benchmark::News20, opts).with_policies(tune, freeze);
        let r = run_cfg(sw, &c, opts)?;
        t.row(vec![
            name.into(),
            pct(r.avg_inference_accuracy),
            f1(r.energy.total_s() / 60.0),
            f2(r.energy.total_wh()),
        ]);
    }
    t.emit(&opts.results_dir, "tab4")
}

// ---------------------------------------------------------------------------
// Table V — SOTA comparison (all with LazyTune integrated)
// ---------------------------------------------------------------------------

fn tab5(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Table V: SOTA efficient-learning comparison (LazyTune integrated)",
        &["model", "benchmark", "method", "accuracy%", "energy_Wh"],
    );
    let entries = [
        ("LazyTune (base)", FreezePolicyKind::None),
        ("Egeria", FreezePolicyKind::Egeria),
        ("SlimFit", FreezePolicyKind::SlimFit),
        ("RigL", FreezePolicyKind::RigL),
        ("Ekya", FreezePolicyKind::Ekya),
        ("ETuner", FreezePolicyKind::SimFreeze),
    ];
    // one flat parallel batch over the whole comparison grid
    let models = ["res50", "mbv2", "deit"];
    let benches = [Benchmark::Nc, Benchmark::Nic391];
    let mut cfgs = Vec::new();
    for model in models {
        for b in benches {
            for (_, freeze) in entries {
                cfgs.push(
                    cfg(model, b, opts)
                        .with_policies(TunePolicyKind::LazyTune, freeze),
                );
            }
        }
    }
    let reports = sw.run_averaged_many(&cfgs, &opts.seeds)?;
    let mut cells = reports.iter();
    for model in models {
        for b in benches {
            for (name, _) in entries {
                let r = cells.next().expect("grid cell");
                t.row(vec![
                    model.into(),
                    b.name().into(),
                    name.into(),
                    pct(r.avg_inference_accuracy),
                    f2(r.energy.total_wh()),
                ]);
            }
        }
    }
    t.emit(&opts.results_dir, "tab5")
}

// ---------------------------------------------------------------------------
// Fig. 13 — sensitivity to the number of inference requests
// ---------------------------------------------------------------------------

fn fig13(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Fig 13: sensitivity to request count (res50, NC)",
        &["requests", "method", "accuracy%", "energy_Wh"],
    );
    for n in [50usize, 100, 200, 400, 800] {
        for (name, tune, freeze) in [
            ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
            ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
        ] {
            let mut c = cfg("res50", Benchmark::Nc, opts).with_policies(tune, freeze);
            c.n_requests = n;
            let r = run_cfg(sw, &c, opts)?;
            t.row(vec![
                format!("{n}"),
                name.into(),
                pct(r.avg_inference_accuracy),
                f2(r.energy.total_wh()),
            ]);
        }
    }
    t.emit(&opts.results_dir, "fig13")
}

// ---------------------------------------------------------------------------
// Fig. 14 — arrival distributions
// ---------------------------------------------------------------------------

fn fig14(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Fig 14: arrival-distribution sensitivity (res50, NC)",
        &["distribution", "method", "accuracy%", "energy_Wh"],
    );
    for kind in [
        ArrivalKind::Poisson,
        ArrivalKind::Uniform,
        ArrivalKind::Normal,
        ArrivalKind::Trace,
    ] {
        for (name, tune, freeze) in [
            ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
            ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
        ] {
            let mut c = cfg("res50", Benchmark::Nc, opts).with_policies(tune, freeze);
            c.train_arrival = kind;
            c.infer_arrival = kind;
            let r = run_cfg(sw, &c, opts)?;
            t.row(vec![
                kind.name().into(),
                name.into(),
                pct(r.avg_inference_accuracy),
                f2(r.energy.total_wh()),
            ]);
        }
    }
    t.emit(&opts.results_dir, "fig14")
}

// ---------------------------------------------------------------------------
// Fig. 15 — CKA stability threshold
// ---------------------------------------------------------------------------

fn fig15(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Fig 15: CKA stability threshold sweep (res50, NC, ETuner)",
        &["threshold%", "accuracy%", "energy_Wh"],
    );
    for th in [0.005, 0.01, 0.02, 0.04, 0.08] {
        let mut c = cfg("res50", Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
        c.cka_th = th;
        let r = run_cfg(sw, &c, opts)?;
        t.row(vec![
            format!("{:.1}", th * 100.0),
            pct(r.avg_inference_accuracy),
            f2(r.energy.total_wh()),
        ]);
    }
    t.emit(&opts.results_dir, "fig15")
}

// ---------------------------------------------------------------------------
// Table VI — semi-supervised learning
// ---------------------------------------------------------------------------

fn tab6(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Table VI: semi-supervised (NC, 10% labeled, SimSiam + supervised)",
        &["model", "method", "accuracy%", "energy_Wh"],
    );
    for model in ["res50", "mbv2", "deit"] {
        for (name, tune, freeze) in [
            ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
            ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
        ] {
            let mut c = cfg(model, Benchmark::Nc, opts).with_policies(tune, freeze);
            c.labeled_fraction = Some(0.1);
            let r = run_cfg(sw, &c, opts)?;
            t.row(vec![
                model.into(),
                name.into(),
                pct(r.avg_inference_accuracy),
                f2(r.energy.total_wh()),
            ]);
        }
    }
    t.emit(&opts.results_dir, "tab6")
}

// ---------------------------------------------------------------------------
// Table VII — static lazy strategies
// ---------------------------------------------------------------------------

fn tab7(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Table VII: static fine-tuning strategies vs LazyTune (res50, NC)",
        &["method", "batches_to_trigger", "accuracy%", "energy_Wh"],
    );
    let mut entries: Vec<(String, TunePolicyKind)> =
        vec![("Immed.".into(), TunePolicyKind::Immediate)];
    for (i, n) in [5usize, 10, 20, 50].iter().enumerate() {
        entries.push((format!("S{}", i + 1), TunePolicyKind::Static(*n)));
    }
    entries.push(("LazyTune".into(), TunePolicyKind::LazyTune));
    for (name, tune) in entries {
        let c = cfg("res50", Benchmark::Nc, opts)
            .with_policies(tune, FreezePolicyKind::None);
        let r = run_cfg(sw, &c, opts)?;
        let trig = match tune {
            TunePolicyKind::Immediate => "1".to_string(),
            TunePolicyKind::Static(n) => format!("{n}"),
            TunePolicyKind::LazyTune => "-".to_string(),
        };
        t.row(vec![
            name,
            trig,
            pct(r.avg_inference_accuracy),
            f2(r.energy.total_wh()),
        ]);
    }
    t.emit(&opts.results_dir, "tab7")
}

// ---------------------------------------------------------------------------
// Table VIII — quantization compatibility
// ---------------------------------------------------------------------------

fn tab8(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Table VIII: 8-bit QAT compatibility (res50)",
        &["benchmark", "method", "acc_8bit%", "acc_32bit%"],
    );
    for b in [Benchmark::Nc, Benchmark::Nic79] {
        for (name, tune, freeze) in [
            ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
            ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
        ] {
            let mut cq = cfg("res50", b, opts).with_policies(tune, freeze);
            cq.quant = true;
            let rq = run_cfg(sw, &cq, opts)?;
            let cf = cfg("res50", b, opts).with_policies(tune, freeze);
            let rf = run_cfg(sw, &cf, opts)?;
            t.row(vec![
                b.name().into(),
                name.into(),
                pct(rq.avg_inference_accuracy),
                pct(rf.avg_inference_accuracy),
            ]);
        }
    }
    t.emit(&opts.results_dir, "tab8")
}

// ---------------------------------------------------------------------------
// Ablations (design-choice benches called out in DESIGN.md)
// ---------------------------------------------------------------------------

fn abl_decay(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    use crate::coordinator::lazytune::DecayKind;
    let mut t = Table::new(
        "Ablation: batches_needed decay function (res50, NC, ETuner)",
        &["decay", "accuracy%", "energy_Wh", "rounds"],
    );
    for (name, decay) in [
        ("logarithmic (paper)", DecayKind::Logarithmic),
        ("exponential", DecayKind::Exponential),
        ("additive", DecayKind::Additive),
    ] {
        let mut c = cfg("res50", Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
        c.decay = decay;
        let r = run_cfg(sw, &c, opts)?;
        t.row(vec![
            name.into(),
            pct(r.avg_inference_accuracy),
            f2(r.energy.total_wh()),
            format!("{}", r.rounds),
        ]);
    }
    t.emit(&opts.results_dir, "abl_decay")
}

fn abl_interval(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Ablation: SimFreeze probe interval (res50, NC, ETuner)",
        &["interval_iters", "accuracy%", "energy_Wh", "cka_TFLOPs"],
    );
    for interval in [4u64, 8, 16, 32] {
        let mut c = cfg("res50", Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
        c.freeze_interval = interval;
        let r = run_cfg(sw, &c, opts)?;
        t.row(vec![
            format!("{interval}"),
            pct(r.avg_inference_accuracy),
            f2(r.energy.total_wh()),
            format!("{:.2}", r.cka_tflops),
        ]);
    }
    t.emit(&opts.results_dir, "abl_interval")
}

fn abl_oracle(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    let mut t = Table::new(
        "Ablation: scenario-change signal (res50, NC, ETuner)",
        &["signal", "accuracy%", "energy_Wh", "changes_detected"],
    );
    for (name, oracle) in
        [("energy-score detector (paper)", false), ("oracle boundaries", true)]
    {
        let mut c = cfg("res50", Benchmark::Nc, opts)
            .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
        c.oracle_change_detection = oracle;
        let r = run_cfg(sw, &c, opts)?;
        t.row(vec![
            name.into(),
            pct(r.avg_inference_accuracy),
            f2(r.energy.total_wh()),
            format!("{}", r.scenario_changes_detected),
        ]);
    }
    t.emit(&opts.results_dir, "abl_oracle")
}

/// Shared helper for callers needing just one averaged cell.
pub fn one_cell(
    sw: &ParallelSweeper,
    model: &str,
    b: Benchmark,
    tune: TunePolicyKind,
    freeze: FreezePolicyKind,
    opts: &ReproOpts,
) -> Result<Report> {
    let c = cfg(model, b, opts).with_policies(tune, freeze);
    run_cfg(sw, &c, opts)
}

/// Results directory helper used by main.
pub fn default_results_dir() -> &'static Path {
    Path::new("results")
}
