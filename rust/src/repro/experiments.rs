//! The experiment registry: `etuner repro <id>` regenerates one paper
//! table/figure.  Workloads are scaled to this testbed (see EXPERIMENTS.md
//! §Setup); the *shape* of each result — who wins, by what factor, where
//! crossovers sit — is the reproduction target.
//!
//! # Shared work queue
//!
//! Every experiment is a [`Plan`]: a deterministic list of cells (a cell is
//! one seed-averaged config or one single run) plus a render step that
//! turns the resulting reports into tables.  `repro all` flattens the
//! cells of *every* experiment into one job list for a single
//! [`ParallelSweeper::run_many`] call, so the worker pool steals work
//! across experiment boundaries instead of draining one experiment at a
//! time — the figure grids no longer serialize behind the small
//! single-run experiments.  Because `run_many` preserves input order and
//! every simulation is seed-deterministic, the emitted tables are
//! identical to the per-experiment runs.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
use crate::data::arrival::ArrivalKind;
use crate::data::benchmarks::Benchmark;
use crate::metrics::{average, Report};
use crate::sim::{ParallelSweeper, RunConfig};

use super::table::{f1, f2, pct, Table};

/// All experiment ids with a one-line description.
pub fn list() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig3", "time & energy breakdown of immediate fine-tuning"),
        ("fig4", "validation-accuracy saturation across rounds (2 scenarios)"),
        ("fig5", "per-layer CKA variation as fine-tuning proceeds"),
        ("fig8", "overall fine-tuning execution time (normalized)"),
        ("fig9", "overall fine-tuning energy (normalized)"),
        ("tab2", "average inference accuracy (methods x models x benchmarks)"),
        ("tab3", "whole-process computation TFLOPs (NC)"),
        ("fig10", "training memory at begin vs end of continual learning"),
        ("fig11", "convergence speed: Immed vs ETuner in one scenario"),
        ("fig12", "LazyTune case study: batches_needed trace"),
        ("tab4", "NLP workload (bert / 20News)"),
        ("tab5", "SOTA comparison (Egeria/SlimFit/RigL/Ekya + LazyTune)"),
        ("fig13", "sensitivity: number of inference requests"),
        ("fig14", "sensitivity: arrival distributions"),
        ("fig15", "sensitivity: CKA stability threshold"),
        ("tab6", "semi-supervised learning (10% labels)"),
        ("tab7", "static lazy strategies S1-S4 vs LazyTune"),
        ("tab8", "compatibility with 8-bit quantization"),
        ("abl-decay", "ablation: log vs exponential vs additive decay (§IV-A2)"),
        ("abl-interval", "ablation: SimFreeze probe interval"),
        ("abl-oracle", "ablation: energy-score detector vs oracle boundaries"),
        ("serve", "serving engine: latency percentiles & SLO vs batch window"),
        ("serve-policy", "serving control plane: fifo vs edf x queue caps"),
        ("faults", "robustness: fault rate x retry policy (accuracy, p99, drops)"),
        ("fleet", "fleet router: engines x affinity (p99, drops, rebuilds)"),
        ("capacity", "capacity search: sustainable RPS knee (workload x fleet x SLO)"),
    ]
}

/// Experiment-wide defaults: seeds + request count are overridable from the
/// CLI (`--seeds`, `--requests`) to trade runtime for variance.
#[derive(Clone, Debug)]
pub struct ReproOpts {
    pub seeds: Vec<u64>,
    pub n_requests: usize,
    pub results_dir: std::path::PathBuf,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            seeds: vec![1, 2],
            n_requests: 200,
            results_dir: "results".into(),
        }
    }
}

/// One schedulable unit of an experiment.
enum Cell {
    /// Mean over `opts.seeds` (the paper averages its runs).
    Avg(RunConfig),
    /// Exactly one run, seed already fixed (trace/curve experiments).
    One(RunConfig),
}

/// A planned experiment: deterministic cells + a render step consuming the
/// cell-level reports in the same order.
struct Plan {
    cells: Vec<Cell>,
    render: Box<dyn FnOnce(Vec<Report>) -> Result<()>>,
}

pub fn run_experiment(sw: &ParallelSweeper, id: &str, opts: &ReproOpts) -> Result<()> {
    if id == "capacity" {
        // Adaptive bisection: each probe batch depends on the previous
        // one, so this experiment cannot be expressed as a static Plan
        // cell list — it drives the sweeper directly.
        return capacity_table(sw, opts);
    }
    let plans = if id == "all" {
        let mut plans = Vec::new();
        for (eid, _) in list() {
            if eid == "fig9" || eid == "tab2" || eid == "fig10" {
                continue; // produced jointly with fig8/tab3
            }
            if eid == "capacity" {
                continue; // adaptive; runs after the static plans below
            }
            plans.push(plan(eid, opts)?);
        }
        plans
    } else {
        vec![plan(id, opts)?]
    };
    run_plans(sw, plans, opts)?;
    if id == "all" {
        capacity_table(sw, opts)?;
    }
    Ok(())
}

fn plan(id: &str, opts: &ReproOpts) -> Result<Plan> {
    Ok(match id {
        "fig3" => fig3(opts),
        "fig4" => fig4(opts),
        "fig5" => fig5(opts),
        "fig8" | "fig9" | "tab2" => fig8_9_tab2(opts),
        "tab3" | "fig10" => tab3_fig10(opts),
        "fig11" => fig11(opts),
        "fig12" => fig12(opts),
        "tab4" => tab4(opts),
        "tab5" => tab5(opts),
        "fig13" => fig13(opts),
        "fig14" => fig14(opts),
        "fig15" => fig15(opts),
        "tab6" => tab6(opts),
        "tab7" => tab7(opts),
        "tab8" => tab8(opts),
        "abl-decay" => abl_decay(opts),
        "abl-interval" => abl_interval(opts),
        "abl-oracle" => abl_oracle(opts),
        "serve" => serve_table(opts),
        "serve-policy" => serve_policy_table(opts),
        "faults" => faults_table(opts),
        "fleet" => fleet_table(opts),
        other => anyhow::bail!("unknown experiment {other:?} (try `list`)"),
    })
}

/// Expand every plan's cells into one flat job list, run it through the
/// shared sweeper queue, re-chunk the reports per cell, and render.
fn run_plans(sw: &ParallelSweeper, plans: Vec<Plan>, opts: &ReproOpts) -> Result<()> {
    let mut jobs: Vec<RunConfig> = Vec::new();
    for p in &plans {
        for cell in &p.cells {
            match cell {
                Cell::Avg(c) => {
                    for &s in &opts.seeds {
                        jobs.push(c.clone().with_seed(s));
                    }
                }
                Cell::One(c) => jobs.push(c.clone()),
            }
        }
    }
    anyhow::ensure!(!opts.seeds.is_empty(), "need at least one seed");
    let mut reports = sw.run_many(&jobs)?.into_iter();
    for p in plans {
        let mut cell_reports = Vec::with_capacity(p.cells.len());
        for cell in &p.cells {
            match cell {
                Cell::Avg(_) => {
                    let chunk: Vec<Report> =
                        reports.by_ref().take(opts.seeds.len()).collect();
                    anyhow::ensure!(
                        chunk.len() == opts.seeds.len(),
                        "sweep under-produced reports"
                    );
                    cell_reports.push(average(&chunk));
                }
                Cell::One(_) => cell_reports.push(
                    reports.next().ok_or_else(|| {
                        anyhow::anyhow!("sweep under-produced reports")
                    })?,
                ),
            }
        }
        (p.render)(cell_reports)?;
    }
    Ok(())
}

fn cfg(model: &str, b: Benchmark, opts: &ReproOpts) -> RunConfig {
    let mut c = RunConfig::quickstart(model, b);
    c.n_requests = opts.n_requests;
    c
}

/// The four methods of the main grid (paper Figs. 8/9, Table II).
fn methods() -> Vec<(&'static str, TunePolicyKind, FreezePolicyKind)> {
    vec![
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("LazyTune", TunePolicyKind::LazyTune, FreezePolicyKind::None),
        ("SimFreeze", TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ]
}

// ---------------------------------------------------------------------------
// Fig. 3 — time/energy breakdown of immediate fine-tuning
// ---------------------------------------------------------------------------

fn fig3(opts: &ReproOpts) -> Plan {
    let models = ["res50", "mbv2", "deit"];
    let cells = models
        .iter()
        .map(|&m| {
            Cell::Avg(
                cfg(m, Benchmark::Nc, opts)
                    .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None),
            )
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fig 3: breakdown of immediate fine-tuning (NC)",
                &["model", "init%t", "load/save%t", "compute%t", "init%e",
                  "load/save%e", "compute%e", "time_s", "energy_Wh"],
            );
            for (model, r) in models.iter().zip(&reports) {
                let e = &r.energy;
                let ts = e.total_s();
                let tj = e.total_j();
                t.row(vec![
                    (*model).into(),
                    pct(e.init_s / ts),
                    pct(e.loadsave_s / ts),
                    pct(e.compute_s / ts),
                    pct(e.init_j / tj),
                    pct(e.loadsave_j / tj),
                    pct(e.compute_j / tj),
                    f1(ts),
                    f2(e.total_wh()),
                ]);
            }
            t.emit(&dir, "fig3")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 4 — accuracy saturation across fine-tuning rounds
// ---------------------------------------------------------------------------

fn fig4(opts: &ReproOpts) -> Plan {
    let models = ["res50", "mbv2"];
    let seed = opts.seeds[0];
    let cells = models
        .iter()
        .map(|&m| {
            Cell::One(
                cfg(m, Benchmark::Nc, opts)
                    .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None)
                    .with_seed(seed),
            )
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fig 4: validation accuracy over rounds (scenarios 2-3, Immed.)",
                &["model", "round", "scenario", "val_acc%"],
            );
            for (model, r) in models.iter().zip(&reports) {
                for (i, rr) in r
                    .round_log
                    .iter()
                    .filter(|rr| rr.scenario <= 2)
                    .enumerate()
                {
                    t.row(vec![
                        (*model).into(),
                        format!("{i}"),
                        format!("{}", rr.scenario),
                        pct(rr.val_acc),
                    ]);
                }
            }
            t.emit(&dir, "fig4")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 5 — CKA variation curves
// ---------------------------------------------------------------------------

fn fig5(opts: &ReproOpts) -> Plan {
    let mut c = cfg("res50", Benchmark::Nc, opts)
        .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze)
        .with_seed(opts.seeds[0]);
    c.keep_cka_trace = true;
    c.cka_th = 0.0; // observe without freezing so full curves are traced
    let dir = opts.results_dir.clone();
    Plan {
        cells: vec![Cell::One(c)],
        render: Box::new(move |reports| {
            let report = &reports[0];
            let mut t = Table::new(
                "Fig 5: CKA of selected layers over fine-tuning (res50, NC)",
                &["iteration", "layer", "cka"],
            );
            let picks = [0usize, 2, 4, 6, 8];
            for s in &report.cka_trace {
                if picks.contains(&s.layer) {
                    t.row(vec![
                        format!("{}", s.iteration),
                        format!("{}", s.layer),
                        format!("{:.4}", s.cka),
                    ]);
                }
            }
            t.emit(&dir, "fig5")
        }),
    }
}

// ---------------------------------------------------------------------------
// Figs. 8/9 + Table II — the main grid
// ---------------------------------------------------------------------------

fn fig8_9_tab2(opts: &ReproOpts) -> Plan {
    let benches = [
        Benchmark::Nc,
        Benchmark::Nic79,
        Benchmark::Nic391,
        Benchmark::SCifar10,
    ];
    let models = ["res50", "mbv2", "deit"];
    let mut cells = Vec::new();
    for model in models {
        for b in benches {
            for (_, tune, freeze) in methods() {
                cells.push(Cell::Avg(cfg(model, b, opts).with_policies(tune, freeze)));
            }
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t8 = Table::new(
                "Fig 8: overall fine-tuning time, normalized to Immed.",
                &["model", "benchmark", "Immed.", "LazyTune", "SimFreeze", "ETuner"],
            );
            let mut t9 = Table::new(
                "Fig 9: overall fine-tuning energy, normalized to Immed.",
                &["model", "benchmark", "Immed.", "LazyTune", "SimFreeze", "ETuner"],
            );
            let mut t2 = Table::new(
                "Table II: average inference accuracy (%)",
                &["model", "benchmark", "Immed.", "LazyTune", "SimFreeze", "ETuner"],
            );
            let mut cells = reports.iter();
            for model in models {
                for b in benches {
                    let mut times = vec![];
                    let mut energies = vec![];
                    let mut accs = vec![];
                    for _ in methods() {
                        let r = cells.next().expect("grid cell");
                        times.push(r.energy.total_s());
                        energies.push(r.energy.total_j());
                        accs.push(r.avg_inference_accuracy);
                    }
                    let norm = |v: &[f64]| -> Vec<String> {
                        v.iter().map(|x| f2(x / v[0])).collect()
                    };
                    let mut row8 = vec![model.to_string(), b.name().to_string()];
                    row8.extend(norm(&times));
                    t8.row(row8);
                    let mut row9 = vec![model.to_string(), b.name().to_string()];
                    row9.extend(norm(&energies));
                    t9.row(row9);
                    let mut row2 = vec![model.to_string(), b.name().to_string()];
                    row2.extend(accs.iter().map(|a| pct(*a)));
                    t2.row(row2);
                }
            }
            t8.emit(&dir, "fig8")?;
            t9.emit(&dir, "fig9")?;
            t2.emit(&dir, "tab2")
        }),
    }
}

// ---------------------------------------------------------------------------
// Table III + Fig. 10 — computation & memory
// ---------------------------------------------------------------------------

fn tab3_fig10(opts: &ReproOpts) -> Plan {
    let models = ["res50", "mbv2"];
    let mut cells = Vec::new();
    for model in models {
        cells.push(Cell::Avg(
            cfg(model, Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::Immediate, FreezePolicyKind::None),
        ));
        cells.push(Cell::Avg(
            cfg(model, Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
        ));
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t3 = Table::new(
                "Table III: computation of the whole NC process (paper-scale TFLOPs)",
                &["model", "Immed.", "ETuner", "reduction%"],
            );
            let mut t10 = Table::new(
                "Fig 10: training memory begin vs end (paper-scale MB)",
                &["model", "method", "begin_MB", "end_MB", "reduction%"],
            );
            let mut it = reports.iter();
            for model in models {
                let ri = it.next().expect("grid cell");
                let re = it.next().expect("grid cell");
                t3.row(vec![
                    model.into(),
                    f1(ri.train_tflops),
                    f1(re.train_tflops + re.cka_tflops),
                    pct(1.0 - (re.train_tflops + re.cka_tflops) / ri.train_tflops),
                ]);
                for (name, r) in [("Immed.", ri), ("ETuner", re)] {
                    t10.row(vec![
                        model.into(),
                        name.into(),
                        f1(r.memory_begin_bytes / 1e6),
                        f1(r.memory_end_bytes / 1e6),
                        pct(1.0 - r.memory_end_bytes / r.memory_begin_bytes.max(1.0)),
                    ]);
                }
            }
            t3.emit(&dir, "tab3")?;
            t10.emit(&dir, "fig10")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 11 — convergence speed
// ---------------------------------------------------------------------------

fn fig11(opts: &ReproOpts) -> Plan {
    let entries = [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::Immediate, FreezePolicyKind::SimFreeze),
    ];
    let seed = opts.seeds[0];
    let cells = entries
        .iter()
        .map(|&(_, tune, freeze)| {
            Cell::One(
                cfg("res50", Benchmark::Nc, opts)
                    .with_policies(tune, freeze)
                    .with_seed(seed),
            )
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fig 11: convergence within scenario 2 (res50, NC)",
                &["method", "round_in_scenario", "val_acc%"],
            );
            for ((name, _, _), r) in entries.iter().zip(&reports) {
                for (i, rr) in r
                    .round_log
                    .iter()
                    .filter(|rr| rr.scenario == 1)
                    .enumerate()
                {
                    t.row(vec![(*name).into(), format!("{i}"), pct(rr.val_acc)]);
                }
            }
            t.emit(&dir, "fig11")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 12 — LazyTune case study
// ---------------------------------------------------------------------------

fn fig12(opts: &ReproOpts) -> Plan {
    let c = cfg("res50", Benchmark::Nc, opts)
        .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::None)
        .with_seed(opts.seeds[0]);
    let dir = opts.results_dir.clone();
    Plan {
        cells: vec![Cell::One(c)],
        render: Box::new(move |reports| {
            let r = &reports[0];
            let mut t = Table::new(
                "Fig 12: batches_needed trace (res50, NC, scenarios 2-3)",
                &["t", "scenario", "batches_needed", "batches_merged", "val_acc%"],
            );
            for rr in r.round_log.iter().filter(|rr| rr.scenario <= 2) {
                t.row(vec![
                    f1(rr.t),
                    format!("{}", rr.scenario),
                    format!("{}", rr.batches_needed),
                    format!("{}", rr.batches),
                    pct(rr.val_acc),
                ]);
            }
            t.emit(&dir, "fig12")
        }),
    }
}

// ---------------------------------------------------------------------------
// Table IV — NLP workload
// ---------------------------------------------------------------------------

fn tab4(opts: &ReproOpts) -> Plan {
    let cells = methods()
        .into_iter()
        .map(|(_, tune, freeze)| {
            Cell::Avg(cfg("bert", Benchmark::News20, opts).with_policies(tune, freeze))
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Table IV: NLP workload (bert, 20News)",
                &["method", "accuracy%", "time_min", "energy_Wh"],
            );
            for ((name, _, _), r) in methods().iter().zip(&reports) {
                t.row(vec![
                    (*name).into(),
                    pct(r.avg_inference_accuracy),
                    f1(r.energy.total_s() / 60.0),
                    f2(r.energy.total_wh()),
                ]);
            }
            t.emit(&dir, "tab4")
        }),
    }
}

// ---------------------------------------------------------------------------
// Table V — SOTA comparison (all with LazyTune integrated)
// ---------------------------------------------------------------------------

fn tab5(opts: &ReproOpts) -> Plan {
    let entries = [
        ("LazyTune (base)", FreezePolicyKind::None),
        ("Egeria", FreezePolicyKind::Egeria),
        ("SlimFit", FreezePolicyKind::SlimFit),
        ("RigL", FreezePolicyKind::RigL),
        ("Ekya", FreezePolicyKind::Ekya),
        ("ETuner", FreezePolicyKind::SimFreeze),
    ];
    let models = ["res50", "mbv2", "deit"];
    let benches = [Benchmark::Nc, Benchmark::Nic391];
    let mut cells = Vec::new();
    for model in models {
        for b in benches {
            for (_, freeze) in entries {
                cells.push(Cell::Avg(
                    cfg(model, b, opts).with_policies(TunePolicyKind::LazyTune, freeze),
                ));
            }
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Table V: SOTA efficient-learning comparison (LazyTune integrated)",
                &["model", "benchmark", "method", "accuracy%", "energy_Wh"],
            );
            let mut cells = reports.iter();
            for model in models {
                for b in benches {
                    for (name, _) in entries {
                        let r = cells.next().expect("grid cell");
                        t.row(vec![
                            model.into(),
                            b.name().into(),
                            name.into(),
                            pct(r.avg_inference_accuracy),
                            f2(r.energy.total_wh()),
                        ]);
                    }
                }
            }
            t.emit(&dir, "tab5")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 13 — sensitivity to the number of inference requests
// ---------------------------------------------------------------------------

fn fig13(opts: &ReproOpts) -> Plan {
    let counts = [50usize, 100, 200, 400, 800];
    let entries = [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ];
    let mut cells = Vec::new();
    for n in counts {
        for (_, tune, freeze) in entries {
            let mut c = cfg("res50", Benchmark::Nc, opts).with_policies(tune, freeze);
            c.n_requests = n;
            cells.push(Cell::Avg(c));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fig 13: sensitivity to request count (res50, NC)",
                &["requests", "method", "accuracy%", "energy_Wh"],
            );
            let mut it = reports.iter();
            for n in counts {
                for (name, _, _) in entries {
                    let r = it.next().expect("grid cell");
                    t.row(vec![
                        format!("{n}"),
                        name.into(),
                        pct(r.avg_inference_accuracy),
                        f2(r.energy.total_wh()),
                    ]);
                }
            }
            t.emit(&dir, "fig13")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 14 — arrival distributions
// ---------------------------------------------------------------------------

fn fig14(opts: &ReproOpts) -> Plan {
    let kinds = [
        ArrivalKind::Poisson,
        ArrivalKind::Uniform,
        ArrivalKind::Normal,
        ArrivalKind::Trace,
    ];
    let entries = [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ];
    let mut cells = Vec::new();
    for kind in kinds {
        for (_, tune, freeze) in entries {
            let mut c = cfg("res50", Benchmark::Nc, opts).with_policies(tune, freeze);
            c.train_arrival = kind;
            c.infer_arrival = kind;
            cells.push(Cell::Avg(c));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fig 14: arrival-distribution sensitivity (res50, NC)",
                &["distribution", "method", "accuracy%", "energy_Wh"],
            );
            let mut it = reports.iter();
            for kind in kinds {
                for (name, _, _) in entries {
                    let r = it.next().expect("grid cell");
                    t.row(vec![
                        kind.name().into(),
                        name.into(),
                        pct(r.avg_inference_accuracy),
                        f2(r.energy.total_wh()),
                    ]);
                }
            }
            t.emit(&dir, "fig14")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fig. 15 — CKA stability threshold
// ---------------------------------------------------------------------------

fn fig15(opts: &ReproOpts) -> Plan {
    let thresholds = [0.005, 0.01, 0.02, 0.04, 0.08];
    let cells = thresholds
        .iter()
        .map(|&th| {
            let mut c = cfg("res50", Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
            c.cka_th = th;
            Cell::Avg(c)
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fig 15: CKA stability threshold sweep (res50, NC, ETuner)",
                &["threshold%", "accuracy%", "energy_Wh"],
            );
            for (th, r) in thresholds.iter().zip(&reports) {
                t.row(vec![
                    format!("{:.1}", th * 100.0),
                    pct(r.avg_inference_accuracy),
                    f2(r.energy.total_wh()),
                ]);
            }
            t.emit(&dir, "fig15")
        }),
    }
}

// ---------------------------------------------------------------------------
// Table VI — semi-supervised learning
// ---------------------------------------------------------------------------

fn tab6(opts: &ReproOpts) -> Plan {
    let models = ["res50", "mbv2", "deit"];
    let entries = [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ];
    let mut cells = Vec::new();
    for model in models {
        for (_, tune, freeze) in entries {
            let mut c = cfg(model, Benchmark::Nc, opts).with_policies(tune, freeze);
            c.labeled_fraction = Some(0.1);
            cells.push(Cell::Avg(c));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Table VI: semi-supervised (NC, 10% labeled, SimSiam + supervised)",
                &["model", "method", "accuracy%", "energy_Wh"],
            );
            let mut it = reports.iter();
            for model in models {
                for (name, _, _) in entries {
                    let r = it.next().expect("grid cell");
                    t.row(vec![
                        model.into(),
                        name.into(),
                        pct(r.avg_inference_accuracy),
                        f2(r.energy.total_wh()),
                    ]);
                }
            }
            t.emit(&dir, "tab6")
        }),
    }
}

// ---------------------------------------------------------------------------
// Table VII — static lazy strategies
// ---------------------------------------------------------------------------

fn tab7(opts: &ReproOpts) -> Plan {
    let mut entries: Vec<(String, TunePolicyKind)> =
        vec![("Immed.".into(), TunePolicyKind::Immediate)];
    for (i, n) in [5usize, 10, 20, 50].iter().enumerate() {
        entries.push((format!("S{}", i + 1), TunePolicyKind::Static(*n)));
    }
    entries.push(("LazyTune".into(), TunePolicyKind::LazyTune));
    let cells = entries
        .iter()
        .map(|(_, tune)| {
            Cell::Avg(
                cfg("res50", Benchmark::Nc, opts)
                    .with_policies(*tune, FreezePolicyKind::None),
            )
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Table VII: static fine-tuning strategies vs LazyTune (res50, NC)",
                &["method", "batches_to_trigger", "accuracy%", "energy_Wh"],
            );
            for ((name, tune), r) in entries.into_iter().zip(&reports) {
                let trig = match tune {
                    TunePolicyKind::Immediate => "1".to_string(),
                    TunePolicyKind::Static(n) => format!("{n}"),
                    TunePolicyKind::LazyTune => "-".to_string(),
                };
                t.row(vec![
                    name,
                    trig,
                    pct(r.avg_inference_accuracy),
                    f2(r.energy.total_wh()),
                ]);
            }
            t.emit(&dir, "tab7")
        }),
    }
}

// ---------------------------------------------------------------------------
// Table VIII — quantization compatibility
// ---------------------------------------------------------------------------

fn tab8(opts: &ReproOpts) -> Plan {
    let benches = [Benchmark::Nc, Benchmark::Nic79];
    let entries = [
        ("Immed.", TunePolicyKind::Immediate, FreezePolicyKind::None),
        ("ETuner", TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze),
    ];
    let mut cells = Vec::new();
    for b in benches {
        for (_, tune, freeze) in entries {
            let mut cq = cfg("res50", b, opts).with_policies(tune, freeze);
            cq.quant = true;
            cells.push(Cell::Avg(cq));
            cells.push(Cell::Avg(cfg("res50", b, opts).with_policies(tune, freeze)));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Table VIII: 8-bit QAT compatibility (res50)",
                &["benchmark", "method", "acc_8bit%", "acc_32bit%"],
            );
            let mut it = reports.iter();
            for b in benches {
                for (name, _, _) in entries {
                    let rq = it.next().expect("grid cell");
                    let rf = it.next().expect("grid cell");
                    t.row(vec![
                        b.name().into(),
                        name.into(),
                        pct(rq.avg_inference_accuracy),
                        pct(rf.avg_inference_accuracy),
                    ]);
                }
            }
            t.emit(&dir, "tab8")
        }),
    }
}

// ---------------------------------------------------------------------------
// Ablations (design-choice benches called out in DESIGN.md)
// ---------------------------------------------------------------------------

fn abl_decay(opts: &ReproOpts) -> Plan {
    use crate::coordinator::lazytune::DecayKind;
    let entries = [
        ("logarithmic (paper)", DecayKind::Logarithmic),
        ("exponential", DecayKind::Exponential),
        ("additive", DecayKind::Additive),
    ];
    let cells = entries
        .iter()
        .map(|&(_, decay)| {
            let mut c = cfg("res50", Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
            c.decay = decay;
            Cell::Avg(c)
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Ablation: batches_needed decay function (res50, NC, ETuner)",
                &["decay", "accuracy%", "energy_Wh", "rounds"],
            );
            for ((name, _), r) in entries.iter().zip(&reports) {
                t.row(vec![
                    (*name).into(),
                    pct(r.avg_inference_accuracy),
                    f2(r.energy.total_wh()),
                    format!("{}", r.rounds),
                ]);
            }
            t.emit(&dir, "abl_decay")
        }),
    }
}

fn abl_interval(opts: &ReproOpts) -> Plan {
    let intervals = [4u64, 8, 16, 32];
    let cells = intervals
        .iter()
        .map(|&interval| {
            let mut c = cfg("res50", Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
            c.freeze_interval = interval;
            Cell::Avg(c)
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Ablation: SimFreeze probe interval (res50, NC, ETuner)",
                &["interval_iters", "accuracy%", "energy_Wh", "cka_TFLOPs"],
            );
            for (interval, r) in intervals.iter().zip(&reports) {
                t.row(vec![
                    format!("{interval}"),
                    pct(r.avg_inference_accuracy),
                    f2(r.energy.total_wh()),
                    format!("{:.2}", r.cka_tflops),
                ]);
            }
            t.emit(&dir, "abl_interval")
        }),
    }
}

fn abl_oracle(opts: &ReproOpts) -> Plan {
    let entries =
        [("energy-score detector (paper)", false), ("oracle boundaries", true)];
    let cells = entries
        .iter()
        .map(|&(_, oracle)| {
            let mut c = cfg("res50", Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
            c.oracle_change_detection = oracle;
            Cell::Avg(c)
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Ablation: scenario-change signal (res50, NC, ETuner)",
                &["signal", "accuracy%", "energy_Wh", "changes_detected"],
            );
            for ((name, _), r) in entries.iter().zip(&reports) {
                t.row(vec![
                    (*name).into(),
                    pct(r.avg_inference_accuracy),
                    f2(r.energy.total_wh()),
                    format!("{}", r.scenario_changes_detected),
                ]);
            }
            t.emit(&dir, "abl_oracle")
        }),
    }
}

// ---------------------------------------------------------------------------
// Serving engine — latency percentiles & SLO attainment vs batch window
// ---------------------------------------------------------------------------

fn serve_table(opts: &ReproOpts) -> Plan {
    // 30s SLO: windows below it coalesce freely, the 60s window is capped
    // by the deadline-aware flush — the table shows the latency/executes
    // trade-off and where the SLO starts binding.
    let windows = [0.0f64, 15.0, 30.0, 60.0];
    let n_requests = opts.n_requests;
    let cells = windows
        .iter()
        .map(|&w| {
            let mut c = cfg("res50", Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
            c.serve.batch_window_s = w;
            c.serve.slo_ms = 30_000.0;
            Cell::Avg(c)
        })
        .collect();
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Serving: latency & SLO vs batch window (res50, NC, ETuner)",
                &["window_s", "p50_ms", "p95_ms", "p99_ms", "slo_miss",
                  "attain%", "req/exec", "deferred", "accuracy%"],
            );
            for (w, r) in windows.iter().zip(&reports) {
                let attain =
                    1.0 - r.slo_violations as f64 / n_requests.max(1) as f64;
                t.row(vec![
                    f1(*w),
                    f1(r.latency_p50_ms),
                    f1(r.latency_p95_ms),
                    f1(r.latency_p99_ms),
                    format!("{}", r.slo_violations),
                    pct(attain),
                    f2(r.avg_batch_requests),
                    format!("{}", r.rounds_deferred),
                    pct(r.avg_inference_accuracy),
                ]);
            }
            t.emit(&dir, "serve")
        }),
    }
}

// ---------------------------------------------------------------------------
// Serving control plane — admission policy × queue cap
// ---------------------------------------------------------------------------

/// Share of the virtual horizon the device spent inside fine-tuning
/// rounds (PR 7 time-in-state accounting) — how much tuning displaced
/// serving in each cell.
fn tuning_pct(r: &Report) -> String {
    let total = r.time_serving_s + r.time_tuning_s + r.time_idle_s;
    pct(if total > 0.0 { r.time_tuning_s / total } else { 0.0 })
}

fn serve_policy_table(opts: &ReproOpts) -> Plan {
    use crate::serve::QueuePolicyKind;
    // A real coalescing window so arrivals actually queue (caps can bind)
    // and a 30s SLO like the `serve` table.  The simulator derives every
    // deadline as arrival + SLO, so EDF must order exactly like FIFO here
    // — the table doubles as a visible regression check of that
    // degeneracy (crafted deadline-inverted traces live in
    // tests/serving_engine.rs).
    let policies = [QueuePolicyKind::Fifo, QueuePolicyKind::Edf];
    let caps = [0usize, 8, 2];
    let n_requests = opts.n_requests;
    let mut cells = Vec::new();
    for policy in policies {
        for cap in caps {
            let mut c = cfg("res50", Benchmark::Nc, opts)
                .with_policies(TunePolicyKind::LazyTune, FreezePolicyKind::SimFreeze);
            c.serve.batch_window_s = 20.0;
            c.serve.slo_ms = 30_000.0;
            c.serve.queue_policy = policy;
            c.serve.max_queue = cap;
            cells.push(Cell::Avg(c));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Serving control plane: policy x queue cap (res50, NC, ETuner)",
                &["policy", "max_queue", "served", "dropped", "p95_ms",
                  "attain%", "req/exec", "miss", "tuning%", "accuracy%"],
            );
            let mut it = reports.iter();
            for policy in policies {
                for cap in caps {
                    let r = it.next().expect("grid cell");
                    // served + dropped == n_requests holds per seed, so
                    // the cross-seed mean of served is derivable from the
                    // mean drop count (average() keeps only seed #1's
                    // request list, whose length would be inconsistent
                    // with the averaged drop/miss columns).
                    let served = n_requests as u64 - r.requests_dropped;
                    let attain = 1.0
                        - r.slo_violations as f64 / (served.max(1)) as f64;
                    t.row(vec![
                        policy.name().into(),
                        if cap == 0 { "inf".into() } else { format!("{cap}") },
                        format!("{served}"),
                        format!("{}", r.requests_dropped),
                        f1(r.latency_p95_ms),
                        pct(attain),
                        f2(r.avg_batch_requests),
                        format!("{}", r.deadline_misses),
                        tuning_pct(r),
                        pct(r.avg_inference_accuracy),
                    ]);
                }
            }
            t.emit(&dir, "serve_policy")
        }),
    }
}

// ---------------------------------------------------------------------------
// Robustness — fault rate × retry policy
// ---------------------------------------------------------------------------

fn faults_table(opts: &ReproOpts) -> Plan {
    use crate::runtime::FaultPlan;
    // Fault axis: nothing injected, light transient exec faults, heavy
    // bursty exec faults, and heavy faults plus latency spikes.  Retry
    // axis: no retries (first failure feeds the breaker), the default
    // policy, and an aggressive one (more attempts, hair-trigger
    // breaker, fast cooldown).  Same coalescing window + SLO as the
    // `serve-policy` table so queues actually form.
    let fault_specs: [(&str, &str); 4] = [
        ("none", "none"),
        ("exec:2%", "exec:0.02"),
        ("exec:5%x3", "exec:0.05,burst:3"),
        ("5%+spikes", "exec:0.05,burst:3,spike:0.02x0.25"),
    ];
    let retries: [&str; 3] = ["none", "default", "aggressive"];
    let n_requests = opts.n_requests;
    let mut cells = Vec::new();
    for (_, spec) in fault_specs {
        for retry in retries {
            let mut c = cfg("res50", Benchmark::Nc, opts).with_policies(
                TunePolicyKind::LazyTune,
                FreezePolicyKind::SimFreeze,
            );
            c.serve.batch_window_s = 20.0;
            c.serve.slo_ms = 30_000.0;
            c.faults = FaultPlan::parse(spec).expect("static fault spec");
            match retry {
                "none" => c.serve.recovery.max_attempts = 1,
                "default" => {}
                _ => {
                    c.serve.recovery.max_attempts = 5;
                    c.serve.recovery.breaker_threshold = 2;
                    c.serve.recovery.breaker_cooldown_s = 10.0;
                }
            }
            cells.push(Cell::Avg(c));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Robustness: fault rate x retry policy (res50, NC, ETuner)",
                &["faults", "retry", "accuracy%", "p99_ms", "dropped",
                  "degraded%", "retries", "trips", "rollbacks", "tuning%"],
            );
            let mut it = reports.iter();
            for (label, _) in fault_specs {
                for retry in retries {
                    let r = it.next().expect("grid cell");
                    let served = n_requests as u64 - r.requests_dropped;
                    let degraded =
                        r.degraded_serves as f64 / served.max(1) as f64;
                    t.row(vec![
                        label.into(),
                        retry.into(),
                        pct(r.avg_inference_accuracy),
                        f1(r.latency_p99_ms),
                        format!("{}", r.requests_dropped),
                        pct(degraded),
                        format!("{}", r.serve_retries),
                        format!("{}", r.breaker_trips),
                        format!("{}", r.round_rollbacks),
                        tuning_pct(r),
                    ]);
                }
            }
            t.emit(&dir, "faults")
        }),
    }
}

// ---------------------------------------------------------------------------
// Fleet router — engines × affinity
// ---------------------------------------------------------------------------

fn fleet_table(opts: &ReproOpts) -> Plan {
    // Same coalescing window + SLO as the `serve-policy` table so queues
    // actually form, plus a tight per-engine queue cap so the affinity
    // target can fill up and the queue-full → cross-engine retry path
    // actually fires.  The affinity-off arm (pure least-loaded) is the
    // ablation: it spreads scenarios across engines, so expect more
    // serving rebuilds for the same workload.
    let engine_counts = [1usize, 2, 4, 8];
    let affinities = [true, false];
    let n_requests = opts.n_requests;
    let mut cells = Vec::new();
    for n in engine_counts {
        for affinity in affinities {
            let mut c = cfg("res50", Benchmark::Nc, opts).with_policies(
                TunePolicyKind::LazyTune,
                FreezePolicyKind::SimFreeze,
            );
            c.serve.batch_window_s = 20.0;
            c.serve.slo_ms = 30_000.0;
            c.serve.max_queue = 2;
            c.fleet.engines = n;
            c.fleet.affinity = affinity;
            cells.push(Cell::Avg(c));
        }
    }
    let dir = opts.results_dir.clone();
    Plan {
        cells,
        render: Box::new(move |reports| {
            let mut t = Table::new(
                "Fleet router: engines x affinity (res50, NC, ETuner)",
                &["engines", "affinity", "p99_ms", "dropped", "retries",
                  "rebalances", "rebuilds", "served", "tuning%"],
            );
            let mut it = reports.iter();
            for n in engine_counts {
                for affinity in affinities {
                    let r = it.next().expect("grid cell");
                    let served = n_requests as u64 - r.requests_dropped;
                    t.row(vec![
                        format!("{n}"),
                        if affinity { "on".into() } else { "off".into() },
                        f1(r.latency_p99_ms),
                        format!("{}", r.requests_dropped),
                        format!("{}", r.fleet_cross_engine_retries),
                        format!("{}", r.fleet_rebalances),
                        format!("{}", r.serving_rebuilds),
                        format!("{served}"),
                        tuning_pct(r),
                    ]);
                }
            }
            t.emit(&dir, "fleet")
        }),
    }
}

// ---------------------------------------------------------------------------
// Capacity — sustainable RPS at the SLO knee (workload × fleet × SLO)
// ---------------------------------------------------------------------------

/// `repro capacity`: for each workload kind × fleet size × SLO, bisect
/// the offered RPS for the latency-vs-throughput knee.  The SLO grid and
/// the RPS bracket are scaled off one measured low-rate base probe, so
/// the two monotone shapes the experiment demonstrates — knee decreasing
/// as the SLO tightens, non-decreasing as the fleet grows — hold
/// regardless of how fast the executing backend is.
fn capacity_table(sw: &ParallelSweeper, opts: &ReproOpts) -> Result<()> {
    use crate::load::{
        capacity_search, CapacitySpec, WorkloadKind, WorkloadSpec,
    };
    let mut base = cfg("mbv2", Benchmark::SCifar10, opts);
    base.seed = opts.seeds[0];
    base.workload = Some(WorkloadSpec {
        kind: WorkloadKind::Poisson,
        offered_rps: 0.25,
        window_s: Some(60.0),
        mix: None,
    });
    // Base probe: the p99 of a nearly-unloaded run approximates the bare
    // service time, so 1000/base_p99 approximates the per-engine service
    // rate mu (requests per virtual second).
    let probe = sw.run_many(std::slice::from_ref(&base))?;
    let base_p99 = probe[0].latency_p99_ms.max(1.0);
    let mu = 1000.0 / base_p99;
    let slos = [("loose", base_p99 * 8.0), ("tight", base_p99 * 2.5)];
    let kinds = [WorkloadKind::Poisson, WorkloadKind::Bursty];
    let fleets = [1usize, 2];
    let mut t = Table::new(
        "Capacity: sustainable RPS at the SLO knee (mbv2, s-cifar10)",
        &["workload", "fleet", "slo", "slo_ms", "knee_rps", "p99@knee_ms",
          "drop@knee", "probes"],
    );
    for kind in kinds {
        for &n in &fleets {
            for (label, slo_ms) in slos {
                let mut c = base.clone();
                c.fleet.engines = n;
                c.serve.slo_ms = slo_ms;
                if let Some(w) = c.workload.as_mut() {
                    w.kind = kind;
                }
                let spec = CapacitySpec {
                    slo_ms,
                    drop_eps: 0.01,
                    lo_rps: 0.05,
                    hi_rps: (4.0 * mu * n as f64).max(1.0),
                    iters: 3,
                    probes_per_iter: 2,
                };
                let res = capacity_search(sw, &c, &spec)?;
                t.row(vec![
                    kind.name().into(),
                    format!("{n}"),
                    label.into(),
                    f1(slo_ms),
                    f2(res.knee_rps),
                    f1(res.p99_at_knee_ms),
                    format!("{:.3}", res.drop_rate_at_knee),
                    format!("{}", res.probes.len()),
                ]);
            }
        }
    }
    t.emit(&opts.results_dir, "capacity")
}

/// Shared helper for callers needing just one averaged cell.
pub fn one_cell(
    sw: &ParallelSweeper,
    model: &str,
    b: Benchmark,
    tune: TunePolicyKind,
    freeze: FreezePolicyKind,
    opts: &ReproOpts,
) -> Result<Report> {
    let c = cfg(model, b, opts).with_policies(tune, freeze);
    Ok(sw.run_averaged(&c, &opts.seeds)?.0)
}

/// Results directory helper used by main.
pub fn default_results_dir() -> &'static Path {
    Path::new("results")
}
