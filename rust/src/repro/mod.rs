//! Experiment reproduction harness: one entry point per table/figure of the
//! paper's evaluation (see DESIGN.md's experiment index).  Each experiment
//! prints the paper-shaped rows/series and writes a CSV under `results/`.

pub mod experiments;
pub mod table;

pub use experiments::{list, run_experiment};
