//! Deterministic PRNG substrate (no external crates available offline).
//!
//! `Pcg32` (PCG-XSH-RR 64/32) drives every stochastic component — synthetic
//! data, arrival processes, seed sweeps — so runs are exactly reproducible
//! from `(benchmark, seed)`.

/// PCG-XSH-RR 64/32: small, fast, statistically solid for simulation use.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Raw generator state `(state, inc)` — the checkpoint subsystem
    /// serializes these so a resumed run continues the exact stream.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a saved `(state, inc)` pair.  The next
    /// draw is bit-identical to what the saved generator would have
    /// produced.
    pub fn from_state(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }

    /// Derive an independent stream (for per-scenario / per-class RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg32::new(s ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        let hi = self.next_u32() as u64;
        let lo = self.next_u32() as u64;
        (((hi << 32) | lo) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Exponential with rate `lambda` (mean 1/lambda); inter-arrival draw
    /// of a Poisson process.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn state_round_trips_bit_identically() {
        let mut a = Pcg32::new(11, 3);
        for _ in 0..17 {
            a.next_u32();
        }
        let (s, i) = a.state();
        let mut b = Pcg32::from_state(s, i);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_streams_diverge() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval_and_spread() {
        let mut r = Pcg32::new(1, 1);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            lo |= v < 0.1;
            hi |= v > 0.9;
        }
        assert!(lo && hi);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Pcg32::new(3, 3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Pcg32::new(4, 4);
        let lambda = 2.5;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Pcg32::new(5, 5);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(6, 6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
