//! Non-Negative Least Squares (Lawson–Hanson) — the solver behind
//! LazyTune's accuracy-curve fitting (paper §IV-A1, following Optimus [70];
//! the paper calls scipy's `optimize.nnls` [3], this is the same algorithm).
//!
//! Solves `argmin_{x >= 0} ||A x - b||_2` for small dense systems (the
//! curve fit uses 3 basis functions over tens of observations).

/// Dense column-major-free matrix as rows of `Vec<f64>`.
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>, // row-major
}

impl Mat {
    pub fn new(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut m = Mat::new(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    /// A^T * v
    fn tmul(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            for j in 0..self.cols {
                out[j] += self.at(i, j) * vi;
            }
        }
        out
    }

    /// A * x
    fn mul(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += self.at(i, j) * x[j];
            }
            out[i] = acc;
        }
        out
    }
}

/// Unconstrained least squares on the passive-set columns via normal
/// equations + Gaussian elimination with partial pivoting.  Fine for the
/// tiny, well-scaled systems the curve fitter produces.
fn ls_on_set(a: &Mat, b: &[f64], set: &[usize]) -> Option<Vec<f64>> {
    let k = set.len();
    if k == 0 {
        return Some(vec![]);
    }
    // G = Ap^T Ap (k x k), rhs = Ap^T b
    let mut g = vec![0.0; k * k];
    let mut rhs = vec![0.0; k];
    for (cj, &j) in set.iter().enumerate() {
        for (ci, &i) in set.iter().enumerate() {
            let mut acc = 0.0;
            for r in 0..a.rows {
                acc += a.at(r, i) * a.at(r, j);
            }
            g[ci * k + cj] = acc;
        }
        let mut acc = 0.0;
        for r in 0..a.rows {
            acc += a.at(r, j) * b[r];
        }
        rhs[cj] = acc;
    }
    // solve G z = rhs
    let mut z = rhs;
    for col in 0..k {
        // pivot
        let mut piv = col;
        for r in col + 1..k {
            if g[r * k + col].abs() > g[piv * k + col].abs() {
                piv = r;
            }
        }
        if g[piv * k + col].abs() < 1e-12 {
            return None; // singular
        }
        if piv != col {
            for c in 0..k {
                g.swap(col * k + c, piv * k + c);
            }
            z.swap(col, piv);
        }
        let d = g[col * k + col];
        for r in col + 1..k {
            let f = g[r * k + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..k {
                g[r * k + c] -= f * g[col * k + c];
            }
            z[r] -= f * z[col];
        }
    }
    for col in (0..k).rev() {
        let mut acc = z[col];
        for c in col + 1..k {
            acc -= g[col * k + c] * z[c];
        }
        z[col] = acc / g[col * k + col];
    }
    Some(z)
}

/// Lawson–Hanson active-set NNLS.  Returns `x >= 0` minimizing `||Ax-b||`.
pub fn nnls(a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    let n = a.cols;
    let mut x = vec![0.0; n];
    let mut passive: Vec<usize> = Vec::new();
    let tol = 1e-10;

    for _outer in 0..(3 * n + 30) {
        // w = A^T (b - A x): Lagrange gradient on the active set
        let ax = a.mul(&x);
        let resid: Vec<f64> =
            b.iter().zip(&ax).map(|(bi, axi)| bi - axi).collect();
        let w = a.tmul(&resid);

        // pick the most violated active constraint
        let mut best: Option<(usize, f64)> = None;
        for j in 0..n {
            if !passive.contains(&j) && w[j] > tol {
                if best.map_or(true, |(_, bw)| w[j] > bw) {
                    best = Some((j, w[j]));
                }
            }
        }
        let Some((j, _)) = best else { break };
        passive.push(j);

        // inner loop: solve LS on passive set, clip negatives
        loop {
            let Some(z) = ls_on_set(a, b, &passive) else {
                passive.pop();
                return x;
            };
            if z.iter().all(|&v| v > tol) {
                x.iter_mut().for_each(|v| *v = 0.0);
                for (c, &jj) in passive.iter().enumerate() {
                    x[jj] = z[c];
                }
                break;
            }
            // step toward z until the first passive var hits zero
            let mut alpha = f64::INFINITY;
            for (c, &jj) in passive.iter().enumerate() {
                if z[c] <= tol {
                    let denom = x[jj] - z[c];
                    if denom.abs() > 1e-15 {
                        alpha = alpha.min(x[jj] / denom);
                    }
                }
            }
            if !alpha.is_finite() {
                alpha = 0.0;
            }
            for (c, &jj) in passive.iter().enumerate() {
                x[jj] += alpha * (z[c] - x[jj]);
            }
            let drop: Vec<usize> = passive
                .iter()
                .copied()
                .filter(|&jj| x[jj] <= tol)
                .collect();
            for d in drop {
                passive.retain(|&jj| jj != d);
                x[d] = 0.0;
            }
            if passive.is_empty() {
                break;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn resid_norm(a: &Mat, x: &[f64], b: &[f64]) -> f64 {
        a.mul(x)
            .iter()
            .zip(b)
            .map(|(ax, bi)| (ax - bi) * (ax - bi))
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn exact_nonnegative_solution_recovered() {
        // A x* = b with x* >= 0 and A well conditioned -> recover x*.
        let a = Mat::from_rows(&[
            vec![1.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0],
            vec![0.0, 0.0, 3.0],
            vec![1.0, 1.0, 1.0],
        ]);
        let xstar = [0.5, 1.5, 2.0];
        let b = a.mul(&xstar);
        let x = nnls(&a, &b);
        for (xi, xs) in x.iter().zip(&xstar) {
            assert!((xi - xs).abs() < 1e-8, "{x:?}");
        }
    }

    #[test]
    fn negative_ls_solution_clamps_to_zero() {
        // unconstrained solution would be negative in x0
        let a = Mat::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.1]]);
        let b = [1.0, 1.2];
        let x = nnls(&a, &b);
        assert!(x.iter().all(|&v| v >= 0.0), "{x:?}");
        // KKT: gradient of active vars must be <= 0
        let ax = a.mul(&x);
        let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let w = a.tmul(&r);
        for j in 0..2 {
            if x[j] == 0.0 {
                assert!(w[j] <= 1e-8, "KKT violated {w:?}");
            }
        }
    }

    #[test]
    fn property_kkt_conditions_random_problems() {
        // Hand-rolled property test: for random (A, b), the solution is
        // feasible and satisfies the NNLS KKT conditions.
        let mut r = Pcg32::new(99, 1);
        for case in 0..50 {
            let rows = 3 + r.below(8);
            let cols = 1 + r.below(4);
            let mut rowv = Vec::new();
            for _ in 0..rows {
                rowv.push((0..cols).map(|_| r.normal() as f64).collect());
            }
            let a = Mat::from_rows(&rowv);
            let b: Vec<f64> = (0..rows).map(|_| r.normal() as f64).collect();
            let x = nnls(&a, &b);
            assert!(x.iter().all(|&v| v >= 0.0), "case {case}: {x:?}");
            let ax = a.mul(&x);
            let resid: Vec<f64> =
                b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
            let w = a.tmul(&resid);
            for j in 0..cols {
                if x[j] > 1e-9 {
                    assert!(w[j].abs() < 1e-6, "case {case}: grad {w:?}");
                } else {
                    assert!(w[j] <= 1e-6, "case {case}: active grad {w:?}");
                }
            }
        }
    }

    #[test]
    fn never_worse_than_zero_vector() {
        let mut r = Pcg32::new(7, 2);
        for _ in 0..30 {
            let rows = 4 + r.below(6);
            let mut rowv = Vec::new();
            for _ in 0..rows {
                rowv.push((0..3).map(|_| r.normal() as f64).collect());
            }
            let a = Mat::from_rows(&rowv);
            let b: Vec<f64> = (0..rows).map(|_| r.normal() as f64).collect();
            let x = nnls(&a, &b);
            let zero = vec![0.0; 3];
            assert!(
                resid_norm(&a, &x, &b) <= resid_norm(&a, &zero, &b) + 1e-9
            );
        }
    }
}
