//! # etuner — redundancy-aware continual learning for edge devices
//!
//! Rust implementation of the coordination layer of **ETuner / EdgeOL**
//! (Li et al., 2024): an edge continual-learning runtime that serves
//! streaming inference requests while continually fine-tuning the deployed
//! model, and removes two redundancies of the immediate-fine-tuning
//! baseline:
//!
//! * **inter-tuning** — [`coordinator::lazytune`] delays & merges
//!   fine-tuning rounds (NNLS accuracy-curve extrapolation, logarithmic
//!   decay on inference arrivals, reset on scenario change);
//! * **intra-tuning** — [`coordinator::simfreeze`] freezes layers whose CKA
//!   self-representational similarity has stabilized, and selectively
//!   unfreezes them on scenario changes.
//!
//! Compute flows through the object-safe [`runtime::Backend`] trait with
//! two interchangeable executors: the python build step (`make artifacts`)
//! AOT-lowers JAX + Pallas programs to HLO text which
//! [`runtime::PjrtBackend`] executes through the PJRT C API, while
//! [`runtime::RefCpuBackend`] implements the same segment semantics in
//! pure rust — so full end-to-end runs (and CI) work on machines with no
//! XLA toolchain and no artifacts at all.
//!
//! ```no_run
//! use etuner::prelude::*;
//! let be = BackendSpec::auto("artifacts").create().unwrap();
//! let cfg = RunConfig::quickstart("res50", Benchmark::Nc);
//! let report = Simulation::new(be.as_ref(), cfg).unwrap().run().unwrap();
//! println!("avg accuracy {:.2}%  energy {:.1} Wh",
//!          report.avg_inference_accuracy * 100.0,
//!          report.energy.total_wh());
//! ```

// `clippy.toml` disallows `Option::unwrap`/`Result::unwrap`/`expect` so
// the serving hot path (serve::engine, serve::banks, model::session) can
// opt *in* with an inner `#![deny(clippy::disallowed_methods)]` — those
// modules must stay panic-free under injected faults.  Everywhere else
// (tests, setup paths, lock poisoning) unwrap stays allowed.
#![allow(clippy::disallowed_methods)]

pub mod baselines;
pub mod bitset;
pub mod ckpt;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod json;
pub mod load;
pub mod metrics;
pub mod model;
pub mod nnls;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testkit;
pub mod trace;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::policy::{FreezePolicyKind, TunePolicyKind};
    pub use crate::cost::device::DeviceModel;
    pub use crate::data::arrival::ArrivalKind;
    pub use crate::data::benchmarks::Benchmark;
    pub use crate::load::{
        capacity_search, CapacityResult, CapacitySpec, MixSpec, WorkloadKind,
        WorkloadSpec,
    };
    pub use crate::metrics::Report;
    pub use crate::runtime::{
        Backend, BackendKind, BackendSpec, FaultPlan, FaultyBackend,
        PjrtBackend, RefCpuBackend, TracingBackend,
    };
    pub use crate::serve::{
        Admission, QueuePolicyKind, RecoveryConfig, ServeConfig, ServeCtx,
        ServeEngine, ServeEvent,
    };
    pub use crate::sim::{
        run_config, run_config_traced, ParallelSweeper, RunConfig, Simulation,
    };
    pub use crate::trace::{Lane, Tracer};
}
