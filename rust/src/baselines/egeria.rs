//! Egeria (Wang et al., EuroSys'23 [88]): knowledge-guided layer freezing
//! driven by similarity against a reference model — like SimFreeze — but
//! with the two restrictions the paper's §V-C calls out and exploits:
//!
//! 1. **module granularity** — layers are assessed in blocks of two
//!    ("modules"), so a converged layer inside a non-converged module keeps
//!    training;
//! 2. **strictly front-to-back** — module `i` may only freeze if every
//!    module before it is already frozen, so late layers that converge
//!    early (residual networks, paper Fig. 5) are over-trained.

use anyhow::Result;

use crate::coordinator::policy::FreezePolicy;
use crate::cost::energy::CostBook;
use crate::cost::flops::FreezeState;
use crate::model::{ModelSession, Params};
use crate::runtime::artifact::ModelManifest;
use crate::runtime::exec::TensorF32;

/// Module size in freeze units (Egeria freezes in blocks).
const MODULE: usize = 2;

pub struct Egeria {
    state: FreezeState,
    ref_params: Params,
    probe: Option<Vec<f32>>,
    ref_feats: Option<TensorF32>,
    last_cka: Vec<Option<f32>>,
    interval: u64,
    since: u64,
    th: f64,
}

impl Egeria {
    pub fn new(m: &ModelManifest, ref_theta: Vec<f32>, interval: u64) -> Egeria {
        Egeria {
            state: FreezeState::none(m.units),
            ref_params: Params::from_vec(ref_theta),
            probe: None,
            ref_feats: None,
            last_cka: vec![None; m.units - 1],
            interval,
            since: 0,
            th: 0.01,
        }
    }

    fn feature_layers(&self) -> usize {
        self.state.units() - 1
    }

    /// The next candidate module: the first unfrozen one (front-to-back).
    fn next_module(&self) -> Option<(usize, usize)> {
        let fl = self.feature_layers();
        let mut u = 0;
        while u < fl {
            let hi = (u + MODULE).min(fl);
            if (u..hi).any(|l| !self.state.frozen[l]) {
                return Some((u, hi));
            }
            u = hi;
        }
        None
    }
}

impl FreezePolicy for Egeria {
    fn name(&self) -> &'static str {
        "Egeria"
    }

    fn state(&self) -> &FreezeState {
        &self.state
    }

    fn on_scenario_probe(
        &mut self,
        sess: &ModelSession,
        _params: &Params,
        probe: &[f32],
        _book: &mut CostBook,
    ) -> Result<()> {
        self.ref_feats = Some(sess.features(&self.ref_params, probe)?);
        self.probe = Some(probe.to_vec());
        // Egeria has no unfreezing path: on scenario change it keeps its
        // plan and relies on the reference snapshot refresh.
        self.last_cka.iter_mut().for_each(|c| *c = None);
        Ok(())
    }

    fn after_iteration(
        &mut self,
        sess: &ModelSession,
        params: &mut Params,
        book: &mut CostBook,
    ) -> Result<()> {
        self.since += 1;
        if self.since < self.interval || self.probe.is_none() {
            return Ok(());
        }
        self.since = 0;
        let Some((lo, hi)) = self.next_module() else {
            return Ok(());
        };
        book.charge_cka_probe(&sess.m, hi - lo);
        let feats = sess.features(params, self.probe.as_ref().unwrap())?;
        let ref_feats = self.ref_feats.as_ref().unwrap();
        // whole-module test: every layer in the candidate module must be
        // stable for the module to freeze.
        let mut all_stable = true;
        for l in lo..hi {
            let cka = sess.cka_layer(&feats, ref_feats, l)?;
            if let Some(prev) = self.last_cka[l] {
                let var = ((cka - prev) / prev.abs().max(1e-6)).abs() as f64;
                if var > self.th {
                    all_stable = false;
                }
            } else {
                all_stable = false;
            }
            self.last_cka[l] = Some(cka);
        }
        if all_stable {
            for l in lo..hi {
                self.state.frozen[l] = true;
            }
        }
        Ok(())
    }

    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.bools(&self.state.frozen);
        w.usize(self.last_cka.len());
        for &c in &self.last_cka {
            w.opt_f32(c);
        }
        match &self.probe {
            Some(p) => {
                w.bool(true);
                w.f32s(p);
            }
            None => w.bool(false),
        }
        w.u64(self.since);
    }

    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        sess: &ModelSession,
    ) -> Result<()> {
        self.state.frozen = r.bools()?;
        let n = r.usize()?;
        let mut last_cka = Vec::with_capacity(n);
        for _ in 0..n {
            last_cka.push(r.opt_f32()?);
        }
        self.last_cka = last_cka;
        if r.bool()? {
            let p = r.f32s()?;
            // ref_feats is derived: recompute on the restored probe.
            self.ref_feats = Some(sess.features(&self.ref_params, &p)?);
            self.probe = Some(p);
        } else {
            self.ref_feats = None;
            self.probe = None;
        }
        self.since = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment,
    };

    fn toy(units: usize) -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            d: 4,
            h: 4,
            blocks: units - 2,
            classes: 3,
            units,
            kind: "relu_res".into(),
            theta_len: 10,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![Segment { offset: 0, len: 1 }; units],
            tensors: vec![],
            head: HeadInfo { w_offset: 0, w_shape: [4, 3], b_offset: 0, classes: 3 },
            paper_units: (0..units)
                .map(|_| PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 })
                .collect(),
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn next_module_is_front_to_back() {
        let m = toy(6); // 5 feature layers, modules [0,2) [2,4) [4,5)
        let mut e = Egeria::new(&m, vec![], 10);
        assert_eq!(e.next_module(), Some((0, 2)));
        e.state.frozen[0] = true;
        e.state.frozen[1] = true;
        assert_eq!(e.next_module(), Some((2, 4)));
        for l in 2..5 {
            e.state.frozen[l] = true;
        }
        assert_eq!(e.next_module(), None);
    }

    #[test]
    fn partially_frozen_module_is_still_the_candidate() {
        let m = toy(6);
        let mut e = Egeria::new(&m, vec![], 10);
        e.state.frozen[1] = true; // interior layer frozen out of order
        assert_eq!(e.next_module(), Some((0, 2)));
    }
}
