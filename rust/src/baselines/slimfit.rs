//! SlimFit (Ardakani et al. [9]): freeze layers by *weight-update
//! magnitude* — an indirect training-dynamics signal.  Each interval, the
//! per-unit L1 norm of the parameter delta (normalized by the unit's norm)
//! is compared to a threshold; quiet units freeze.  Unlike SimFreeze there
//! is no representation-level check, so units whose weights move little but
//! whose features still shift get frozen prematurely — the inaccuracy the
//! paper's §V-C attributes to it.  Frozen units thaw on scenario changes
//! (SlimFit re-evaluates when the loss landscape shifts).

use anyhow::Result;

use crate::coordinator::policy::FreezePolicy;
use crate::cost::energy::CostBook;
use crate::cost::flops::FreezeState;
use crate::model::{ModelSession, Params};
use crate::runtime::artifact::ModelManifest;

pub struct SlimFit {
    state: FreezeState,
    snapshot: Option<Params>,
    interval: u64,
    since: u64,
    /// relative update-magnitude threshold.
    th: f32,
}

impl SlimFit {
    pub fn new(m: &ModelManifest, interval: u64) -> SlimFit {
        SlimFit {
            state: FreezeState::none(m.units),
            snapshot: None,
            interval,
            since: 0,
            th: 2e-3,
        }
    }
}

impl FreezePolicy for SlimFit {
    fn name(&self) -> &'static str {
        "SlimFit"
    }

    fn state(&self) -> &FreezeState {
        &self.state
    }

    fn on_scenario_probe(
        &mut self,
        _sess: &ModelSession,
        params: &Params,
        _probe: &[f32],
        _book: &mut CostBook,
    ) -> Result<()> {
        // thaw everything; new scenario, new dynamics.
        self.state.frozen.iter_mut().for_each(|f| *f = false);
        self.snapshot = Some(params.clone());
        self.since = 0;
        Ok(())
    }

    fn after_iteration(
        &mut self,
        sess: &ModelSession,
        params: &mut Params,
        _book: &mut CostBook,
    ) -> Result<()> {
        self.since += 1;
        if self.since < self.interval {
            return Ok(());
        }
        self.since = 0;
        let m = &sess.m;
        if let Some(snap) = &self.snapshot {
            // never freeze the head (last unit): the classifier must track
            // new classes.
            for u in 0..m.units - 1 {
                if self.state.frozen[u] {
                    continue;
                }
                let delta = params.unit_delta_l1(snap, m, u);
                let norm = params.unit_norm(m, u).max(1e-6);
                if delta / norm < self.th * self.interval as f32 {
                    self.state.frozen[u] = true;
                }
            }
        }
        self.snapshot = Some(params.clone());
        Ok(())
    }

    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.bools(&self.state.frozen);
        w.u64(self.since);
        match &self.snapshot {
            Some(p) => {
                w.bool(true);
                w.f32s(p.theta());
            }
            None => w.bool(false),
        }
    }

    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        _sess: &ModelSession,
    ) -> Result<()> {
        self.state.frozen = r.bools()?;
        self.since = r.u64()?;
        self.snapshot = if r.bool()? {
            // the snapshot is only ever read host-side (delta norms), so a
            // fresh Params identity is fine.
            Some(Params::from_vec(r.f32s()?))
        } else {
            None
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment, TensorInfo,
    };

    fn toy() -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            d: 2,
            h: 2,
            blocks: 1,
            classes: 2,
            units: 3,
            kind: "relu_res".into(),
            theta_len: 9,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![
                Segment { offset: 0, len: 3 },
                Segment { offset: 3, len: 3 },
                Segment { offset: 6, len: 3 },
            ],
            tensors: vec![TensorInfo {
                name: "embed.w".into(),
                shape: vec![3],
                unit: 0,
                offset: 0,
            }],
            head: HeadInfo { w_offset: 6, w_shape: [1, 2], b_offset: 8, classes: 2 },
            paper_units: (0..3)
                .map(|_| PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 })
                .collect(),
            artifacts: ArtifactNames::default(),
        }
    }

    // after_iteration needs a ModelSession only for the manifest; build a
    // fake by transmuting is unsafe — instead test the decision math via
    // the public pieces (delta/norm) and the freeze bookkeeping directly.
    #[test]
    fn quiet_units_freeze_active_units_do_not() {
        let m = toy();
        let snap = Params::new(vec![1.0; 9], &m).unwrap();
        let mut moved = snap.clone();
        // unit 0 quiet; unit 1 moves a lot
        moved.theta_mut()[3] += 1.0;
        let d0 = moved.unit_delta_l1(&snap, &m, 0);
        let d1 = moved.unit_delta_l1(&snap, &m, 1);
        assert_eq!(d0, 0.0);
        assert_eq!(d1, 1.0);
        let th = 2e-3f32 * 8.0;
        assert!(d0 / moved.unit_norm(&m, 0) < th);
        assert!(d1 / moved.unit_norm(&m, 1) > th);
    }

    #[test]
    fn head_is_never_a_freeze_candidate() {
        // encoded in the loop bound; assert the invariant used there.
        let m = toy();
        let sf = SlimFit::new(&m, 4);
        assert_eq!(sf.state.units(), 3);
        // the freeze loop runs over 0..units-1 — the head (unit 2) is out.
        assert_eq!((0..m.units - 1).last(), Some(1));
    }
}
