//! Ekya (Bhardwaj et al., NSDI'22 [12]): continuous-learning scheduler that
//! picks training configurations (here: how many leading units to freeze)
//! by **trial-and-error microprofiling** — at each scenario it runs a short
//! trial with every candidate configuration, observes validation accuracy,
//! and commits to the best for the rest of the scenario.  The trials
//! themselves are the inefficiency the paper's §V-C points at: a chunk of
//! each scenario's data is spent training under configurations that get
//! discarded.

use anyhow::Result;

use crate::coordinator::policy::FreezePolicy;
use crate::cost::energy::CostBook;
use crate::cost::flops::FreezeState;
use crate::model::{ModelSession, Params};
use crate::runtime::artifact::ModelManifest;

/// Rounds of trial per candidate configuration.
const TRIAL_ROUNDS: usize = 2;

pub struct Ekya {
    state: FreezeState,
    candidates: Vec<usize>, // prefix-freeze depths to microprofile
    /// trial bookkeeping: (candidate idx, rounds seen, best-so-far).
    trial: Option<TrialState>,
}

struct TrialState {
    idx: usize,
    rounds_in_trial: usize,
    results: Vec<f64>,
}

impl Ekya {
    pub fn new(m: &ModelManifest) -> Ekya {
        let u = m.units;
        // candidate prefixes: 0, ¼, ½, ¾ of the feature units.
        let fl = u - 1;
        let mut candidates = vec![0, fl / 4, fl / 2, (3 * fl) / 4];
        candidates.dedup();
        Ekya {
            state: FreezeState::none(u),
            candidates,
            trial: None,
        }
    }

    fn set_prefix(&mut self, k: usize) {
        for (i, f) in self.state.frozen.iter_mut().enumerate() {
            *f = i < k;
        }
    }

    pub fn profiling(&self) -> bool {
        self.trial.is_some()
    }
}

impl FreezePolicy for Ekya {
    fn name(&self) -> &'static str {
        "Ekya"
    }

    fn state(&self) -> &FreezeState {
        &self.state
    }

    fn on_scenario_probe(
        &mut self,
        _sess: &ModelSession,
        _params: &Params,
        _probe: &[f32],
        _book: &mut CostBook,
    ) -> Result<()> {
        // new scenario: restart microprofiling from the first candidate.
        self.trial = Some(TrialState {
            idx: 0,
            rounds_in_trial: 0,
            results: vec![],
        });
        let k = self.candidates[0];
        self.set_prefix(k);
        Ok(())
    }

    fn on_round_end(
        &mut self,
        sess: &ModelSession,
        _params: &mut Params,
        val_acc: f64,
        book: &mut CostBook,
    ) -> Result<()> {
        let Some(trial) = &mut self.trial else {
            return Ok(());
        };
        trial.rounds_in_trial += 1;
        if trial.rounds_in_trial < TRIAL_ROUNDS {
            return Ok(());
        }
        // trial for this candidate done
        trial.results.push(val_acc);
        trial.rounds_in_trial = 0;
        trial.idx += 1;
        // microprofiling bookkeeping cost (thumbnail evaluation)
        book.charge_validation(&sess.m, sess.m.batch_infer);
        if trial.idx < self.candidates.len() {
            let k = self.candidates[trial.idx];
            self.set_prefix(k);
        } else {
            // commit to the best configuration for the rest of the scenario
            let best = trial
                .results
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let k = self.candidates[best];
            self.set_prefix(k);
            self.trial = None;
        }
        Ok(())
    }

    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.bools(&self.state.frozen);
        match &self.trial {
            Some(t) => {
                w.bool(true);
                w.usize(t.idx);
                w.usize(t.rounds_in_trial);
                w.f64s(&t.results);
            }
            None => w.bool(false),
        }
    }

    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        _sess: &ModelSession,
    ) -> Result<()> {
        self.state.frozen = r.bools()?;
        self.trial = if r.bool()? {
            Some(TrialState {
                idx: r.usize()?,
                rounds_in_trial: r.usize()?,
                results: r.f64s()?,
            })
        } else {
            None
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment,
    };

    fn toy(units: usize) -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            d: 4,
            h: 4,
            blocks: units - 2,
            classes: 3,
            units,
            kind: "relu_res".into(),
            theta_len: 10,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![Segment { offset: 0, len: 1 }; units],
            tensors: vec![],
            head: HeadInfo { w_offset: 0, w_shape: [4, 3], b_offset: 0, classes: 3 },
            paper_units: (0..units)
                .map(|_| PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 })
                .collect(),
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn candidates_cover_increasing_depths() {
        let e = Ekya::new(&toy(10)); // 9 feature layers
        assert_eq!(e.candidates, vec![0, 2, 4, 6]);
    }

    #[test]
    fn set_prefix_freezes_exactly_k() {
        let mut e = Ekya::new(&toy(6));
        e.set_prefix(3);
        assert_eq!(e.state.frozen_prefix(), 3);
        assert_eq!(e.state.trainable_count(), 3);
        e.set_prefix(0);
        assert_eq!(e.state.frozen_prefix(), 0);
    }
}
