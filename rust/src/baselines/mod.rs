//! SOTA efficient-training baselines the paper compares against in
//! Table V.  All four are implemented for real against the same
//! [`crate::coordinator::policy::FreezePolicy`] surface, and — as the
//! paper does for fairness — every baseline is run *with* LazyTune's
//! inter-tuning optimization integrated.
//!
//! | baseline | mechanism (our faithful scale-down)                        |
//! |----------|------------------------------------------------------------|
//! | Egeria [88]  | reference-model similarity at *module* granularity, frozen strictly front-to-back |
//! | SlimFit [9]  | freeze layers whose weight-update magnitude falls below a threshold (indirect metric) |
//! | RigL [23]    | sparse training: magnitude drop / gradient-proxy grow masks over θ segments |
//! | Ekya [12]    | trial-and-error microprofiling of freeze configurations at each scenario |

pub mod egeria;
pub mod ekya;
pub mod rigl;
pub mod slimfit;
