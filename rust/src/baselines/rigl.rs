//! RigL (Evci et al., ICML'20 [23]): sparse training with dynamic topology.
//! A fixed global sparsity is maintained over the backbone weights; every
//! `update_interval` iterations the lowest-magnitude fraction of active
//! weights is *dropped* and the same number of inactive weights with the
//! largest gradient proxy (here: recent parameter movement, since dense
//! gradients for masked weights are not materialized by the artifacts) is
//! *grown*.
//!
//! Cost accounting: the paper's §V-C notes sparse training underuses edge
//! GPUs (irregular access, imbalance).  We charge compute as
//! `dense_flops × (1 − sparsity) × inefficiency` with inefficiency 2.2 —
//! RigL saves FLOPs on paper but only part of it materializes.

use anyhow::Result;

use crate::coordinator::policy::FreezePolicy;
use crate::cost::energy::CostBook;
use crate::cost::flops::FreezeState;
use crate::model::{ModelSession, Params};
use crate::rng::Pcg32;
use crate::runtime::artifact::ModelManifest;

const UPDATE_INTERVAL: u64 = 10;
const DROP_FRACTION: f32 = 0.2;
const INEFFICIENCY: f64 = 2.2;

pub struct RigL {
    state: FreezeState, // nothing ever freezes; kept for the trait
    /// active-weight mask over the backbone θ range (head stays dense).
    mask: Vec<bool>,
    backbone_len: usize,
    sparsity: f32,
    since: u64,
    prev: Option<Vec<f32>>,
    rng: Pcg32,
}

impl RigL {
    pub fn new(m: &ModelManifest, sparsity: f32, seed: u64) -> RigL {
        // head (last unit) stays dense: classifier rows must stay trainable.
        let backbone_len = m.unit_segments[m.units - 1].offset;
        let mut rng = Pcg32::new(seed ^ 0x51AB, 9);
        let mut mask = vec![true; backbone_len];
        // ERK-style random init at the target sparsity
        let target_off = (backbone_len as f32 * sparsity) as usize;
        let mut off = 0;
        while off < target_off {
            let i = rng.below(backbone_len);
            if mask[i] {
                mask[i] = false;
                off += 1;
            }
        }
        RigL {
            state: FreezeState::none(m.units),
            mask,
            backbone_len,
            sparsity,
            since: 0,
            prev: None,
            rng,
        }
    }

    pub fn active_count(&self) -> usize {
        self.mask.iter().filter(|&&a| a).count()
    }

    fn apply_mask(&self, params: &mut Params) {
        let theta = params.theta_mut();
        for (i, &active) in self.mask.iter().enumerate() {
            if !active {
                theta[i] = 0.0;
            }
        }
    }

    /// drop lowest-|w| active weights, grow by movement proxy.
    fn update_topology(&mut self, params: &Params) {
        let n_active = self.active_count();
        let k = ((n_active as f32) * DROP_FRACTION) as usize;
        if k == 0 {
            return;
        }
        let theta = params.theta();
        // drop: k smallest-magnitude active weights
        let mut active: Vec<usize> =
            (0..self.backbone_len).filter(|&i| self.mask[i]).collect();
        active.sort_by(|&a, &b| {
            theta[a].abs().partial_cmp(&theta[b].abs()).unwrap()
        });
        for &i in active.iter().take(k) {
            self.mask[i] = false;
        }
        // grow: k inactive weights with the largest movement proxy (or
        // random when no history yet)
        let mut inactive: Vec<usize> =
            (0..self.backbone_len).filter(|&i| !self.mask[i]).collect();
        match &self.prev {
            Some(prev) => {
                inactive.sort_by(|&a, &b| {
                    let ma = (theta[a] - prev[a]).abs();
                    let mb = (theta[b] - prev[b]).abs();
                    mb.partial_cmp(&ma).unwrap()
                });
            }
            None => self.rng.shuffle(&mut inactive),
        }
        for &i in inactive.iter().take(k) {
            self.mask[i] = true;
        }
    }
}

impl FreezePolicy for RigL {
    fn name(&self) -> &'static str {
        "RigL"
    }

    fn state(&self) -> &FreezeState {
        &self.state
    }

    fn after_iteration(
        &mut self,
        _sess: &ModelSession,
        params: &mut Params,
        _book: &mut CostBook,
    ) -> Result<()> {
        self.since += 1;
        if self.since >= UPDATE_INTERVAL {
            self.since = 0;
            self.update_topology(params);
            self.prev = Some(params.theta()[..self.backbone_len].to_vec());
        }
        self.apply_mask(params);
        Ok(())
    }

    fn compute_inefficiency(&self) -> f64 {
        ((1.0 - self.sparsity as f64) * INEFFICIENCY).min(1.0)
    }

    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.bools(&self.state.frozen);
        w.bools(&self.mask);
        w.u64(self.since);
        match &self.prev {
            Some(p) => {
                w.bool(true);
                w.f32s(p);
            }
            None => w.bool(false),
        }
        let (s, i) = self.rng.state();
        w.u64(s);
        w.u64(i);
    }

    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        _sess: &ModelSession,
    ) -> Result<()> {
        self.state.frozen = r.bools()?;
        self.mask = r.bools()?;
        self.since = r.u64()?;
        self.prev = if r.bool()? { Some(r.f32s()?) } else { None };
        let s = r.u64()?;
        let i = r.u64()?;
        self.rng = Pcg32::from_state(s, i);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment,
    };

    fn toy() -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            d: 4,
            h: 4,
            blocks: 2,
            classes: 3,
            units: 4,
            kind: "relu_res".into(),
            theta_len: 100,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![
                Segment { offset: 0, len: 30 },
                Segment { offset: 30, len: 30 },
                Segment { offset: 60, len: 20 },
                Segment { offset: 80, len: 20 },
            ],
            tensors: vec![],
            head: HeadInfo { w_offset: 80, w_shape: [4, 3], b_offset: 92, classes: 3 },
            paper_units: (0..4)
                .map(|_| PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 })
                .collect(),
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn init_hits_target_sparsity_over_backbone_only() {
        let m = toy();
        let r = RigL::new(&m, 0.8, 1);
        assert_eq!(r.backbone_len, 80); // head (20) stays dense
        let active = r.active_count();
        assert_eq!(active, 80 - (80.0f32 * 0.8) as usize);
    }

    #[test]
    fn topology_update_preserves_active_count() {
        let m = toy();
        let mut r = RigL::new(&m, 0.5, 2);
        let before = r.active_count();
        let mut p = Params::new(
            (0..100).map(|i| (i as f32 * 0.37).sin()).collect(),
            &m,
        )
        .unwrap();
        r.update_topology(&p);
        assert_eq!(r.active_count(), before);
        r.apply_mask(&mut p);
        let zeroed = p.theta()[..80].iter().filter(|&&v| v == 0.0).count();
        assert!(zeroed >= 80 - before);
    }

    #[test]
    fn mask_zeroes_only_backbone() {
        let m = toy();
        let r = RigL::new(&m, 0.9, 3);
        let mut p = Params::new(vec![1.0; 100], &m).unwrap();
        r.apply_mask(&mut p);
        assert!(p.theta()[80..].iter().all(|&v| v == 1.0), "head touched");
        let active = p.theta()[..80].iter().filter(|&&v| v != 0.0).count();
        assert_eq!(active, r.active_count());
    }

    #[test]
    fn inefficiency_caps_at_dense() {
        let m = toy();
        let r = RigL::new(&m, 0.1, 4); // low sparsity: (0.9*2.2) > 1 -> cap
        assert_eq!(r.compute_inefficiency(), 1.0);
        let r2 = RigL::new(&m, 0.8, 4);
        assert!((r2.compute_inefficiency() - 0.44).abs() < 1e-6);
    }
}
