//! Fixed-capacity bitset over small dense id spaces (class ids, unit ids).
//!
//! Replaces the `Vec<usize>` + `contains` scans on the simulator's request
//! and training hot paths: membership is O(1), iteration is ascending, and
//! clearing reuses the allocation.

/// Fixed-capacity set of `usize` ids in `0..capacity`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
    len: usize,
}

impl BitSet {
    pub fn new(capacity: usize) -> BitSet {
        BitSet { words: vec![0; (capacity + 63) / 64], capacity, len: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of ids currently in the set.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert `i`; returns true if it was newly inserted.
    pub fn insert(&mut self, i: usize) -> bool {
        debug_assert!(i < self.capacity, "bit {i} out of range {}", self.capacity);
        let (w, b) = (i / 64, 1u64 << (i % 64));
        if self.words[w] & b != 0 {
            return false;
        }
        self.words[w] |= b;
        self.len += 1;
        true
    }

    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.capacity, "bit {i} out of range {}", self.capacity);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// Remove every id, keeping the allocation.
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
        self.len = 0;
    }

    /// Ascending iterator over the set ids.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let b = w.trailing_zeros() as usize;
                w &= w - 1;
                Some(wi * 64 + b)
            })
        })
    }

    /// Refill from a slice of ids (duplicates collapse).
    pub fn assign(&mut self, ids: &[usize]) {
        self.clear();
        for &i in ids {
            self.insert(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_and_dedup() {
        let mut s = BitSet::new(130);
        assert!(s.insert(0));
        assert!(s.insert(129));
        assert!(s.insert(64));
        assert!(!s.insert(64), "duplicate insert must report false");
        assert_eq!(s.len(), 3);
        assert!(s.contains(0) && s.contains(64) && s.contains(129));
        assert!(!s.contains(1) && !s.contains(128));
    }

    #[test]
    fn iter_is_ascending_and_complete() {
        let mut s = BitSet::new(200);
        for i in [199, 3, 64, 65, 0, 127] {
            s.insert(i);
        }
        let got: Vec<usize> = s.iter().collect();
        assert_eq!(got, vec![0, 3, 64, 65, 127, 199]);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut s = BitSet::new(70);
        s.insert(69);
        s.clear();
        assert_eq!(s.len(), 0);
        assert!(!s.contains(69));
        assert_eq!(s.capacity(), 70);
        s.assign(&[1, 1, 2]);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn empty_and_full_words() {
        let s = BitSet::new(64);
        assert_eq!(s.iter().count(), 0);
        let mut f = BitSet::new(64);
        for i in 0..64 {
            f.insert(i);
        }
        assert_eq!(f.iter().count(), 64);
        assert_eq!(f.iter().last(), Some(63));
    }
}
