//! The continual-learning simulation: one deployed model serving a
//! benchmark's event stream under a (tune, freeze) policy pair, with all
//! compute flowing through a [`crate::runtime::Backend`] (PJRT artifacts
//! or the pure-Rust reference executor) and all costs charged to the
//! Jetson-scale ledger.  Seed sweeps scale across cores through
//! [`ParallelSweeper`] (one backend per worker thread).

pub mod run;
pub mod sweep;
pub mod valpool;

pub use run::{run_config, run_config_traced, RunConfig, Simulation};
pub use sweep::{run_averaged, ParallelSweeper, QUARANTINE_AFTER};
