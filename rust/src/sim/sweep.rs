//! Seed sweeps: the paper reports every number as the average of 5 runs
//! with different random seeds.
//!
//! # Parallel sweep engine
//!
//! Backends are deliberately single-threaded (`!Sync`: PJRT executables
//! live behind `Rc`/`RefCell`, and the reference executor keeps interior
//! counters), so a *single* backend can't be shared across threads.
//! [`ParallelSweeper`] instead carries a [`BackendSpec`] and gives each
//! worker thread its **own** backend constructed from it: workers pull
//! `(index, RunConfig)` jobs from a shared queue and write results into
//! their reserved slot, so the output order — and, because every
//! simulation is seed-deterministic, every byte of every report except
//! wall-clock timings — is identical no matter how many workers run (on
//! the reference backend this determinism is *bit-exact*, enforced by
//! `tests/backend_parity.rs`).

use std::sync::Mutex;

use anyhow::Result;

use crate::metrics::{average, Report};
use crate::runtime::{Backend, BackendKind, BackendSpec};

use super::run::{RunConfig, Simulation};

/// Run `cfg` under `seeds` sequentially on a borrowed backend and return
/// (mean report, per-seed reports).  The compatibility entry point —
/// sweeps that should use every core go through [`ParallelSweeper`].
pub fn run_averaged(
    be: &dyn Backend,
    cfg: &RunConfig,
    seeds: &[u64],
) -> Result<(Report, Vec<Report>)> {
    anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
    let mut reports = Vec::with_capacity(seeds.len());
    for &s in seeds {
        let c = cfg.clone().with_seed(s);
        reports.push(Simulation::new(be, c)?.run()?);
    }
    Ok((average(&reports), reports))
}

/// Multi-core sweep engine: owns a backend for main-thread work and spawns
/// `jobs` scoped worker threads (each constructing its own backend from
/// the spec) for batched runs.
pub struct ParallelSweeper {
    be: Box<dyn Backend>,
    spec: BackendSpec,
    jobs: usize,
}

impl ParallelSweeper {
    /// Construct the main-thread backend from `spec`.  `jobs` is clamped
    /// to ≥ 1; `jobs == 1` means fully sequential (no threads spawned).
    ///
    /// An `Auto` spec is resolved to the *concrete* kind the main backend
    /// landed on before it is handed to workers: every worker must
    /// construct the same executor (a worker whose PJRT client fails must
    /// surface that error, not silently fall back to refcpu and mix
    /// fp-close-but-different numbers into one sweep).
    pub fn new(spec: BackendSpec, jobs: usize) -> Result<ParallelSweeper> {
        let be = spec.create()?;
        let resolved = match be.name() {
            "pjrt" => BackendKind::Pjrt,
            _ => BackendKind::RefCpu,
        };
        let spec = BackendSpec::new(resolved, &spec.dir);
        Ok(ParallelSweeper { be, spec, jobs: jobs.max(1) })
    }

    /// Auto-select the backend over an artifact directory (PJRT when it
    /// can execute here, the reference executor otherwise).
    pub fn from_dir<P: AsRef<std::path::Path>>(dir: P, jobs: usize) -> Result<ParallelSweeper> {
        ParallelSweeper::new(BackendSpec::auto(dir), jobs)
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Default worker count for CLI/bench entry points: every core.
    pub fn default_jobs() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get())
    }

    /// The main-thread backend (single runs, probes, direct simulations).
    pub fn backend(&self) -> &dyn Backend {
        self.be.as_ref()
    }

    /// Run every config, in deterministic input order, across up to
    /// `jobs` worker threads.
    pub fn run_many(&self, cfgs: &[RunConfig]) -> Result<Vec<Report>> {
        let workers = self.jobs.min(cfgs.len());
        if workers <= 1 {
            return cfgs
                .iter()
                .map(|c| Simulation::new(self.be.as_ref(), c.clone())?.run())
                .collect();
        }
        let spec = &self.spec;
        let next = Mutex::new(0usize);
        let slots: Mutex<Vec<Option<Result<Report>>>> =
            Mutex::new((0..cfgs.len()).map(|_| None).collect());
        let failed = Mutex::new(false);
        // worker-initialization failures get their own slot so a job
        // completing concurrently can never overwrite the root cause.
        let init_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    // each worker owns its backend: backends are !Sync.
                    let be = match spec.create() {
                        Ok(be) => be,
                        Err(e) => {
                            *failed.lock().unwrap() = true;
                            init_err.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    };
                    loop {
                        let i = {
                            let mut n = next.lock().unwrap();
                            if *n >= cfgs.len() || *failed.lock().unwrap() {
                                break;
                            }
                            let i = *n;
                            *n += 1;
                            i
                        };
                        let res = Simulation::new(be.as_ref(), cfgs[i].clone())
                            .and_then(|s| s.run());
                        if res.is_err() {
                            *failed.lock().unwrap() = true;
                        }
                        slots.lock().unwrap()[i] = Some(res);
                    }
                });
            }
        });
        if let Some(e) = init_err.into_inner().unwrap() {
            return Err(e.context("sweep worker failed to construct its backend"));
        }
        let slots = slots.into_inner().unwrap();
        let mut out = Vec::with_capacity(cfgs.len());
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e.context(format!("sweep job {i}"))),
                None => anyhow::bail!("sweep job {i} was aborted by an earlier failure"),
            }
        }
        Ok(out)
    }

    /// Parallel equivalent of [`run_averaged`]: identical mean and
    /// per-seed reports (modulo wall-clock fields) for any worker count.
    pub fn run_averaged(
        &self,
        cfg: &RunConfig,
        seeds: &[u64],
    ) -> Result<(Report, Vec<Report>)> {
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        let cfgs: Vec<RunConfig> =
            seeds.iter().map(|&s| cfg.clone().with_seed(s)).collect();
        let reports = self.run_many(&cfgs)?;
        Ok((average(&reports), reports))
    }

    /// Seed-average many configs in one flat parallel batch (the whole
    /// table grid keeps every core busy instead of one cell at a time).
    /// Returns one mean report per input config, in input order.
    pub fn run_averaged_many(
        &self,
        cfgs: &[RunConfig],
        seeds: &[u64],
    ) -> Result<Vec<Report>> {
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        let jobs: Vec<RunConfig> = cfgs
            .iter()
            .flat_map(|c| seeds.iter().map(|&s| c.clone().with_seed(s)))
            .collect();
        let reports = self.run_many(&jobs)?;
        Ok(reports
            .chunks(seeds.len())
            .map(average)
            .collect())
    }
}
