//! Seed sweeps: the paper reports every number as the average of 5 runs
//! with different random seeds.
//!
//! # Parallel sweep engine
//!
//! Backends are deliberately single-threaded (`!Sync`: PJRT executables
//! live behind `Rc`/`RefCell`, and the reference executor keeps interior
//! counters), so a *single* backend can't be shared across threads.
//! [`ParallelSweeper`] instead carries a [`BackendSpec`] and gives each
//! worker thread its **own** backend constructed from it: workers pull
//! `(index, RunConfig)` jobs from a shared queue and write results into
//! their reserved slot, so the output order — and, because every
//! simulation is seed-deterministic, every byte of every report except
//! wall-clock timings — is identical no matter how many workers run (on
//! the reference backend this determinism is *bit-exact*, enforced by
//! `tests/backend_parity.rs`).
//!
//! # Supervision
//!
//! Worker cells run under [`std::panic::catch_unwind`]: a cell that
//! panics restarts the worker's backend (interior caches may be
//! mid-update at the unwind point) and re-runs the cell; after
//! [`QUARANTINE_AFTER`] consecutive panics the cell is quarantined and
//! its slot reports an error.  Because every run is seed-deterministic, a
//! deterministic panic quarantines the *same* cell with the same message
//! regardless of worker count, preserving N=1 vs N=4 equivalence.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use anyhow::Result;

use crate::metrics::{average, Report};
use crate::runtime::{Backend, BackendKind, BackendSpec};
use crate::trace::{self, Event, Lane, Tracer};

use super::run::{run_config, run_config_traced, RunConfig};

/// Default consecutive panics of one sweep cell before it is quarantined
/// (the first panic restarts the backend and requeues the cell once).
/// Configurable per sweep via [`ParallelSweeper::set_quarantine_after`]
/// (`--quarantine-after`).
pub const QUARANTINE_AFTER: u32 = 2;

/// Render a `catch_unwind` payload for the quarantine error message.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one sweep cell under supervision: a panicking attempt restarts
/// `be` from `spec` and re-runs the cell; `quarantine_after` consecutive
/// panics quarantine it.  `Err` results from the run itself (not panics)
/// pass through untouched — recoverable failures are the engine's job,
/// supervision only contains crashes.
fn run_supervised(
    be: &mut Box<dyn Backend>,
    mut restart: impl FnMut() -> Result<Box<dyn Backend>>,
    i: usize,
    cfg: &RunConfig,
    tracer: &Tracer,
    quarantine_after: u32,
) -> Result<Report> {
    let mut last = String::new();
    for _ in 0..quarantine_after.max(1) {
        // AssertUnwindSafe: on panic the backend is discarded and rebuilt
        // below, and the config clone is owned by the attempt — nothing
        // in a half-unwound state is observed again.  (The tracer's
        // record methods never hold a borrow across the backend call, so
        // an unwound attempt leaves it usable.)
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            run_config_traced(be.as_ref(), cfg.clone(), tracer)
        }));
        match attempt {
            Ok(res) => return res,
            Err(p) => {
                last = panic_msg(p.as_ref());
                tracer.instant(
                    Lane::Sweep,
                    "backend_restart",
                    0.0,
                    &[("cell", i as f64)],
                );
                *be = restart().map_err(|e| {
                    e.context(format!(
                        "sweep cell {i}: backend restart after panic failed"
                    ))
                })?;
            }
        }
    }
    tracer.instant(Lane::Sweep, "cell_quarantined", 0.0, &[("cell", i as f64)]);
    Err(anyhow::anyhow!(
        "sweep cell {i} quarantined after {quarantine_after} panics (last: {last})"
    ))
}

/// Run `cfg` under `seeds` sequentially on a borrowed backend and return
/// (mean report, per-seed reports).  The compatibility entry point —
/// sweeps that should use every core go through [`ParallelSweeper`].
pub fn run_averaged(
    be: &dyn Backend,
    cfg: &RunConfig,
    seeds: &[u64],
) -> Result<(Report, Vec<Report>)> {
    anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
    let mut reports = Vec::with_capacity(seeds.len());
    for &s in seeds {
        let c = cfg.clone().with_seed(s);
        reports.push(run_config(be, c)?);
    }
    Ok((average(&reports), reports))
}

/// Multi-core sweep engine: owns a backend for main-thread work and spawns
/// `jobs` scoped worker threads (each constructing its own backend from
/// the spec) for batched runs.
pub struct ParallelSweeper {
    be: Box<dyn Backend>,
    spec: BackendSpec,
    jobs: usize,
    /// Coordinator-side tracer (disabled by default).  Workers record
    /// into thread-local tracers; the coordinator absorbs their event
    /// batches in **cell order**, so the merged timeline is deterministic
    /// for any worker count.
    tracer: Tracer,
    /// Consecutive panics before a cell is quarantined
    /// (`--quarantine-after`; default [`QUARANTINE_AFTER`], clamped ≥ 1).
    quarantine_after: u32,
    /// Sweep-cell journal (`--sweep-journal`): completed cells — keyed by
    /// [`crate::ckpt::config_digest`] — are read back instead of re-run,
    /// so an interrupted grid resumes with only its unfinished cells.
    journal: Option<crate::ckpt::SweepJournal>,
}

impl ParallelSweeper {
    /// Construct the main-thread backend from `spec`.  `jobs` is clamped
    /// to ≥ 1; `jobs == 1` means fully sequential (no threads spawned).
    ///
    /// An `Auto` spec is resolved to the *concrete* kind the main backend
    /// landed on before it is handed to workers: every worker must
    /// construct the same executor (a worker whose PJRT client fails must
    /// surface that error, not silently fall back to refcpu and mix
    /// fp-close-but-different numbers into one sweep).
    pub fn new(spec: BackendSpec, jobs: usize) -> Result<ParallelSweeper> {
        let be = spec.create()?;
        let resolved = match be.name() {
            "pjrt" => BackendKind::Pjrt,
            _ => BackendKind::RefCpu,
        };
        let spec = BackendSpec::new(resolved, &spec.dir);
        Ok(ParallelSweeper {
            be,
            spec,
            jobs: jobs.max(1),
            tracer: Tracer::disabled(),
            quarantine_after: QUARANTINE_AFTER,
            journal: None,
        })
    }

    /// Override the panic budget before a cell is quarantined
    /// (`--quarantine-after`; clamped to ≥ 1).
    pub fn set_quarantine_after(&mut self, n: u32) {
        self.quarantine_after = n.max(1);
    }

    /// Attach a sweep-cell journal (`--sweep-journal`): completed cells
    /// found in it are returned without re-running, and every freshly
    /// completed cell is appended — so a crashed or interrupted sweep
    /// resumes from where it stopped with bit-identical merged results.
    pub fn set_journal<P: AsRef<std::path::Path>>(&mut self, path: P) {
        self.journal =
            Some(crate::ckpt::SweepJournal::new(path.as_ref()));
    }

    /// Attach a tracer: every cell run by [`ParallelSweeper::run_many`]
    /// records into it (worker batches merged in cell order).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Auto-select the backend over an artifact directory (PJRT when it
    /// can execute here, the reference executor otherwise).
    pub fn from_dir<P: AsRef<std::path::Path>>(dir: P, jobs: usize) -> Result<ParallelSweeper> {
        ParallelSweeper::new(BackendSpec::auto(dir), jobs)
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Default worker count for CLI/bench entry points: every core.
    pub fn default_jobs() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get())
    }

    /// The main-thread backend (single runs, probes, direct simulations).
    pub fn backend(&self) -> &dyn Backend {
        self.be.as_ref()
    }

    /// Run every config, in deterministic input order, across up to
    /// `jobs` worker threads.  With a journal attached
    /// ([`ParallelSweeper::set_journal`]), cells whose config digest
    /// already has a valid journal record are read back instead of
    /// re-run; freshly completed cells are appended.
    pub fn run_many(&self, cfgs: &[RunConfig]) -> Result<Vec<Report>> {
        let Some(journal) = &self.journal else {
            return self.run_many_inner(cfgs);
        };
        let digests: Vec<u64> =
            cfgs.iter().map(crate::ckpt::config_digest).collect();
        let done = journal.load()?;
        let mut out: Vec<Option<Report>> = Vec::with_capacity(cfgs.len());
        let mut todo: Vec<usize> = Vec::new();
        for (i, &d) in digests.iter().enumerate() {
            match done.iter().find(|(k, _)| *k == d) {
                Some((_, r)) => out.push(Some(r.clone())),
                None => {
                    out.push(None);
                    todo.push(i);
                }
            }
        }
        if !todo.is_empty() {
            let fresh_cfgs: Vec<RunConfig> =
                todo.iter().map(|&i| cfgs[i].clone()).collect();
            let fresh = self.run_many_inner(&fresh_cfgs)?;
            for (&i, r) in todo.iter().zip(fresh) {
                journal.record(digests[i], &r)?;
                out[i] = Some(r);
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every cell resolved")).collect())
    }

    fn run_many_inner(&self, cfgs: &[RunConfig]) -> Result<Vec<Report>> {
        let workers = self.jobs.min(cfgs.len());
        if workers <= 1 {
            // sequential path, same supervision semantics as the worker
            // path: run on the main backend until a panic forces a
            // replacement (the main backend cannot be rebuilt in place —
            // it is borrowed — so a fresh one takes over from the spec).
            let mut replacement: Option<Box<dyn Backend>> = None;
            let mut out = Vec::with_capacity(cfgs.len());
            for (i, c) in cfgs.iter().enumerate() {
                self.tracer.instant(
                    Lane::Sweep,
                    "cell_claim",
                    0.0,
                    &[("cell", i as f64), ("worker", 0.0)],
                );
                let mut res = None;
                for attempt in 1..=self.quarantine_after {
                    let be: &dyn Backend =
                        replacement.as_deref().unwrap_or(self.be.as_ref());
                    match catch_unwind(AssertUnwindSafe(|| {
                        run_config_traced(be, c.clone(), &self.tracer)
                    })) {
                        Ok(r) => {
                            res = Some(r);
                            break;
                        }
                        Err(p) => {
                            let msg = panic_msg(p.as_ref());
                            self.tracer.instant(
                                Lane::Sweep,
                                "backend_restart",
                                0.0,
                                &[("cell", i as f64)],
                            );
                            replacement = Some(self.spec.create().map_err(
                                |e| {
                                    e.context(format!(
                                        "sweep cell {i}: backend restart \
                                         after panic failed"
                                    ))
                                },
                            )?);
                            if attempt == self.quarantine_after {
                                self.tracer.instant(
                                    Lane::Sweep,
                                    "cell_quarantined",
                                    0.0,
                                    &[("cell", i as f64)],
                                );
                                res = Some(Err(anyhow::anyhow!(
                                    "sweep cell {i} quarantined after {} \
                                     panics (last: {msg})",
                                    self.quarantine_after
                                )));
                            }
                        }
                    }
                }
                match res {
                    Some(Ok(r)) => out.push(r),
                    Some(Err(e)) => {
                        return Err(e.context(format!("sweep job {i}")))
                    }
                    None => unreachable!("supervision loop always resolves"),
                }
            }
            return Ok(out);
        }
        let spec = &self.spec;
        let trace_on = self.tracer.on();
        let quarantine_after = self.quarantine_after;
        let next = Mutex::new(0usize);
        let slots: Mutex<Vec<Option<Result<Report>>>> =
            Mutex::new((0..cfgs.len()).map(|_| None).collect());
        // per-cell event batches from the workers' thread-local tracers
        // (a `Tracer` itself is `Rc`-backed and never crosses threads);
        // absorbed below in cell order so the merged timeline is
        // worker-count independent.
        let cell_events: Mutex<Vec<Vec<Event>>> =
            Mutex::new((0..cfgs.len()).map(|_| Vec::new()).collect());
        let failed = Mutex::new(false);
        // worker-initialization failures get their own slot so a job
        // completing concurrently can never overwrite the root cause.
        let init_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let (next, slots, failed, init_err, cell_events) =
                    (&next, &slots, &failed, &init_err, &cell_events);
                scope.spawn(move || {
                    // each worker owns its backend: backends are !Sync.
                    let mut be = match spec.create() {
                        Ok(be) => be,
                        Err(e) => {
                            *failed.lock().unwrap() = true;
                            init_err.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    };
                    loop {
                        let i = {
                            let mut n = next.lock().unwrap();
                            if *n >= cfgs.len() || *failed.lock().unwrap() {
                                break;
                            }
                            let i = *n;
                            *n += 1;
                            i
                        };
                        let local = if trace_on {
                            Tracer::enabled(trace::DEFAULT_CAPACITY)
                        } else {
                            Tracer::disabled()
                        };
                        local.instant(
                            Lane::Sweep,
                            "cell_claim",
                            0.0,
                            &[("cell", i as f64), ("worker", w as f64)],
                        );
                        let res = run_supervised(
                            &mut be,
                            || spec.create(),
                            i,
                            &cfgs[i],
                            &local,
                            quarantine_after,
                        );
                        if trace_on {
                            cell_events.lock().unwrap()[i] =
                                local.take_events();
                        }
                        if res.is_err() {
                            *failed.lock().unwrap() = true;
                        }
                        slots.lock().unwrap()[i] = Some(res);
                    }
                });
            }
        });
        for evs in cell_events.into_inner().unwrap() {
            self.tracer.absorb(&evs);
        }
        if let Some(e) = init_err.into_inner().unwrap() {
            return Err(e.context("sweep worker failed to construct its backend"));
        }
        let slots = slots.into_inner().unwrap();
        let mut out = Vec::with_capacity(cfgs.len());
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e.context(format!("sweep job {i}"))),
                None => anyhow::bail!("sweep job {i} was aborted by an earlier failure"),
            }
        }
        Ok(out)
    }

    /// Parallel equivalent of [`run_averaged`]: identical mean and
    /// per-seed reports (modulo wall-clock fields) for any worker count.
    pub fn run_averaged(
        &self,
        cfg: &RunConfig,
        seeds: &[u64],
    ) -> Result<(Report, Vec<Report>)> {
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        let cfgs: Vec<RunConfig> =
            seeds.iter().map(|&s| cfg.clone().with_seed(s)).collect();
        let reports = self.run_many(&cfgs)?;
        Ok((average(&reports), reports))
    }

    /// Seed-average many configs in one flat parallel batch (the whole
    /// table grid keeps every core busy instead of one cell at a time).
    /// Returns one mean report per input config, in input order.
    pub fn run_averaged_many(
        &self,
        cfgs: &[RunConfig],
        seeds: &[u64],
    ) -> Result<Vec<Report>> {
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        let jobs: Vec<RunConfig> = cfgs
            .iter()
            .flat_map(|c| seeds.iter().map(|&s| c.clone().with_seed(s)))
            .collect();
        let reports = self.run_many(&jobs)?;
        Ok(reports
            .chunks(seeds.len())
            .map(average)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::benchmarks::Benchmark;
    use crate::runtime::{FaultPlan, Manifest, Value};
    use crate::testkit;

    /// Panics on first contact — a crashed worker backend.
    struct PanicBackend;

    impl Backend for PanicBackend {
        fn name(&self) -> &'static str {
            "panic"
        }
        fn manifest(&self) -> &Manifest {
            panic!("injected backend crash")
        }
        fn executions(&self) -> u64 {
            panic!("injected backend crash")
        }
        fn marshal_f32(&self, _: &[f32], _: &[usize]) -> Result<Value> {
            panic!("injected backend crash")
        }
        fn marshal_i32(&self, _: &[i32], _: &[usize]) -> Result<Value> {
            panic!("injected backend crash")
        }
        fn execute(&self, _: &str, _: &[&Value]) -> Result<Vec<Value>> {
            panic!("injected backend crash")
        }
        fn theta0(&self, _: &str) -> Result<Vec<f32>> {
            panic!("injected backend crash")
        }
        fn phi0(&self, _: &str) -> Result<Vec<f32>> {
            panic!("injected backend crash")
        }
    }

    fn quick(seed: u64) -> RunConfig {
        let mut c = RunConfig::quickstart("mbv2", Benchmark::SCifar10)
            .with_seed(seed);
        c.n_requests = 40;
        c.faults = FaultPlan::none();
        c
    }

    #[test]
    fn panicking_cell_restarts_backend_and_requeues() {
        let spec = testkit::refcpu_spec();
        let mut be: Box<dyn Backend> = Box::new(PanicBackend);
        let got = run_supervised(
            &mut be,
            || spec.create(),
            0,
            &quick(3),
            &Tracer::disabled(),
            QUARANTINE_AFTER,
        )
        .unwrap();
        // the requeued attempt ran on the restarted (real) backend to
        // completion, bit-identical to a crash-free run…
        let direct =
            run_config(testkit::refcpu_backend().as_ref(), quick(3)).unwrap();
        assert_eq!(got.fingerprint(), direct.fingerprint());
        // …and the worker keeps the restarted backend afterwards.
        assert_eq!(be.name(), "refcpu");
    }

    #[test]
    fn traced_sweep_merges_worker_events_in_cell_order() {
        let mut sw = ParallelSweeper::new(testkit::refcpu_spec(), 2).unwrap();
        sw.set_tracer(Tracer::enabled(1 << 14));
        let reports = sw.run_many(&[quick(3), quick(4)]).unwrap();
        assert_eq!(reports.len(), 2);
        let evs = sw.tracer().events();
        let claims: Vec<f64> = evs
            .iter()
            .filter(|e| e.name == "cell_claim")
            .map(|e| e.args()[0].1)
            .collect();
        // absorbed in cell order regardless of which worker ran which
        assert_eq!(claims, vec![0.0, 1.0]);
        assert!(evs.iter().any(|e| e.name == "cell" && e.lane == Lane::Sweep));
        // tracing must not perturb the science
        let direct =
            run_config(testkit::refcpu_backend().as_ref(), quick(3)).unwrap();
        assert_eq!(reports[0].fingerprint(), direct.fingerprint());
    }

    #[test]
    fn persistent_panic_quarantines_the_cell() {
        let mut be: Box<dyn Backend> = Box::new(PanicBackend);
        let err = run_supervised(
            &mut be,
            || Ok(Box::new(PanicBackend) as Box<dyn Backend>),
            7,
            &quick(3),
            &Tracer::disabled(),
            QUARANTINE_AFTER,
        )
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("quarantined"), "got: {msg}");
        assert!(msg.contains("sweep cell 7"), "got: {msg}");
    }

    #[test]
    fn quarantine_budget_of_one_skips_the_retry() {
        // restart closure that would hand over a working backend — with a
        // budget of 1 it must never be consulted.
        let spec = testkit::refcpu_spec();
        let mut restarts = 0u32;
        let mut be: Box<dyn Backend> = Box::new(PanicBackend);
        let err = run_supervised(
            &mut be,
            || {
                restarts += 1;
                spec.create()
            },
            2,
            &quick(3),
            &Tracer::disabled(),
            1,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("after 1 panics"), "got: {err}");
        assert_eq!(restarts, 1, "restart happens, but no second attempt");
    }

    #[test]
    fn raised_quarantine_budget_survives_more_panics() {
        // a backend that panics the first two times it is constructed:
        // with the default budget of 2 the cell would quarantine, with 3
        // it completes on the third attempt.
        let spec = testkit::refcpu_spec();
        let mut failures_left = 1u32; // first restart panics too
        let mut be: Box<dyn Backend> = Box::new(PanicBackend);
        let got = run_supervised(
            &mut be,
            || {
                if failures_left > 0 {
                    failures_left -= 1;
                    Ok(Box::new(PanicBackend) as Box<dyn Backend>)
                } else {
                    spec.create()
                }
            },
            0,
            &quick(3),
            &Tracer::disabled(),
            3,
        )
        .unwrap();
        let direct =
            run_config(testkit::refcpu_backend().as_ref(), quick(3)).unwrap();
        assert_eq!(got.fingerprint(), direct.fingerprint());
    }

    #[test]
    fn sweeper_quarantine_after_is_clamped_and_settable() {
        let mut sw = ParallelSweeper::new(testkit::refcpu_spec(), 1).unwrap();
        sw.set_quarantine_after(0);
        assert_eq!(sw.quarantine_after, 1, "clamped to at least one attempt");
        sw.set_quarantine_after(5);
        assert_eq!(sw.quarantine_after, 5);
    }
}
