//! Seed sweeps: the paper reports every number as the average of 5 runs
//! with different random seeds.
//!
//! # Parallel sweep engine
//!
//! [`Runtime`] is deliberately `!Sync` (PJRT executables live behind
//! `Rc`/`RefCell`), so a *single* runtime can't be shared across threads.
//! [`ParallelSweeper`] instead gives each worker thread its **own**
//! runtime over the same artifact directory: workers pull `(index,
//! RunConfig)` jobs from a shared queue and write results into their
//! reserved slot, so the output order — and, because every simulation is
//! seed-deterministic, every byte of every report except wall-clock
//! timings — is identical no matter how many workers run.

use std::sync::Mutex;

use anyhow::Result;

use crate::metrics::{average, Report};
use crate::runtime::Runtime;

use super::run::{RunConfig, Simulation};

/// Run `cfg` under `seeds` sequentially on a borrowed runtime and return
/// (mean report, per-seed reports).  The compatibility entry point —
/// sweeps that should use every core go through [`ParallelSweeper`].
pub fn run_averaged(
    rt: &Runtime,
    cfg: &RunConfig,
    seeds: &[u64],
) -> Result<(Report, Vec<Report>)> {
    anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
    let mut reports = Vec::with_capacity(seeds.len());
    for &s in seeds {
        let c = cfg.clone().with_seed(s);
        reports.push(Simulation::new(rt, c)?.run()?);
    }
    Ok((average(&reports), reports))
}

/// Multi-core sweep engine: owns a runtime for main-thread work and spawns
/// `jobs` scoped worker threads (each constructing its own runtime) for
/// batched runs.
pub struct ParallelSweeper {
    rt: Runtime,
    jobs: usize,
}

impl ParallelSweeper {
    /// Wrap an already-loaded runtime.  `jobs` is clamped to ≥ 1;
    /// `jobs == 1` means fully sequential (no threads spawned).
    pub fn new(rt: Runtime, jobs: usize) -> ParallelSweeper {
        ParallelSweeper { rt, jobs: jobs.max(1) }
    }

    /// Load the runtime from an artifact directory.
    pub fn from_dir<P: AsRef<std::path::Path>>(dir: P, jobs: usize) -> Result<ParallelSweeper> {
        Ok(ParallelSweeper::new(Runtime::load(dir)?, jobs))
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Default worker count for CLI/bench entry points: every core.
    pub fn default_jobs() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get())
    }

    /// The main-thread runtime (single runs, probes, direct simulations).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Run every config, in deterministic input order, across up to
    /// `jobs` worker threads.
    pub fn run_many(&self, cfgs: &[RunConfig]) -> Result<Vec<Report>> {
        let workers = self.jobs.min(cfgs.len());
        if workers <= 1 {
            return cfgs
                .iter()
                .map(|c| Simulation::new(&self.rt, c.clone())?.run())
                .collect();
        }
        let dir = self.rt.artifact_dir().to_path_buf();
        let next = Mutex::new(0usize);
        let slots: Mutex<Vec<Option<Result<Report>>>> =
            Mutex::new((0..cfgs.len()).map(|_| None).collect());
        let failed = Mutex::new(false);
        // worker-initialization failures get their own slot so a job
        // completing concurrently can never overwrite the root cause.
        let init_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    // each worker owns its runtime: `Runtime` is !Sync.
                    let rt = match Runtime::load(&dir) {
                        Ok(rt) => rt,
                        Err(e) => {
                            *failed.lock().unwrap() = true;
                            init_err.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    };
                    loop {
                        let i = {
                            let mut n = next.lock().unwrap();
                            if *n >= cfgs.len() || *failed.lock().unwrap() {
                                break;
                            }
                            let i = *n;
                            *n += 1;
                            i
                        };
                        let res = Simulation::new(&rt, cfgs[i].clone())
                            .and_then(|s| s.run());
                        if res.is_err() {
                            *failed.lock().unwrap() = true;
                        }
                        slots.lock().unwrap()[i] = Some(res);
                    }
                });
            }
        });
        if let Some(e) = init_err.into_inner().unwrap() {
            return Err(e.context("sweep worker failed to load its runtime"));
        }
        let slots = slots.into_inner().unwrap();
        let mut out = Vec::with_capacity(cfgs.len());
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e.context(format!("sweep job {i}"))),
                None => anyhow::bail!("sweep job {i} was aborted by an earlier failure"),
            }
        }
        Ok(out)
    }

    /// Parallel equivalent of [`run_averaged`]: identical mean and
    /// per-seed reports (modulo wall-clock fields) for any worker count.
    pub fn run_averaged(
        &self,
        cfg: &RunConfig,
        seeds: &[u64],
    ) -> Result<(Report, Vec<Report>)> {
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        let cfgs: Vec<RunConfig> =
            seeds.iter().map(|&s| cfg.clone().with_seed(s)).collect();
        let reports = self.run_many(&cfgs)?;
        Ok((average(&reports), reports))
    }

    /// Seed-average many configs in one flat parallel batch (the whole
    /// table grid keeps every core busy instead of one cell at a time).
    /// Returns one mean report per input config, in input order.
    pub fn run_averaged_many(
        &self,
        cfgs: &[RunConfig],
        seeds: &[u64],
    ) -> Result<Vec<Report>> {
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        let jobs: Vec<RunConfig> = cfgs
            .iter()
            .flat_map(|c| seeds.iter().map(|&s| c.clone().with_seed(s)))
            .collect();
        let reports = self.run_many(&jobs)?;
        Ok(reports
            .chunks(seeds.len())
            .map(average)
            .collect())
    }
}
