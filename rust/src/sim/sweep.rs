//! Seed sweeps: the paper reports every number as the average of 5 runs
//! with different random seeds.

use anyhow::Result;

use crate::metrics::{average, Report};
use crate::runtime::Runtime;

use super::run::{RunConfig, Simulation};

/// Run `cfg` under `seeds` and return (mean report, per-seed reports).
pub fn run_averaged(
    rt: &Runtime,
    cfg: &RunConfig,
    seeds: &[u64],
) -> Result<(Report, Vec<Report>)> {
    anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
    let mut reports = Vec::with_capacity(seeds.len());
    for &s in seeds {
        let c = cfg.clone().with_seed(s);
        reports.push(Simulation::new(rt, c)?.run()?);
    }
    Ok((average(&reports), reports))
}
