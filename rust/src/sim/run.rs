//! One continual-learning run (the runtime behind every table and figure).
//!
//! Mirrors the paper's Fig. 1 timeline: training batches and inference
//! requests arrive over virtual time; the coordinator buffers batches,
//! triggers fine-tuning rounds per the inter-tuning policy, freezes layers
//! per the intra-tuning policy, detects scenario changes from inference
//! energy scores, and maintains CWR head consolidation across scenarios.
//!
//! # Request path
//!
//! All inference requests route through the serving control plane
//! ([`crate::serve::ServeEngine`]): requests are drawn at arrival (so the
//! world RNG stream stays in event order) and handed to
//! `ServeEngine::on_arrival`, which admits or sheds them
//! (`--max-queue`/`--shed-infeasible`); the simulation then *polls* the
//! engine at every virtual-time step and absorbs the resulting
//! [`ServeEvent`]s — served requests (accuracy + energy score, in service
//! order, feeding the scenario-change detector), drops, executes, and
//! bank installs.  Queue order is the `--queue-policy` (FIFO or EDF
//! across scenarios); batches may mix scenarios because the engine keeps
//! one resident bank-installed serving θ per active scenario
//! ([`crate::serve::BankSet`]), invalidated by generation counters
//! ([`Params::generation`] moves on every train step / head surgery,
//! [`Cwr::generation`] on every consolidation) — a request whose inputs
//! did not change performs **zero full-θ copies** and — via the session's
//! literal cache (see [`crate::model::ModelSession`]) — no θ re-marshal.
//! With the default configuration (FIFO, no shedding,
//! `serve.batch_window_s == 0`) every batch degenerates to one full-draw
//! request and reports are bit-identical to the pre-control-plane path.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::baselines;
use crate::bitset::BitSet;
use crate::coordinator::policy::{
    FreezePolicy, FreezePolicyKind, NoFreeze, SimFreezePolicy, TunePolicy,
    TunePolicyKind,
};
use crate::coordinator::lazytune::{DecayKind, LazyTune, DEFAULT_CAP};
use crate::coordinator::simfreeze::SimFreeze;
use crate::coordinator::EnergyOod;
use crate::cost::device::DeviceModel;
use crate::cost::energy::CostBook;
use crate::cost::flops;
use crate::data::arrival::ArrivalKind;
use crate::data::benchmarks::{self, Benchmark, Schedule};
use crate::data::stream::{EventKind, Stream};
use crate::metrics::{Report, RequestRecord, RoundRecord};
use crate::model::{Cwr, ModelSession, Params};
use crate::rng::Pcg32;
use crate::runtime::{faults, Backend, FaultPlan, FaultyBackend, TracingBackend};
use crate::serve::{
    Fleet, FleetConfig, QueuedRequest, RoundDecision, ServeConfig, ServeCtx,
    ServeEvent,
};
use crate::trace::{Lane, Tracer};

use super::valpool::ValPool;

/// Everything configurable about one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub benchmark: Benchmark,
    pub tune: TunePolicyKind,
    pub freeze: FreezePolicyKind,
    pub seed: u64,
    pub n_requests: usize,
    pub train_arrival: ArrivalKind,
    pub infer_arrival: ArrivalKind,
    /// SimFreeze stability threshold (paper default 1%).
    pub cka_th: f64,
    /// SimFreeze probe interval in training iterations.
    pub freeze_interval: u64,
    /// Use the 8-bit QAT artifacts (Table VIII; res50 only).
    pub quant: bool,
    /// `Some(frac)`: semi-supervised mode with `frac` of batches labeled.
    pub labeled_fraction: Option<f32>,
    pub lr: f32,
    /// RigL sparsity when `freeze == RigL`.
    pub rigl_sparsity: f32,
    pub device: DeviceModel,
    /// Keep the per-layer CKA trace (Fig. 5) — costs memory.
    pub keep_cka_trace: bool,
    /// LazyTune's request-pressure decay function (ablation: §IV-A2).
    pub decay: DecayKind,
    /// Use the event stream's true scenario boundaries instead of the
    /// energy-score detector (oracle ablation).
    pub oracle_change_detection: bool,
    /// Debug/regression knob: rebuild the serving θ on every request (the
    /// seed behaviour).  Reports must be bit-identical either way.
    pub disable_serving_cache: bool,
    /// Serving-engine knobs (batching window, SLO, scheduler thresholds).
    pub serve: ServeConfig,
    /// Fleet knobs (`--fleet N`, `--no-affinity`,
    /// `--rebalance-threshold`).  The default fleet of one routes every
    /// request to engine 0 and is bit-identical to the engine-only
    /// control plane (pinned by `tests/fleet.rs`).
    pub fleet: FleetConfig,
    /// `--no-batching`: every request draws a full batch, so each one
    /// fills and flushes its own execute at the arrival instant — the
    /// pre-engine behaviour.  Reports must be bit-identical to
    /// `serve.batch_window_s == 0`.
    pub serve_direct: bool,
    /// Deterministic fault injection (`--faults`/`--fault-seed`; see
    /// [`crate::runtime::faults`]).  [`FaultPlan::none()`] — the default —
    /// is a true passthrough: [`run_config`] constructs no decorator and
    /// reports stay bit-identical to a build without the fault layer.
    pub faults: FaultPlan,
    /// Crash-durable checkpointing (`--checkpoint-dir` /
    /// `--checkpoint-every` / `--resume`; see [`crate::ckpt`]).  The
    /// default (`dir: None`) constructs nothing: the run takes the exact
    /// pre-checkpoint path and reports stay bit-identical.
    pub checkpoint: crate::ckpt::CheckpointConfig,
    /// Open-loop workload (`--workload`/`--offered-rps`/`--mix`; see
    /// [`crate::load`]).  `Some` replaces the closed `n_requests`
    /// inference stream with generator-emitted arrivals at a configured
    /// offered rate; `None` — the default — generates the exact
    /// pre-load-layer stream (the closed stream's RNG draws nothing for
    /// an empty request set, so reports stay byte-identical).
    pub workload: Option<crate::load::WorkloadSpec>,
}

impl RunConfig {
    pub fn quickstart(model: &str, benchmark: Benchmark) -> RunConfig {
        RunConfig {
            model: model.to_string(),
            benchmark,
            tune: TunePolicyKind::LazyTune,
            freeze: FreezePolicyKind::SimFreeze,
            seed: 1,
            n_requests: 500,
            train_arrival: ArrivalKind::Poisson,
            infer_arrival: ArrivalKind::Poisson,
            cka_th: 0.01,
            freeze_interval: 8,
            quant: false,
            labeled_fraction: None,
            lr: 0.05,
            rigl_sparsity: 0.8,
            device: DeviceModel::jetson_nx_15w(),
            keep_cka_trace: false,
            decay: DecayKind::Logarithmic,
            oracle_change_detection: false,
            disable_serving_cache: false,
            serve: ServeConfig::default(),
            fleet: FleetConfig::default(),
            serve_direct: false,
            faults: faults::env_plan(),
            checkpoint: crate::ckpt::CheckpointConfig::default(),
            workload: None,
        }
    }

    pub fn with_policies(mut self, tune: TunePolicyKind, freeze: FreezePolicyKind) -> Self {
        self.tune = tune;
        self.freeze = freeze;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// The `run` event-loop locals a checkpoint record carries: everything
/// the loop owns on its stack at a round boundary (the training buffer
/// is deliberately absent — the round that just finished drained it).
struct ResumeLocals {
    events_done: usize,
    trained_classes: BitSet,
    reinit_done: Vec<bool>,
    probe_pending: bool,
    total_iters: u64,
    first_round: bool,
    last_train_scenario: Option<usize>,
}

/// Ready-to-run simulation state.
pub struct Simulation<'b> {
    cfg: RunConfig,
    sess: ModelSession<'b>,
    schedule: Schedule,
    stream: Stream,
    params: Params,
    phi: Vec<f32>,
    cwr: Cwr,
    tune: TunePolicy,
    freeze: Box<dyn FreezePolicy>,
    ood: EnergyOod,
    book: CostBook,
    rng: Pcg32,
    val_pool: ValPool,
    val_x: Vec<f32>,
    val_y: Vec<i32>,
    fleet: Fleet,
    aug_a: Vec<f32>,
    aug_b: Vec<f32>,
    last_energy_score: Option<f64>,
    /// Fine-tuning rounds whose θ was rolled back to the last good
    /// generation after a mid-round fault (tentpole: a failed round must
    /// not poison session caches with a half-updated θ).
    round_rollbacks: u64,
    /// Crash-durable checkpoint writer (`--checkpoint-dir`; `None` — the
    /// default — writes nothing and costs nothing).
    ckpt_writer: Option<crate::ckpt::CheckpointWriter>,
    /// Crash-point evaluator, consulted at every round boundary.
    crash: crate::ckpt::CrashState,
    /// Loop state restored by [`Simulation::resume_from`], consumed at
    /// the top of [`Simulation::run`].
    resume: Option<ResumeLocals>,
    report: Report,
    /// Virtual-time event recorder (disabled by default — see
    /// [`crate::trace`]); shared with the serving engine via
    /// [`Simulation::set_tracer`].
    tracer: Tracer,
}

const VAL_KEEP: usize = 64; // rolling validation window (≈5% of stream)

impl<'b> Simulation<'b> {
    pub fn new(be: &'b dyn Backend, cfg: RunConfig) -> Result<Simulation<'b>> {
        let mut sess = ModelSession::new(be, &cfg.model)?;
        sess.quant = cfg.quant;
        sess.lr = cfg.lr;
        let mut schedule = benchmarks::build(cfg.benchmark, cfg.seed);
        // open-loop workloads replace the closed inference stream: the
        // closed generator draws nothing for n == 0, so the `None` path
        // is byte-identical to every pre-load-layer run.
        let mut stream = Stream::generate(
            cfg.benchmark,
            if cfg.workload.is_some() { 0 } else { cfg.n_requests },
            cfg.train_arrival,
            cfg.infer_arrival,
            cfg.seed,
        );
        if let Some(w) = &cfg.workload {
            w.inject(&mut stream, cfg.benchmark.scenario_count(), cfg.seed);
        }
        let rng = Pcg32::new(cfg.seed ^ 0xE7E7, 5);

        // --- pre-deployment: "originally well-trained on scenario 1" ----
        let mut params = sess.theta0()?;
        let warm_fs = flops::FreezeState::none(sess.m.units);
        let warm_classes = schedule.scenarios[0].classes.clone();
        for _ in 0..cfg.benchmark.warmup_batches() {
            let (x, y) =
                schedule.world.batch(sess.m.batch_train, 0, &warm_classes);
            if let Err(e) = sess.train_step(&mut params, &x, &y, &warm_fs) {
                // under injected faults a lost warmup batch is survivable
                // (pre-deployment training is best-effort); without a
                // fault plan it is a real error.
                if !cfg.faults.enabled() {
                    return Err(e);
                }
            }
        }
        let mut cwr = Cwr::new(&sess.m);
        cwr.consolidate(&sess.m, &params, &warm_classes);

        let phi = if cfg.labeled_fraction.is_some() {
            be.phi0(&cfg.model)?
        } else {
            vec![]
        };

        // --- policies ----------------------------------------------------
        let tune = match cfg.tune {
            TunePolicyKind::LazyTune => TunePolicy::Lazy(
                LazyTune::with_decay(DEFAULT_CAP, cfg.decay),
            ),
            other => other.build(),
        };
        let freeze: Box<dyn FreezePolicy> = match cfg.freeze {
            FreezePolicyKind::None => Box::new(NoFreeze::new(sess.m.units)),
            FreezePolicyKind::SimFreeze => {
                let mut sf = SimFreeze::new(
                    sess.m.units,
                    params.theta().to_vec(),
                    cfg.freeze_interval,
                    cfg.cka_th,
                );
                sf.keep_trace = cfg.keep_cka_trace;
                Box::new(SimFreezePolicy::new(sf))
            }
            FreezePolicyKind::Egeria => Box::new(baselines::egeria::Egeria::new(
                &sess.m,
                params.theta().to_vec(),
                cfg.freeze_interval,
            )),
            FreezePolicyKind::SlimFit => Box::new(
                baselines::slimfit::SlimFit::new(&sess.m, cfg.freeze_interval),
            ),
            FreezePolicyKind::RigL => Box::new(baselines::rigl::RigL::new(
                &sess.m,
                cfg.rigl_sparsity,
                cfg.seed,
            )),
            FreezePolicyKind::Ekya => {
                Box::new(baselines::ekya::Ekya::new(&sess.m))
            }
        };

        let book = CostBook::new(cfg.device.clone());
        let mut report = Report::default();
        report.model = cfg.model.clone();
        report.benchmark = cfg.benchmark.name().to_string();
        report.tune_policy = cfg.tune.name();
        report.freeze_policy = cfg.freeze.name().to_string();
        report.seed = cfg.seed;
        // open-loop observability: the realized interarrival distribution
        // of the injected workload (fingerprint-excluded like every other
        // histogram; absent entirely on the default closed stream).
        if cfg.workload.is_some() {
            let mut last = None;
            for e in stream
                .events
                .iter()
                .filter(|e| e.kind == EventKind::Inference)
            {
                if let Some(prev) = last {
                    report.hists.record("load/interarrival_s", e.t - prev);
                }
                last = Some(e.t);
            }
        }

        let val_pool = ValPool::new(sess.m.d, VAL_KEEP);
        let fleet = Fleet::new(
            &sess.m,
            &cfg.device,
            &cfg.serve,
            cfg.serve_direct,
            cfg.disable_serving_cache,
            &cfg.fleet,
        );
        let ckpt_writer = match &cfg.checkpoint.dir {
            Some(dir) => Some(crate::ckpt::CheckpointWriter::new(
                dir,
                cfg.checkpoint.every,
                &cfg.faults,
            )?),
            None => None,
        };
        let crash = crate::ckpt::CrashState::new(&cfg.faults, cfg.seed);
        Ok(Simulation {
            cfg,
            sess,
            schedule,
            stream,
            params,
            phi,
            cwr,
            tune,
            freeze,
            ood: EnergyOod::new(),
            book,
            rng,
            val_pool,
            val_x: Vec::new(),
            val_y: Vec::new(),
            fleet,
            aug_a: Vec::new(),
            aug_b: Vec::new(),
            last_energy_score: None,
            round_rollbacks: 0,
            ckpt_writer,
            crash,
            resume: None,
            report,
            tracer: Tracer::disabled(),
        })
    }

    /// Attach a tracer; every serving engine in the fleet shares the same
    /// buffer, so the full timeline (engines + rounds + backend boundary)
    /// interleaves in one ring.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.fleet.set_tracer(tracer.clone());
        self.tracer = tracer;
    }

    /// Restore the run from the newest valid checkpoint record in `dir`
    /// (`--resume`; see [`crate::ckpt::recover`]).  Call between
    /// [`Simulation::new`] — which rebuilt the identical warmed-up
    /// pre-deployment state from the config — and [`Simulation::run`],
    /// which then skips the already-processed events and continues
    /// bit-identically to the uncrashed run.  The simulation must be
    /// built from the *same* scientific config, validated via
    /// [`crate::ckpt::config_digest`].
    pub fn resume_from(&mut self, dir: &Path) -> Result<()> {
        let rec = crate::ckpt::recover(dir)?;
        let mut r = crate::ckpt::ByteReader::new(&rec.payload);
        let digest = r.u64()?;
        let want = crate::ckpt::config_digest(&self.cfg);
        anyhow::ensure!(
            digest == want,
            "checkpoint config digest {digest:#018x} does not match this \
             run's {want:#018x}: --resume must repeat the original flags"
        );
        let events_done = r.usize()?;
        let theta = r.f32s()?;
        let id = r.u64()?;
        let generation = r.u64()?;
        anyhow::ensure!(
            theta.len() == self.sess.m.theta_len,
            "checkpoint theta length {} != manifest {}",
            theta.len(),
            self.sess.m.theta_len
        );
        self.params = Params::restore(theta, id, generation);
        self.phi = r.f32s()?;
        let n = r.usize()?;
        let mut bank = Vec::with_capacity(n);
        for _ in 0..n {
            bank.push(r.f32s()?);
        }
        let seen = r.u32s()?;
        let gen = r.u64()?;
        self.cwr = Cwr::restore(bank, seen, gen);
        self.tune.ckpt_load(&mut r)?;
        self.freeze.ckpt_load(&mut r, &self.sess)?;
        self.ood.ckpt_load(&mut r)?;
        self.book.ckpt_load(&mut r)?;
        let (s, i) = (r.u64()?, r.u64()?);
        self.rng = Pcg32::from_state(s, i);
        let (s, i) = (r.u64()?, r.u64()?);
        self.schedule.world.set_sampler_state(s, i);
        let d = r.usize()?;
        let cap = r.usize()?;
        let x = r.f32s()?;
        let y = r.i32s()?;
        let head = r.usize()?;
        let len = r.usize()?;
        self.val_pool = ValPool::restore(d, cap, x, y, head, len);
        self.fleet.ckpt_load(
            &mut r,
            &ServeCtx {
                sess: &self.sess,
                params: &self.params,
                cwr: &self.cwr,
                scenarios: &self.schedule.scenarios,
            },
        )?;
        self.last_energy_score = r.opt_f64()?;
        self.round_rollbacks = r.u64()?;
        let cap_bits = r.usize()?;
        anyhow::ensure!(
            cap_bits == self.sess.m.classes,
            "checkpoint class count {cap_bits} != manifest {}",
            self.sess.m.classes
        );
        let mut trained_classes = BitSet::new(cap_bits);
        for id in r.usizes()? {
            trained_classes.insert(id);
        }
        let reinit_done = r.bools()?;
        let probe_pending = r.bool()?;
        let total_iters = r.u64()?;
        let first_round = r.bool()?;
        let last_train_scenario = r.opt_usize()?;
        if r.bool()? {
            let blob = r.bytes()?;
            self.sess.be.fault_state_load(&blob);
        }
        self.crash.load(&mut r)?;
        self.report = crate::ckpt::report_load(&mut r)?;
        r.expect_end()?;
        self.report.checkpoint_restores += 1;
        self.report.checkpoint_fallbacks += rec.fallbacks;
        // continue the write tally where the crashed process left it, so
        // the resumed report counts the whole timeline's records.
        if let Some(w) = self.ckpt_writer.as_mut() {
            w.written = self.report.checkpoints_written;
            w.bytes = self.report.checkpoint_bytes;
        }
        self.resume = Some(ResumeLocals {
            events_done,
            trained_classes,
            reinit_done,
            probe_pending,
            total_iters,
            first_round,
            last_train_scenario,
        });
        Ok(())
    }

    /// Serialize the full mutable state at a round boundary — a quiesce
    /// point: the round drained the training buffer, the serve queues
    /// were drained before it proceeded, and no stream event is half
    /// processed.  Records are self-contained; recovery applies exactly
    /// one.  Layout mirrors [`Simulation::resume_from`] field for field.
    #[allow(clippy::too_many_arguments)]
    fn ckpt_payload(
        &self,
        events_done: usize,
        trained_classes: &BitSet,
        reinit_done: &[bool],
        probe_pending: bool,
        total_iters: u64,
        first_round: bool,
        last_train_scenario: Option<usize>,
    ) -> Vec<u8> {
        let mut w = crate::ckpt::ByteWriter::new();
        w.u64(crate::ckpt::config_digest(&self.cfg));
        w.usize(events_done);
        w.f32s(self.params.theta());
        w.u64(self.params.id());
        w.u64(self.params.generation());
        w.f32s(&self.phi);
        let (bank, seen, gen) = self.cwr.ckpt_state();
        w.usize(bank.len());
        for row in bank {
            w.f32s(row);
        }
        w.u32s(seen);
        w.u64(gen);
        self.tune.ckpt_save(&mut w);
        self.freeze.ckpt_save(&mut w);
        self.ood.ckpt_save(&mut w);
        self.book.ckpt_save(&mut w);
        let (s, i) = self.rng.state();
        w.u64(s);
        w.u64(i);
        let (s, i) = self.schedule.world.sampler_state();
        w.u64(s);
        w.u64(i);
        let (d, cap, x, y, head, len) = self.val_pool.ckpt_state();
        w.usize(d);
        w.usize(cap);
        w.f32s(x);
        w.i32s(y);
        w.usize(head);
        w.usize(len);
        self.fleet.ckpt_save(&mut w);
        w.opt_f64(self.last_energy_score);
        w.u64(self.round_rollbacks);
        w.usize(trained_classes.capacity());
        let ids: Vec<usize> = trained_classes.iter().collect();
        w.usizes(&ids);
        w.bools(reinit_done);
        w.bool(probe_pending);
        w.u64(total_iters);
        w.bool(first_round);
        w.opt_usize(last_train_scenario);
        match self.sess.be.fault_state_save() {
            Some(blob) => {
                w.bool(true);
                w.bytes(&blob);
            }
            None => w.bool(false),
        }
        self.crash.save(&mut w);
        crate::ckpt::report_save(&self.report, &mut w);
        w.into_vec()
    }

    /// One fine-tuning round boundary: evaluate the crash points, persist
    /// the state, and only *then* surface an injected crash — the record
    /// carries the post-draw crash latches, so `--resume` continues past
    /// the boundary without re-firing.  A no-op (not even a branch into
    /// serialization) when neither checkpointing nor crash points are
    /// configured.
    #[allow(clippy::too_many_arguments)]
    fn on_round_boundary(
        &mut self,
        t: f64,
        events_done: usize,
        trained_classes: &BitSet,
        reinit_done: &[bool],
        probe_pending: bool,
        total_iters: u64,
        first_round: bool,
        last_train_scenario: Option<usize>,
    ) -> Result<()> {
        if self.ckpt_writer.is_none() && !self.crash.enabled() {
            return Ok(());
        }
        debug_assert_eq!(
            self.fleet.queue_depth(),
            0,
            "round boundary must be quiesced"
        );
        let round = self.book.rounds;
        let fired = self.crash.check(round, t);
        if self.ckpt_writer.is_some() {
            let payload = self.ckpt_payload(
                events_done,
                trained_classes,
                reinit_done,
                probe_pending,
                total_iters,
                first_round,
                last_train_scenario,
            );
            let w = self.ckpt_writer.as_mut().unwrap();
            w.on_boundary(round, t, &payload)?;
            self.report.checkpoints_written = w.written;
            self.report.checkpoint_bytes = w.bytes;
        }
        if fired {
            return Err(crate::ckpt::CrashInjected { round, t }.into());
        }
        Ok(())
    }

    /// Run the whole event stream; consumes the simulation.
    pub fn run(mut self) -> Result<Report> {
        let wall = Instant::now();
        // backends are reused across runs (one per sweep worker), so the
        // execution-core counters are cumulative per backend — report the
        // per-run delta, like the per-session marshal counters.
        let perf0 = self.sess.be.perf();
        let faults0 = self.sess.be.fault_stats();
        // latency spikes injected during pre-deployment warmup happened
        // before virtual time starts — discard, don't charge.
        let _ = self.sess.be.take_injected_delay_s();
        let mut buffer: Vec<(Vec<f32>, Vec<i32>, usize)> = Vec::new();
        let mut trained_classes = BitSet::new(self.sess.m.classes);
        let mut reinit_done: Vec<bool> = vec![false; self.sess.m.classes];
        let mut probe_pending = true;
        let mut total_iters: u64 = 0;
        let mut first_round = true;
        let mut last_train_scenario: Option<usize> = None;
        // resume: `new` rebuilt the identical warmed-up pre-deployment
        // state from the config (warmup is deterministic), `resume_from`
        // overwrote the evolving state and parked the loop locals here.
        // Already-processed events are skipped, not replayed.
        let mut events_done: usize = 0;
        if let Some(rl) = self.resume.take() {
            events_done = rl.events_done;
            trained_classes = rl.trained_classes;
            reinit_done = rl.reinit_done;
            probe_pending = rl.probe_pending;
            total_iters = rl.total_iters;
            first_round = rl.first_round;
            last_train_scenario = rl.last_train_scenario;
        }

        let events = std::mem::take(&mut self.stream.events);
        for (idx, ev) in events.iter().enumerate() {
            if idx < events_done {
                continue;
            }
            // poll the control plane up to this event's time: serves any
            // batch whose coalescing window expired (keeps service order
            // aligned with virtual time) and surfaces pending drops.
            let served = self.poll_engine(ev.t)?;
            if !served.is_empty() {
                self.absorb_events(
                    served,
                    &mut trained_classes,
                    &mut reinit_done,
                    &mut probe_pending,
                )?;
            }
            match ev.kind {
                EventKind::TrainBatch => {
                    // oracle ablation: take scenario boundaries from the
                    // stream instead of the energy-score detector.
                    if self.cfg.oracle_change_detection
                        && last_train_scenario
                            .is_some_and(|s| s != ev.scenario)
                    {
                        self.report.scenario_changes_detected += 1;
                        self.tune.on_scenario_change();
                        self.cwr.consolidate_set(
                            &self.sess.m,
                            &self.params,
                            &trained_classes,
                        );
                        trained_classes.clear();
                        reinit_done.iter_mut().for_each(|r| *r = false);
                        probe_pending = true;
                    }
                    last_train_scenario = Some(ev.scenario);
                    let scen = &self.schedule.scenarios[ev.scenario];
                    let classes = scen.classes.clone();
                    let (x, y) = self.schedule.world.batch(
                        self.sess.m.batch_train,
                        ev.scenario,
                        &classes,
                    );
                    // 5%-ish validation split: 1 of every 16 samples.
                    if self.rng.f32() < 0.05 * 16.0 / 16.0 {
                        self.push_val(&x, &y);
                    }
                    if probe_pending {
                        match self.freeze.on_scenario_probe(
                            &self.sess,
                            &self.params,
                            &x,
                            &mut self.book,
                        ) {
                            Ok(()) => probe_pending = false,
                            // a faulted probe stays pending and retries on
                            // the next batch; without a fault plan the
                            // error is real.
                            Err(e) if !self.cfg.faults.enabled() => {
                                return Err(e)
                            }
                            Err(_) => {}
                        }
                    }
                    // CWR: first exposure of a class since the last change
                    // reinitializes its training row.
                    let fresh: Vec<usize> = y
                        .iter()
                        .map(|&c| c as usize)
                        .filter(|&c| !reinit_done[c])
                        .collect();
                    if !fresh.is_empty() {
                        for &c in &fresh {
                            reinit_done[c] = true;
                        }
                        // only classes never consolidated start from zero —
                        // re-exposed classes keep their bank discriminator.
                        let unseen: Vec<usize> = fresh
                            .iter()
                            .copied()
                            .filter(|&c| !self.cwr.seen(c))
                            .collect();
                        self.cwr.reinit_rows(&self.sess.m, &mut self.params, &unseen);
                    }
                    buffer.push((x, y, ev.scenario));

                    if self.tune.should_trigger(buffer.len()) {
                        // tune-vs-serve arbitration: under deep serving
                        // backlog the scheduler defers the round (bounded
                        // by its starvation cap) and feeds LazyTune the
                        // real queue depth.
                        let backlog = self.fleet.queue_depth();
                        self.tracer.instant(
                            Lane::Rounds,
                            "round_trigger",
                            ev.t,
                            &[("backlog", backlog as f64)],
                        );
                        match self.fleet.scheduler_mut().consider_round(backlog) {
                            RoundDecision::Defer => {
                                self.tracer.instant(
                                    Lane::Rounds,
                                    "round_defer",
                                    ev.t,
                                    &[("backlog", backlog as f64)],
                                );
                                self.tune.on_queue_depth(backlog);
                            }
                            RoundDecision::Proceed => {
                                // pending requests were admitted before the
                                // round: serve them first, then occupy the
                                // device for the round's ledger time.
                                let served = self.drain_engine(ev.t)?;
                                if !served.is_empty() {
                                    self.absorb_events(
                                        served,
                                        &mut trained_classes,
                                        &mut reinit_done,
                                        &mut probe_pending,
                                    )?;
                                }
                                let ledger_s = self.book.breakdown.total_s();
                                let wh0 = self.book.breakdown.total_wh();
                                let batches = buffer.len();
                                self.tracer.set_now(ev.t);
                                self.tracer.begin(Lane::Rounds, "round", ev.t);
                                self.run_round(
                                    ev.t,
                                    ev.scenario,
                                    &mut buffer,
                                    &mut trained_classes,
                                    &mut total_iters,
                                    &mut first_round,
                                )?;
                                // injected latency spikes during training
                                // steps extend the round in virtual time.
                                let round_s = self.book.breakdown.total_s()
                                    - ledger_s
                                    + self.sess.be.take_injected_delay_s();
                                self.tracer.end(
                                    Lane::Rounds,
                                    ev.t + round_s,
                                    &[
                                        ("batches", batches as f64),
                                        (
                                            "energy_wh",
                                            self.book.breakdown.total_wh() - wh0,
                                        ),
                                        (
                                            "theta_gen",
                                            self.params.generation() as f64,
                                        ),
                                    ],
                                );
                                self.report
                                    .hists
                                    .record("tune/round_s", round_s);
                                self.report
                                    .hists
                                    .record("tune/round_batches", batches as f64);
                                self.fleet
                                    .scheduler_mut()
                                    .on_round(ev.t, round_s);
                                self.on_round_boundary(
                                    ev.t,
                                    idx + 1,
                                    &trained_classes,
                                    &reinit_done,
                                    probe_pending,
                                    total_iters,
                                    first_round,
                                    last_train_scenario,
                                )?;
                            }
                        }
                    }
                }
                EventKind::Inference => {
                    // draw the request's test rows at arrival (world RNG
                    // stays in event order — even for requests the
                    // control plane sheds) and hand it to admission,
                    // then poll so capacity/window-0 flushes serve at
                    // the arrival instant exactly like the seed did.
                    let rows = self.fleet.rows_per_request();
                    let (x, y) = self.schedule.world.batch(
                        rows,
                        ev.scenario,
                        &self.schedule.scenarios[ev.scenario].seen,
                    );
                    let req = QueuedRequest {
                        arrival_t: ev.t,
                        deadline_t: self.fleet.deadline(ev.t),
                        scenario: ev.scenario,
                        stale_batches: buffer.len(),
                        x,
                        y,
                        rows,
                    };
                    self.fleet.on_arrival(req);
                    let served = self.poll_engine(ev.t)?;
                    self.tune.on_inference();
                    self.absorb_events(
                        served,
                        &mut trained_classes,
                        &mut reinit_done,
                        &mut probe_pending,
                    )?;
                }
            }
        }
        // serve everything still queued at the end of the stream: batches
        // already past their window flush at their due time, the rest at
        // the horizon.
        let mut served = self.poll_engine(self.stream.horizon)?;
        served.extend(self.drain_engine(self.stream.horizon)?);
        if !served.is_empty() {
            self.absorb_events(
                served,
                &mut trained_classes,
                &mut reinit_done,
                &mut probe_pending,
            )?;
        }
        // flush any remaining buffered data as a final round
        if !buffer.is_empty() {
            let t = self.stream.horizon;
            let scen = buffer.last().unwrap().2;
            let ledger_s = self.book.breakdown.total_s();
            let wh0 = self.book.breakdown.total_wh();
            let batches = buffer.len();
            self.tracer.set_now(t);
            self.tracer.begin(Lane::Rounds, "round", t);
            self.run_round(
                t,
                scen,
                &mut buffer,
                &mut trained_classes,
                &mut total_iters,
                &mut first_round,
            )?;
            let round_s = self.book.breakdown.total_s() - ledger_s
                + self.sess.be.take_injected_delay_s();
            self.tracer.end(
                Lane::Rounds,
                t + round_s,
                &[
                    ("batches", batches as f64),
                    ("energy_wh", self.book.breakdown.total_wh() - wh0),
                    ("theta_gen", self.params.generation() as f64),
                ],
            );
            self.report.hists.record("tune/round_s", round_s);
            self.report.hists.record("tune/round_batches", batches as f64);
            // charge the horizon round to the occupancy ledger too, so
            // time-in-state covers every round (nothing serves after it,
            // so the device-busy horizon move is inert).
            self.fleet.scheduler_mut().on_round(t, round_s);
            self.on_round_boundary(
                t,
                events.len(),
                &trained_classes,
                &reinit_done,
                probe_pending,
                total_iters,
                first_round,
                last_train_scenario,
            )?;
        }
        self.cwr
            .consolidate_set(&self.sess.m, &self.params, &trained_classes);

        self.report.memory_end_bytes = flops::train_memory_bytes(
            &self.sess.m,
            self.freeze.state(),
            self.sess.m.batch_train,
        );
        self.report.cka_trace = self.freeze.cka_trace();
        self.report.energy = self.book.breakdown;
        self.report.rounds = self.book.rounds;
        self.report.train_iterations = self.book.train_iterations;
        self.report.train_tflops = self.book.train_flops / 1e12;
        self.report.cka_tflops = self.book.cka_flops / 1e12;
        self.report.wall_exec_s = wall.elapsed().as_secs_f64();
        self.report.theta_marshals = self.sess.theta_marshal_count();
        self.report.theta_cache_hits = self.sess.theta_cache_hit_count();
        self.report.serving_rebuilds = self.fleet.serving_rebuilds();
        self.report.serving_hits = self.fleet.serving_hits();
        let perf = self.sess.be.perf();
        self.report.gemm_packs = perf.gemm_packs - perf0.gemm_packs;
        self.report.gemm_pack_hits = perf.gemm_pack_hits - perf0.gemm_pack_hits;
        self.report.scratch_allocs = perf.scratch_allocs - perf0.scratch_allocs;
        self.report.scratch_reuses = perf.scratch_reuses - perf0.scratch_reuses;
        self.report.scratch_bytes_reused =
            perf.scratch_bytes_reused - perf0.scratch_bytes_reused;
        let lat = self.fleet.latency_summary();
        self.report.latency_p50_ms = lat.p50_ms;
        self.report.latency_p95_ms = lat.p95_ms;
        self.report.latency_p99_ms = lat.p99_ms;
        self.report.latency_mean_ms = lat.mean_ms;
        self.report.latency_max_ms = lat.max_ms;
        self.report.slo_ms = self.cfg.serve.slo_ms;
        self.report.slo_violations = lat.violations;
        self.report.serve_executes = self.fleet.executes();
        self.report.avg_batch_requests = self.fleet.avg_batch_requests();
        self.report.peak_queue_depth = self.fleet.peak_queue_depth() as u64;
        self.report.rounds_deferred = self.fleet.rounds_deferred();
        self.report.queue_policy = self.fleet.queue_policy_name().to_string();
        self.report.requests_dropped = self.fleet.requests_dropped();
        self.report.drops_queue_full = self.fleet.drops_queue_full();
        self.report.drops_slo_infeasible = self.fleet.drops_slo_infeasible();
        self.report.deadline_misses = self.fleet.deadline_misses();
        self.report.bank_evictions = self.fleet.bank_evictions();
        self.report.banks_peak_resident = self.fleet.banks_peak_resident() as u64;
        self.report.per_scenario_latency = self.fleet.per_scenario_latency();
        // fault / recovery counters (fingerprint-excluded observability).
        let fstats = self.sess.be.fault_stats();
        self.report.faults_injected_exec =
            fstats.exec_faults - faults0.exec_faults;
        self.report.faults_injected_marshal =
            fstats.marshal_faults - faults0.marshal_faults;
        self.report.faults_injected_spikes =
            fstats.latency_spikes - faults0.latency_spikes;
        self.report.fault_delay_injected_s =
            fstats.spike_s_total - faults0.spike_s_total;
        self.report.serve_retries = self.fleet.serve_retries();
        self.report.serve_flush_failures = self.fleet.flush_failures();
        self.report.breaker_trips = self.fleet.breaker_trips();
        self.report.degraded_serves = self.fleet.degraded_serves();
        self.report.drops_backend_unavailable =
            self.fleet.drops_backend_unavailable();
        self.report.round_rollbacks = self.round_rollbacks;
        // fleet routing accounting (fingerprint-excluded; all zero for a
        // fleet of one except the trivially-affine route counter).
        let rc = self.fleet.router_counters();
        self.report.fleet_engines = self.fleet.n() as u64;
        self.report.fleet_routed_affinity = rc.routed_by_affinity;
        self.report.fleet_routed_least_loaded = rc.routed_least_loaded;
        self.report.fleet_cross_engine_retries = rc.cross_engine_retries;
        self.report.fleet_rebalances = rc.rebalances;
        // time-in-state (fingerprint-excluded): how the virtual horizon
        // split between serving executes, fine-tuning rounds, and idle.
        // With a fleet the budget is N device-horizons: serving sums over
        // engines, tuning stays on the primary, idle absorbs the rest.
        self.report.time_serving_s = self.fleet.serve_busy_s();
        self.report.time_tuning_s = self.fleet.round_busy_s();
        self.report.time_idle_s = (self.fleet.n() as f64 * self.stream.horizon
            - self.report.time_serving_s
            - self.report.time_tuning_s)
            .max(0.0);
        self.fleet.fill_hists(&mut self.report.hists);
        // one whole-run span in the sweep lane, so a single `etuner run`
        // timeline still covers all four subsystems.
        self.tracer.span(
            Lane::Sweep,
            "cell",
            0.0,
            self.stream.horizon,
            &[("seed", self.cfg.seed as f64)],
        );
        self.report.finish();
        Ok(self.report)
    }

    // -- internals -----------------------------------------------------------

    fn push_val(&mut self, x: &[f32], y: &[i32]) {
        let d = self.sess.m.d;
        // take the first 4 samples of the batch into the rolling pool
        for i in 0..4.min(y.len()) {
            self.val_pool.push(&x[i * d..(i + 1) * d], y[i]);
        }
    }

    fn validation_accuracy(&mut self) -> Result<f64> {
        if self.val_pool.is_empty() {
            return Ok(0.0);
        }
        let b = self.sess.m.batch_infer;
        self.val_x.clear();
        self.val_y.clear();
        for i in 0..b {
            let (x, y) = self.val_pool.get(i % self.val_pool.len());
            self.val_x.extend_from_slice(x);
            self.val_y.push(y);
        }
        self.book.charge_validation(&self.sess.m, b);
        let acc = match self.sess.accuracy(&self.params, &self.val_x, &self.val_y)
        {
            Ok(a) => a,
            // a faulted validation pass reads as zero accuracy for this
            // round (policies treat it as a bad round, which is fair).
            Err(_) if self.cfg.faults.enabled() => 0.0,
            Err(e) => return Err(e),
        };
        Ok(acc as f64)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &mut self,
        t: f64,
        scenario: usize,
        buffer: &mut Vec<(Vec<f32>, Vec<i32>, usize)>,
        trained_classes: &mut BitSet,
        total_iters: &mut u64,
        first_round: &mut bool,
    ) -> Result<()> {
        let batches_needed = self.tune.batches_needed();
        self.book.charge_round_overhead(&self.sess.m);
        if *first_round {
            self.report.memory_begin_bytes = flops::train_memory_bytes(
                &self.sess.m,
                self.freeze.state(),
                self.sess.m.batch_train,
            );
            *first_round = false;
        }
        let batches = buffer.len();
        let mut iters_this_round = 0u64;
        // θ snapshot for mid-round fault recovery: a step that fails
        // partway through the round must not leave a half-updated θ in
        // play, so the whole round rolls back to the last good generation
        // (set_theta bumps the generation, invalidating session caches
        // and resident serving banks built from the poisoned θ).
        let theta_snapshot = self.params.theta().to_vec();
        let mut failed: Option<anyhow::Error> = None;
        for (x, y, _scen) in buffer.drain(..) {
            // keep draining so the buffer (and the world/aux RNG draws)
            // stay in sync with the fault-free schedule, but stop
            // stepping once a batch has failed.
            let labeled = match self.cfg.labeled_fraction {
                None => true,
                Some(f) => self.rng.f32() < f,
            };
            if failed.is_some() {
                continue;
            }
            let scale = self.freeze.compute_inefficiency();
            self.book
                .charge_train_scaled(&self.sess.m, self.freeze.state(), 1, scale);
            let step = if labeled {
                let r = self
                    .sess
                    .train_step(&mut self.params, &x, &y, self.freeze.state());
                if r.is_ok() {
                    for &c in &y {
                        trained_classes.insert(c as usize);
                    }
                }
                r
            } else {
                // SimSiam on two augmented views (noise + per-dim jitter),
                // written into reused per-simulation buffers.
                let mut v1 = std::mem::take(&mut self.aug_a);
                let mut v2 = std::mem::take(&mut self.aug_b);
                self.augment(&x, &mut v1, &mut v2);
                let mut phi = std::mem::take(&mut self.phi);
                let r = self.sess.ssl_step(
                    &mut self.params,
                    &mut phi,
                    &v1,
                    &v2,
                    self.freeze.state(),
                );
                // restore the reused buffers before any error handling —
                // losing φ on a fault would silently reset the SSL
                // predictor for the rest of the run.
                self.phi = phi;
                self.aug_a = v1;
                self.aug_b = v2;
                r
            };
            match step.and_then(|()| {
                self.freeze.after_iteration(
                    &self.sess,
                    &mut self.params,
                    &mut self.book,
                )
            }) {
                Ok(()) => {
                    iters_this_round += 1;
                    *total_iters += 1;
                }
                Err(e) => failed = Some(e),
            }
        }
        if let Some(e) = failed {
            self.params.set_theta(theta_snapshot);
            self.round_rollbacks += 1;
            if self.cfg.faults.enabled() {
                // the round is abandoned: no validation, no round record,
                // no policy adaptation on a rolled-back θ.
                return Ok(());
            }
            return Err(e);
        }
        let val_acc = self.validation_accuracy()?;
        self.tune.on_round_end(*total_iters, val_acc);
        if let Err(e) = self.freeze.on_round_end(
            &self.sess,
            &mut self.params,
            val_acc,
            &mut self.book,
        ) {
            // a faulted end-of-round adaptation skips this round's freeze
            // update; the policy re-evaluates next round.
            if !self.cfg.faults.enabled() {
                return Err(e);
            }
        }
        self.report.round_log.push(RoundRecord {
            t,
            scenario,
            batches,
            iterations: iters_this_round,
            batches_needed,
            val_acc,
            frozen_units: self.freeze.state().frozen.iter().filter(|&&f| f).count(),
        });
        Ok(())
    }

    /// Fill `v1`/`v2` with two augmented views of `x` (reused buffers).
    fn augment(&mut self, x: &[f32], v1: &mut Vec<f32>, v2: &mut Vec<f32>) {
        v1.clear();
        v1.extend_from_slice(x);
        v2.clear();
        v2.extend_from_slice(x);
        for v in v1.iter_mut() {
            *v = *v * (0.9 + 0.2 * self.rng.f32()) + 0.1 * self.rng.normal();
        }
        for v in v2.iter_mut() {
            *v = *v * (0.9 + 0.2 * self.rng.f32()) + 0.1 * self.rng.normal();
        }
    }

    /// Poll the serving control plane at `t`.  The [`ServeCtx`] is
    /// rebuilt per call: it borrows fields disjoint from `self.fleet`,
    /// so the split borrow stays legal inside one method.
    fn poll_engine(&mut self, t: f64) -> Result<Vec<ServeEvent>> {
        self.fleet.poll(
            t,
            &ServeCtx {
                sess: &self.sess,
                params: &self.params,
                cwr: &self.cwr,
                scenarios: &self.schedule.scenarios,
            },
        )
    }

    /// Drain the serving control plane at `t` (window-unconditioned).
    fn drain_engine(&mut self, t: f64) -> Result<Vec<ServeEvent>> {
        self.fleet.drain(
            t,
            &ServeCtx {
                sess: &self.sess,
                params: &self.params,
                cwr: &self.cwr,
                scenarios: &self.schedule.scenarios,
            },
        )
    }

    /// Absorb control-plane events in service order: record served
    /// requests and run scenario-change detection on their energy scores
    /// (the request stream is the detector's only signal).  Drop,
    /// execute, and bank-install events are engine bookkeeping — their
    /// totals flow into the report from the engine counters at the end
    /// of the run.
    fn absorb_events(
        &mut self,
        events: Vec<ServeEvent>,
        trained_classes: &mut BitSet,
        reinit_done: &mut [bool],
        probe_pending: &mut bool,
    ) -> Result<()> {
        for ev in events {
            let s = match ev {
                ServeEvent::RequestServed(s) => s,
                ServeEvent::RequestDropped { .. }
                | ServeEvent::BatchExecuted { .. }
                | ServeEvent::BankInstalled { .. } => continue,
            };
            self.report.requests.push(RequestRecord {
                t: s.arrival_t,
                scenario: s.scenario,
                accuracy: s.accuracy,
                stale_batches: s.stale_batches,
                latency_s: s.latency_s,
                batch_requests: s.batch_requests,
                queue_depth: s.queue_depth,
                degraded: s.degraded,
            });
            self.last_energy_score = Some(s.energy_score);
            if !self.cfg.oracle_change_detection && self.detect_change()? {
                self.report.scenario_changes_detected += 1;
                self.tune.on_scenario_change();
                self.cwr.consolidate_set(
                    &self.sess.m,
                    &self.params,
                    trained_classes,
                );
                trained_classes.clear();
                reinit_done.iter_mut().for_each(|r| *r = false);
                *probe_pending = true;
            }
        }
        Ok(())
    }

    fn detect_change(&mut self) -> Result<bool> {
        if let Some(score) = self.last_energy_score.take() {
            Ok(self.ood.observe(score))
        } else {
            Ok(false)
        }
    }
}

/// Run `cfg` against `be`, honouring `cfg.faults`: with a fault plan the
/// backend is wrapped in a [`FaultyBackend`] seeded from
/// `cfg.seed ^ plan.seed` (so every sweep cell has its own deterministic
/// fault stream); with [`FaultPlan::none()`] — the default — no decorator
/// is constructed and the run is bit-identical to calling
/// [`Simulation::new`]`(be, cfg)?.run()` directly.
pub fn run_config(be: &dyn Backend, cfg: RunConfig) -> Result<Report> {
    if cfg.faults.enabled() {
        let fb = FaultyBackend::new(be, cfg.faults, cfg.seed);
        let mut sim = Simulation::new(&fb, cfg)?;
        maybe_resume(&mut sim)?;
        sim.run()
    } else {
        let mut sim = Simulation::new(be, cfg)?;
        maybe_resume(&mut sim)?;
        sim.run()
    }
}

/// Honour `--resume`: restore from the checkpoint directory after the
/// simulation is built (so the deterministic warmup already ran) and
/// before the event loop starts.
fn maybe_resume(sim: &mut Simulation) -> Result<()> {
    if sim.cfg.checkpoint.resume {
        let dir = sim.cfg.checkpoint.dir.clone().ok_or_else(|| {
            anyhow::anyhow!("--resume needs a checkpoint directory")
        })?;
        sim.resume_from(&dir)?;
    }
    Ok(())
}

/// [`run_config`] with a tracer attached.  The [`TracingBackend`] wraps
/// *outside* the fault layer, so injected faults appear in the timeline
/// as failed backend spans; a disabled tracer takes the exact
/// [`run_config`] path (no decorator, bit-identical reports).
pub fn run_config_traced(
    be: &dyn Backend,
    cfg: RunConfig,
    tracer: &Tracer,
) -> Result<Report> {
    if !tracer.on() {
        return run_config(be, cfg);
    }
    if cfg.faults.enabled() {
        let fb = FaultyBackend::new(be, cfg.faults, cfg.seed);
        let tb = TracingBackend::new(&fb, tracer.clone());
        let mut sim = Simulation::new(&tb, cfg)?;
        sim.set_tracer(tracer.clone());
        maybe_resume(&mut sim)?;
        sim.run()
    } else {
        let tb = TracingBackend::new(be, tracer.clone());
        let mut sim = Simulation::new(&tb, cfg)?;
        sim.set_tracer(tracer.clone());
        maybe_resume(&mut sim)?;
        sim.run()
    }
}
