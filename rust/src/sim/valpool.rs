//! Fixed-capacity rolling validation pool.
//!
//! The seed kept the rolling window in two growable `Vec`s and evicted with
//! `drain(0..d)` / `remove(0)` — an O(window) shift of the whole buffer for
//! every arriving batch.  This ring buffer keeps identical FIFO semantics
//! (same logical oldest-first ordering, same capacity) with O(d) pushes and
//! zero steady-state allocation.

/// Ring buffer of `(x, y)` validation samples, each `x` of dimension `d`.
#[derive(Clone, Debug)]
pub struct ValPool {
    d: usize,
    cap: usize,
    x: Vec<f32>,
    y: Vec<i32>,
    /// physical index of the logically-oldest sample (0 until full).
    head: usize,
    len: usize,
}

impl ValPool {
    pub fn new(d: usize, cap: usize) -> ValPool {
        assert!(d > 0 && cap > 0);
        ValPool { d, cap, x: Vec::new(), y: Vec::new(), head: 0, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one sample; once full, the oldest sample is overwritten.
    pub fn push(&mut self, x: &[f32], y: i32) {
        debug_assert_eq!(x.len(), self.d);
        if self.len < self.cap {
            self.x.extend_from_slice(x);
            self.y.push(y);
            self.len += 1;
        } else {
            let pos = self.head;
            self.x[pos * self.d..(pos + 1) * self.d].copy_from_slice(x);
            self.y[pos] = y;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Checkpoint view: `(d, cap, x, y, head, len)` — the full physical
    /// ring state, so a restore is bit-identical (including the physical
    /// rotation, which future pushes depend on).
    pub fn ckpt_state(&self) -> (usize, usize, &[f32], &[i32], usize, usize) {
        (self.d, self.cap, &self.x, &self.y, self.head, self.len)
    }

    /// Rebuild from checkpointed physical state.
    pub fn restore(
        d: usize,
        cap: usize,
        x: Vec<f32>,
        y: Vec<i32>,
        head: usize,
        len: usize,
    ) -> ValPool {
        ValPool { d, cap, x, y, head, len }
    }

    /// Logical index `j` (0 = oldest) -> sample view.
    pub fn get(&self, j: usize) -> (&[f32], i32) {
        debug_assert!(j < self.len);
        let pos = if self.len < self.cap { j } else { (self.head + j) % self.cap };
        (&self.x[pos * self.d..(pos + 1) * self.d], self.y[pos])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The semantics the seed's Vec-shift implementation had.
    struct Naive {
        d: usize,
        cap: usize,
        x: Vec<f32>,
        y: Vec<i32>,
    }

    impl Naive {
        fn push(&mut self, x: &[f32], y: i32) {
            self.x.extend_from_slice(x);
            self.y.push(y);
            while self.y.len() > self.cap {
                self.x.drain(0..self.d);
                self.y.remove(0);
            }
        }
    }

    #[test]
    fn matches_naive_fifo_semantics() {
        let (d, cap) = (3, 5);
        let mut ring = ValPool::new(d, cap);
        let mut naive = Naive { d, cap, x: Vec::new(), y: Vec::new() };
        for s in 0..17i32 {
            let x: Vec<f32> = (0..d).map(|k| (s * 10 + k as i32) as f32).collect();
            ring.push(&x, s);
            naive.push(&x, s);
            assert_eq!(ring.len(), naive.y.len());
            for j in 0..ring.len() {
                let (rx, ry) = ring.get(j);
                assert_eq!(ry, naive.y[j], "step {s} sample {j}");
                assert_eq!(rx, &naive.x[j * d..(j + 1) * d]);
            }
        }
    }

    #[test]
    fn partial_fill_indexes_in_arrival_order() {
        let mut p = ValPool::new(2, 8);
        p.push(&[1.0, 2.0], 10);
        p.push(&[3.0, 4.0], 11);
        assert_eq!(p.len(), 2);
        assert_eq!(p.get(0), (&[1.0f32, 2.0][..], 10));
        assert_eq!(p.get(1), (&[3.0f32, 4.0][..], 11));
    }
}
