//! Shared helpers for tests, benches and examples.

use std::path::PathBuf;

/// Locate the artifacts directory: `$ETUNER_ARTIFACTS` or
/// `<crate root>/artifacts` (works from `cargo test/bench/run`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("ETUNER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Simple timing helper for the dependency-free bench harness.
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: std::time::Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Measure `f` with warmup; returns (mean_ms, min_ms, max_ms) over `n`.
pub fn bench<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Timer::start();
        f();
        times.push(t.elapsed_ms());
    }
    let mean = times.iter().sum::<f64>() / n as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    (mean, min, max)
}
