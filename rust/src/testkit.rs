//! Shared helpers for tests, benches and examples.

use std::path::PathBuf;

/// Locate the artifacts directory: `$ETUNER_ARTIFACTS` or
/// `<crate root>/artifacts` (works from `cargo test/bench/run`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("ETUNER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Reference-backend spec over the artifact directory: uses aot.py's
/// manifest + θ0 when present, the built-in model family otherwise.
/// Either way it *executes* — this is what CI tests run models on.
pub fn refcpu_spec() -> crate::runtime::BackendSpec {
    crate::runtime::BackendSpec::refcpu(artifacts_dir())
}

/// Construct the reference backend (never fails to execute).
pub fn refcpu_backend() -> Box<dyn crate::runtime::Backend> {
    refcpu_spec().create().expect("refcpu backend")
}

/// The preferred *executing* backend for whole-system tests: PJRT over
/// the artifacts when it works here, the reference executor otherwise.
/// Unlike the pre-backend era, this never skips — every environment runs
/// models.
pub fn execution_backend() -> Box<dyn crate::runtime::Backend> {
    pjrt_backend_if_available().unwrap_or_else(refcpu_backend)
}

/// The PJRT backend when it can actually execute here (artifacts built
/// AND compiled with the `xla` feature); `None` otherwise.
///
/// Only two outcomes are a legitimate skip: no artifact directory, or a
/// build without the `xla` feature (the stub client refuses to come up).
/// Artifacts that are *present but unloadable* (truncated θ0 binaries,
/// malformed manifest) are a broken `make artifacts` output and must
/// fail tests loudly, not silently skip the whole PJRT suite.
pub fn pjrt_backend_if_available() -> Option<Box<dyn crate::runtime::Backend>> {
    if !artifacts_available() {
        return None;
    }
    match crate::runtime::BackendSpec::new(
        crate::runtime::BackendKind::Pjrt,
        artifacts_dir(),
    )
    .create()
    {
        Ok(be) => Some(be),
        Err(e) if format!("{e:?}").contains("without the `xla` feature") => None,
        Err(e) => panic!(
            "artifacts are present but the pjrt backend failed to load \
             (corrupt `make artifacts` output?): {e:?}"
        ),
    }
}

/// Two linearly separable synthetic classes — the shared data generator
/// of the executing integration suites (PJRT and refcpu must train on
/// the *same* recipe, so it lives here rather than per test file).
pub fn two_class_batch(
    rng: &mut crate::rng::Pcg32,
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0.0f32; n * d];
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = (rng.next_u32() % 2) as i32;
        y.push(c);
        for j in 0..d {
            let mu = if c == 0 { 1.0 } else { -1.0 };
            let sign = if j % 2 == 0 { mu } else { -mu };
            x[i * d + j] = 0.8 * sign + 0.5 * rng.normal();
        }
    }
    (x, y)
}

/// Simple timing helper for the dependency-free bench harness.
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: std::time::Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Measure `f` with warmup; returns (mean_ms, min_ms, max_ms) over `n`.
pub fn bench<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Timer::start();
        f();
        times.push(t.elapsed_ms());
    }
    let mean = times.iter().sum::<f64>() / n as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    (mean, min, max)
}
