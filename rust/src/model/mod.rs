//! Deployed-model state management: the flat parameter vector, typed
//! sessions over the runtime artifacts, and CWR head consolidation.

pub mod cwr;
pub mod params;
pub mod session;

pub use cwr::Cwr;
pub use params::Params;
pub use session::ModelSession;
