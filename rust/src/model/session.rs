//! Typed session over one deployed model: binds the runtime artifacts to
//! the flat parameter vector and exposes the operations the coordinator
//! needs (train step, inference, CKA probe, SimSiam step).

use anyhow::Result;

use crate::cost::flops::FreezeState;
use crate::runtime::exec::{i32_literal, TensorF32};
use crate::runtime::{ModelManifest, Runtime};

use super::params::Params;

/// A bound (runtime, model) pair.
pub struct ModelSession<'rt> {
    pub rt: &'rt Runtime,
    pub m: ModelManifest,
    /// Use the 8-bit QAT train artifacts (Table VIII).
    pub quant: bool,
    pub lr: f32,
}

impl<'rt> ModelSession<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str) -> Result<Self> {
        let m = rt.manifest.model(model)?.clone();
        Ok(ModelSession { rt, m, quant: false, lr: 0.05 })
    }

    /// Initial (pre-deployment) parameters from the artifact directory.
    pub fn theta0(&self) -> Result<Params> {
        Params::new(self.rt.theta0(&self.m.name)?, &self.m)
    }

    /// One SGD step on a batch.  Chooses the `train_k` artifact matching
    /// the frozen *prefix* (real backprop truncation) and passes the
    /// per-unit lr mask for interior frozen units.  Returns the loss.
    pub fn train_step(
        &self,
        params: &mut Params,
        x: &[f32],
        y: &[i32],
        fs: &FreezeState,
    ) -> Result<f32> {
        let b = self.m.batch_train;
        anyhow::ensure!(x.len() == b * self.m.d, "bad x len {}", x.len());
        anyhow::ensure!(y.len() == b, "bad y len {}", y.len());
        let k = fs.frozen_prefix().min(self.m.units - 1);
        let name = self.m.train_artifact(k, self.quant)?.to_string();
        let inputs = vec![
            TensorF32::new(vec![self.m.theta_len], params.theta.clone()).to_literal()?,
            TensorF32::new(vec![b, self.m.d], x.to_vec()).to_literal()?,
            i32_literal(y, &[b])?,
            TensorF32::vec(fs.lr_mask()).to_literal()?,
            TensorF32::scalar(self.lr).to_literal()?,
        ];
        let mut out = self.rt.exec_raw(&name, &inputs)?;
        anyhow::ensure!(out.len() == 2, "train artifact returned {}", out.len());
        let loss = out.pop().unwrap().data[0];
        params.theta = out.pop().unwrap().data;
        Ok(loss)
    }

    /// Forward pass at the inference batch size; returns logits [B, C].
    pub fn infer(&self, params: &Params, x: &[f32]) -> Result<TensorF32> {
        let b = self.m.batch_infer;
        anyhow::ensure!(x.len() == b * self.m.d, "bad x len {}", x.len());
        let inputs = vec![
            TensorF32::new(vec![self.m.theta_len], params.theta.clone()),
            TensorF32::new(vec![b, self.m.d], x.to_vec()),
        ];
        let mut out = self.rt.exec(&self.m.artifacts.infer, &inputs)?;
        Ok(out.pop().unwrap())
    }

    /// Classification accuracy on (x, y) at the inference batch size.
    pub fn accuracy(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<f32> {
        let logits = self.infer(params, x)?;
        let pred = logits.argmax_rows();
        let correct = pred
            .iter()
            .zip(y)
            .filter(|(p, t)| **p == **t as usize)
            .count();
        Ok(correct as f32 / y.len() as f32)
    }

    /// Energy scores `E(x) = -logsumexp(logits)` for OOD detection.
    pub fn energy_scores(&self, params: &Params, x: &[f32]) -> Result<Vec<f32>> {
        let logits = self.infer(params, x)?;
        Ok(logits.logsumexp_rows().iter().map(|v| -v).collect())
    }

    /// Per-unit feature maps on the probe batch: returns [units-1, B, H]
    /// (embed output + each block output; the head has no feature map).
    pub fn features(&self, params: &Params, x: &[f32]) -> Result<TensorF32> {
        let b = self.m.batch_probe;
        anyhow::ensure!(x.len() == b * self.m.d, "bad probe len {}", x.len());
        let inputs = vec![
            TensorF32::new(vec![self.m.theta_len], params.theta.clone()),
            TensorF32::new(vec![b, self.m.d], x.to_vec()),
        ];
        let mut out = self.rt.exec(&self.m.artifacts.features, &inputs)?;
        Ok(out.pop().unwrap())
    }

    /// CKA between two (B, H) feature maps via the Pallas Gram artifact.
    pub fn cka(&self, fx: &[f32], fy: &[f32]) -> Result<f32> {
        let b = self.m.batch_probe;
        let h = self.m.h;
        anyhow::ensure!(fx.len() == b * h && fy.len() == b * h, "bad feature len");
        let name = self.rt.manifest.cka_artifact(h)?.to_string();
        let inputs = vec![
            TensorF32::new(vec![b, h], fx.to_vec()),
            TensorF32::new(vec![b, h], fy.to_vec()),
        ];
        let out = self.rt.exec(&name, &inputs)?;
        Ok(out[0].data[0])
    }

    /// CKA of layer `l` between two stacked feature tensors [L, B, H].
    pub fn cka_layer(&self, feats_a: &TensorF32, feats_b: &TensorF32, l: usize) -> Result<f32> {
        let bh = self.m.batch_probe * self.m.h;
        let fa = &feats_a.data[l * bh..(l + 1) * bh];
        let fb = &feats_b.data[l * bh..(l + 1) * bh];
        self.cka(fa, fb)
    }

    /// One SimSiam self-supervised step on two augmented views (Table VI).
    pub fn ssl_step(
        &self,
        params: &mut Params,
        phi: &mut Vec<f32>,
        x1: &[f32],
        x2: &[f32],
        fs: &FreezeState,
    ) -> Result<f32> {
        let b = self.m.batch_train;
        let name = self
            .m
            .artifacts
            .ssl
            .clone()
            .ok_or_else(|| anyhow::anyhow!("{} has no ssl artifact", self.m.name))?;
        let inputs = vec![
            TensorF32::new(vec![self.m.theta_len], params.theta.clone()),
            TensorF32::new(vec![phi.len()], phi.clone()),
            TensorF32::new(vec![b, self.m.d], x1.to_vec()),
            TensorF32::new(vec![b, self.m.d], x2.to_vec()),
            TensorF32::vec(fs.lr_mask()),
            TensorF32::scalar(self.lr),
        ];
        let mut out = self.rt.exec(&name, &inputs)?;
        anyhow::ensure!(out.len() == 3, "ssl artifact returned {}", out.len());
        let loss = out.pop().unwrap().data[0];
        *phi = out.pop().unwrap().data;
        params.theta = out.pop().unwrap().data;
        Ok(loss)
    }
}
