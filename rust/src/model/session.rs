//! Typed session over one deployed model: binds a [`Backend`] to the flat
//! parameter vector and exposes the operations the coordinator needs
//! (train step, inference, CKA probe, SimSiam step).
//!
//! The session is backend-agnostic: it talks only to the object-safe
//! [`Backend`] trait, so the same coordinator code drives PJRT artifacts
//! and the pure-Rust reference executor.
//!
//! # Zero-copy θ boundary (adopt/donate)
//!
//! θ is by far the largest tensor crossing the execute boundary; the seed
//! implementation cloned it into a fresh `Vec` *and* re-marshalled it into
//! a backend buffer on every call.  The session keeps a [`Value`] cache
//! keyed by [`Params::id`]`/`[`Params::generation`]: θ is re-marshalled
//! only when the parameter generation changed, input batches are
//! marshalled straight from the caller's slice (no intermediate `Vec`),
//! and a train step's *output* θ buffer is **adopted** back into the cache
//! and **donated** (by reference) to the next call — consecutive train
//! steps never round-trip θ through a re-marshal.  `theta_marshals`/
//! `theta_cache_hits` counters expose the behaviour to benches and
//! regression tests.
//!
//! The cache is also the **generation-keyed invalidation hook** for
//! backend state derived from θ (the reference executor's packed weight
//! panels): every eviction or stale-generation replacement calls
//! [`Backend::release`] with the dropped value's buf id.  The serving
//! engine's [`crate::serve::BankSet`] keeps *multiple* serving θs warm at
//! once — one bank-installed `Params` per active scenario, each a
//! distinct cache entry beside the live training θ:
//! [`ModelSession::warm_infer`] pre-builds a bank's backend state
//! ([`Backend::warm`]) at install time, and
//! [`ModelSession::release_params`] frees it when the bank is evicted.

// Serving hot path: every failure must surface as a recoverable Result
// (reachable under injected faults), never a panic.
#![deny(clippy::disallowed_methods)]

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use anyhow::Result;

use crate::cost::flops::FreezeState;
use crate::runtime::exec::TensorF32;
use crate::runtime::{Backend, ModelManifest, Value};

use super::params::Params;

/// Soft bound on distinct `Params` instances tracked by the value cache.
/// A simulation touches a handful (live θ, resident serving banks, policy
/// references); the cap only guards against pathological callers churning
/// instances.  Crate-visible so the serving engine's `BankSet` can bound
/// its residency *below* this: if banks alone could reach the cap, every
/// overflow would drain the whole cache — live θ and all warm banks —
/// while the banks' generation snapshots still read as valid, silently
/// reintroducing the per-request marshal+pack cost residency exists to
/// avoid.
pub(crate) const THETA_CACHE_CAP: usize = 16;

/// A bound (backend, model) pair.
pub struct ModelSession<'b> {
    pub be: &'b dyn Backend,
    pub m: ModelManifest,
    /// Use the 8-bit QAT train artifacts (Table VIII).
    pub quant: bool,
    pub lr: f32,
    /// (params id) -> (generation, marshalled θ buffer).
    theta_cache: RefCell<HashMap<u64, (u64, Value)>>,
    theta_marshals: Cell<u64>,
    theta_cache_hits: Cell<u64>,
}

impl<'b> Drop for ModelSession<'b> {
    /// Backends outlive sessions (one backend serves many runs in a
    /// sweep), so tell it to free pack state keyed on this session's
    /// cached θ buf ids — otherwise dead srcs accumulate until the
    /// backend's src cap flushes live packs along with them.
    fn drop(&mut self) {
        let mut cache = self.theta_cache.borrow_mut();
        self.clear_theta_cache(&mut cache);
    }
}

impl<'b> ModelSession<'b> {
    pub fn new(be: &'b dyn Backend, model: &str) -> Result<Self> {
        let m = be.manifest().model(model)?.clone();
        Ok(ModelSession {
            be,
            m,
            quant: false,
            lr: 0.05,
            theta_cache: RefCell::new(HashMap::new()),
            theta_marshals: Cell::new(0),
            theta_cache_hits: Cell::new(0),
        })
    }

    /// Initial (pre-deployment) parameters from the backend's θ0 source.
    pub fn theta0(&self) -> Result<Params> {
        Params::new(self.be.theta0(&self.m.name)?, &self.m)
    }

    /// Times θ was serialized host → backend buffer since session creation.
    pub fn theta_marshal_count(&self) -> u64 {
        self.theta_marshals.get()
    }

    /// Times a call reused a cached θ buffer instead of re-marshalling.
    pub fn theta_cache_hit_count(&self) -> u64 {
        self.theta_cache_hits.get()
    }

    /// Drop every cached θ value, telling the backend to free any derived
    /// state (packed weight panels) keyed on the evicted buf ids.
    fn clear_theta_cache(&self, cache: &mut HashMap<u64, (u64, Value)>) {
        for (_, (_, v)) in cache.drain() {
            self.be.release(v.buf_id());
        }
    }

    /// Make sure the cache holds a buffer for `params`' current content.
    ///
    /// This is the generation-keyed invalidation hook for *all* per-θ
    /// backend state: replacing a stale entry (the generation moved)
    /// releases the old value's buf id, so the backend's weight-pack
    /// cache invalidates in lockstep with the θ-literal cache.
    fn ensure_theta_value(&self, params: &Params) -> Result<()> {
        let mut cache = self.theta_cache.borrow_mut();
        if let Some((gen, _)) = cache.get(&params.id()) {
            if *gen == params.generation() {
                self.theta_cache_hits.set(self.theta_cache_hits.get() + 1);
                return Ok(());
            }
        }
        if cache.len() >= THETA_CACHE_CAP {
            self.clear_theta_cache(&mut cache);
        }
        self.theta_marshals.set(self.theta_marshals.get() + 1);
        let v = self.be.marshal_f32(params.theta(), &[self.m.theta_len])?;
        if let Some((_, old)) = cache.insert(params.id(), (params.generation(), v)) {
            self.be.release(old.buf_id());
        }
        Ok(())
    }

    /// The cached θ value for `params` (must follow a successful
    /// [`ModelSession::ensure_theta_value`] in the same borrow — the
    /// cache cannot be evicted between the two, so a miss here is an
    /// internal sequencing bug surfaced as a recoverable error).
    fn cached_theta<'c>(
        &self,
        cache: &'c HashMap<u64, (u64, Value)>,
        params: &Params,
    ) -> Result<&'c Value> {
        cache.get(&params.id()).map(|(_, v)| v).ok_or_else(|| {
            anyhow::anyhow!(
                "θ value for params {} missing after ensure",
                params.id()
            )
        })
    }

    /// Adopt an execute-produced θ buffer for `params`' current content
    /// (train/ssl output reuse: the next step's input marshal is free).
    fn adopt_theta_value(&self, params: &Params, v: Value) {
        let mut cache = self.theta_cache.borrow_mut();
        if cache.len() >= THETA_CACHE_CAP {
            self.clear_theta_cache(&mut cache);
        }
        if let Some((_, old)) = cache.insert(params.id(), (params.generation(), v)) {
            self.be.release(old.buf_id());
        }
    }

    /// Pre-build the backend's per-θ serving state (marshalled literal +
    /// packed forward panels) for `params`.  The serving engine calls
    /// this whenever it installs a CWR-bank θ — since the BankSet there
    /// may be *several* serving θs warm at once (one per active
    /// scenario), each under its own `Params` id, coexisting with the
    /// live training θ in this cache.
    pub fn warm_infer(&self, params: &Params) -> Result<()> {
        self.ensure_theta_value(params)?;
        let cache = self.theta_cache.borrow();
        let theta_v = self.cached_theta(&cache, params)?;
        self.be.warm(&self.m.artifacts.infer, theta_v)
    }

    /// Drop the cached θ value for one `Params` instance, releasing the
    /// backend state (packed panels) keyed on its buf id.  The serving
    /// engine calls this when the BankSet LRU-evicts a scenario's bank,
    /// so inactive serving θs free their literal + packs immediately
    /// instead of lingering until a generation collision or session drop.
    /// A no-op for ids this session never marshalled.
    pub fn release_params(&self, params_id: u64) {
        if let Some((_, v)) = self.theta_cache.borrow_mut().remove(&params_id) {
            self.be.release(v.buf_id());
        }
    }

    /// One SGD step on a batch.  Chooses the `train_k` artifact matching
    /// the frozen *prefix* (real backprop truncation) and passes the
    /// per-unit lr mask for interior frozen units.  Returns the loss.
    pub fn train_step(
        &self,
        params: &mut Params,
        x: &[f32],
        y: &[i32],
        fs: &FreezeState,
    ) -> Result<f32> {
        let b = self.m.batch_train;
        anyhow::ensure!(x.len() == b * self.m.d, "bad x len {}", x.len());
        anyhow::ensure!(y.len() == b, "bad y len {}", y.len());
        let k = fs.frozen_prefix().min(self.m.units - 1);
        let name = self.m.train_artifact(k, self.quant)?;
        self.ensure_theta_value(params)?;
        let x_v = self.be.marshal_f32(x, &[b, self.m.d])?;
        let y_v = self.be.marshal_i32(y, &[b])?;
        let mask_v = self.be.marshal_f32(&fs.lr_mask(), &[fs.units()])?;
        let lr_v = self.be.marshal_f32(&[self.lr], &[])?;
        let mut out = {
            let cache = self.theta_cache.borrow();
            let theta_v = self.cached_theta(&cache, params)?;
            let inputs = [theta_v, &x_v, &y_v, &mask_v, &lr_v];
            self.be.execute(name, &inputs)?
        };
        anyhow::ensure!(out.len() == 2, "train artifact returned {}", out.len());
        let loss = pop_output(&mut out, "loss")?.to_tensor()?.data[0];
        let theta_v = pop_output(&mut out, "theta")?;
        let theta = theta_v.read_f32()?;
        anyhow::ensure!(theta.len() == self.m.theta_len, "train returned bad θ len");
        params.set_theta(theta);
        self.adopt_theta_value(params, theta_v);
        Ok(loss)
    }

    /// Execute a (θ, x)-shaped artifact through the θ value cache.
    fn exec_theta_x(&self, name: &str, params: &Params, x_v: &Value) -> Result<Vec<TensorF32>> {
        self.ensure_theta_value(params)?;
        let cache = self.theta_cache.borrow();
        let theta_v = self.cached_theta(&cache, params)?;
        self.be
            .execute(name, &[theta_v, x_v])?
            .iter()
            .map(Value::to_tensor)
            .collect()
    }

    /// Forward pass at the inference batch size; returns logits [B, C].
    pub fn infer(&self, params: &Params, x: &[f32]) -> Result<TensorF32> {
        let b = self.m.batch_infer;
        anyhow::ensure!(x.len() == b * self.m.d, "bad x len {}", x.len());
        let x_v = self.be.marshal_f32(x, &[b, self.m.d])?;
        let mut out = self.exec_theta_x(&self.m.artifacts.infer, params, &x_v)?;
        out.pop()
            .ok_or_else(|| anyhow::anyhow!("infer artifact returned no output"))
    }

    /// Classification accuracy on (x, y) at the inference batch size.
    pub fn accuracy(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<f32> {
        let logits = self.infer(params, x)?;
        let pred = logits.argmax_rows();
        let correct = pred
            .iter()
            .zip(y)
            .filter(|(p, t)| **p == **t as usize)
            .count();
        Ok(correct as f32 / y.len() as f32)
    }

    /// Energy scores `E(x) = -logsumexp(logits)` for OOD detection.
    pub fn energy_scores(&self, params: &Params, x: &[f32]) -> Result<Vec<f32>> {
        let logits = self.infer(params, x)?;
        Ok(logits.logsumexp_rows().iter().map(|v| -v).collect())
    }

    /// Per-unit feature maps on the probe batch: returns [units-1, B, H]
    /// (embed output + each block output; the head has no feature map).
    pub fn features(&self, params: &Params, x: &[f32]) -> Result<TensorF32> {
        let b = self.m.batch_probe;
        anyhow::ensure!(x.len() == b * self.m.d, "bad probe len {}", x.len());
        let x_v = self.be.marshal_f32(x, &[b, self.m.d])?;
        let mut out = self.exec_theta_x(&self.m.artifacts.features, params, &x_v)?;
        out.pop().ok_or_else(|| {
            anyhow::anyhow!("features artifact returned no output")
        })
    }

    /// CKA between two (B, H) feature maps via the Gram artifact.
    pub fn cka(&self, fx: &[f32], fy: &[f32]) -> Result<f32> {
        let b = self.m.batch_probe;
        let h = self.m.h;
        anyhow::ensure!(fx.len() == b * h && fy.len() == b * h, "bad feature len");
        let name = self.be.manifest().cka_artifact(h)?;
        // marshal straight from the stacked-feature slices: no `to_vec`.
        let fx_v = self.be.marshal_f32(fx, &[b, h])?;
        let fy_v = self.be.marshal_f32(fy, &[b, h])?;
        let out = self.be.execute(name, &[&fx_v, &fy_v])?;
        Ok(out[0].to_tensor()?.data[0])
    }

    /// CKA of layer `l` between two stacked feature tensors [L, B, H].
    pub fn cka_layer(&self, feats_a: &TensorF32, feats_b: &TensorF32, l: usize) -> Result<f32> {
        let bh = self.m.batch_probe * self.m.h;
        let fa = &feats_a.data[l * bh..(l + 1) * bh];
        let fb = &feats_b.data[l * bh..(l + 1) * bh];
        self.cka(fa, fb)
    }

    /// One SimSiam self-supervised step on two augmented views (Table VI).
    pub fn ssl_step(
        &self,
        params: &mut Params,
        phi: &mut Vec<f32>,
        x1: &[f32],
        x2: &[f32],
        fs: &FreezeState,
    ) -> Result<f32> {
        let b = self.m.batch_train;
        let name = self
            .m
            .artifacts
            .ssl
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("{} has no ssl artifact", self.m.name))?;
        self.ensure_theta_value(params)?;
        let phi_v = self.be.marshal_f32(phi, &[phi.len()])?;
        let x1_v = self.be.marshal_f32(x1, &[b, self.m.d])?;
        let x2_v = self.be.marshal_f32(x2, &[b, self.m.d])?;
        let mask_v = self.be.marshal_f32(&fs.lr_mask(), &[fs.units()])?;
        let lr_v = self.be.marshal_f32(&[self.lr], &[])?;
        let mut out = {
            let cache = self.theta_cache.borrow();
            let theta_v = self.cached_theta(&cache, params)?;
            let inputs = [theta_v, &phi_v, &x1_v, &x2_v, &mask_v, &lr_v];
            self.be.execute(name, &inputs)?
        };
        anyhow::ensure!(out.len() == 3, "ssl artifact returned {}", out.len());
        let loss = pop_output(&mut out, "loss")?.to_tensor()?.data[0];
        *phi = pop_output(&mut out, "phi")?.read_f32()?;
        let theta_v = pop_output(&mut out, "theta")?;
        let theta = theta_v.read_f32()?;
        anyhow::ensure!(theta.len() == self.m.theta_len, "ssl returned bad θ len");
        params.set_theta(theta);
        self.adopt_theta_value(params, theta_v);
        Ok(loss)
    }
}

/// Pop the next artifact output, surfacing a short tuple as a recoverable
/// error (a length `ensure!` precedes every use, but the hot path must
/// never panic).
fn pop_output(out: &mut Vec<Value>, what: &str) -> Result<Value> {
    out.pop().ok_or_else(|| {
        anyhow::anyhow!("artifact output tuple missing {what} entry")
    })
}
