//! Flat parameter vector with manifest-driven segment views.
//!
//! The whole model is one `Vec<f32>` (matching the python side's flat θ);
//! freeze units, individual tensors, and the classifier head are views by
//! manifest offsets.  RigL's sparsity masks and CWR's head surgery operate
//! directly on these views.

use anyhow::Result;

use crate::runtime::artifact::ModelManifest;

/// Model parameters + metadata needed for segment addressing.
#[derive(Clone, Debug)]
pub struct Params {
    pub theta: Vec<f32>,
}

impl Params {
    pub fn new(theta: Vec<f32>, m: &ModelManifest) -> Result<Params> {
        anyhow::ensure!(
            theta.len() == m.theta_len,
            "theta length {} != manifest {}",
            theta.len(),
            m.theta_len
        );
        Ok(Params { theta })
    }

    /// View of one freeze unit's slice.
    pub fn unit<'a>(&'a self, m: &ModelManifest, u: usize) -> &'a [f32] {
        let s = m.unit_segments[u];
        &self.theta[s.offset..s.offset + s.len]
    }

    pub fn unit_mut<'a>(&'a mut self, m: &ModelManifest, u: usize) -> &'a mut [f32] {
        let s = m.unit_segments[u];
        &mut self.theta[s.offset..s.offset + s.len]
    }

    /// View of a named tensor.
    pub fn tensor<'a>(&'a self, m: &ModelManifest, name: &str) -> Result<&'a [f32]> {
        let t = m
            .tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("no tensor {name:?}"))?;
        Ok(&self.theta[t.offset..t.offset + t.size()])
    }

    /// Head weight column for class `c`: the row-major (H, C) weight matrix
    /// stores class `c` at stride C — returns (indices, bias_index).
    /// Used by CWR to copy/reset per-class discriminators.
    pub fn head_class_indices(m: &ModelManifest, c: usize) -> (Vec<usize>, usize) {
        let h = m.head.w_shape[0];
        let cdim = m.head.w_shape[1];
        debug_assert!(c < cdim);
        let idx = (0..h).map(|r| m.head.w_offset + r * cdim + c).collect();
        (idx, m.head.b_offset + c)
    }

    /// L2 norm of one unit's slice (used by SlimFit-style baselines).
    pub fn unit_norm(&self, m: &ModelManifest, u: usize) -> f32 {
        self.unit(m, u).iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// L1 of elementwise delta vs `other`, per unit.
    pub fn unit_delta_l1(&self, other: &Params, m: &ModelManifest, u: usize) -> f32 {
        self.unit(m, u)
            .iter()
            .zip(other.unit(m, u))
            .map(|(a, b)| (a - b).abs())
            .sum()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment, TensorInfo,
    };

    pub(crate) fn toy_manifest() -> ModelManifest {
        // layout: embed.w (2x3=6) | head.w (3x4=12), head.b (4)
        ModelManifest {
            name: "toy".into(),
            d: 2,
            h: 3,
            blocks: 0,
            classes: 4,
            units: 2,
            kind: "relu_res".into(),
            theta_len: 22,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![
                Segment { offset: 0, len: 6 },
                Segment { offset: 6, len: 16 },
            ],
            tensors: vec![
                TensorInfo { name: "embed.w".into(), shape: vec![2, 3], unit: 0, offset: 0 },
                TensorInfo { name: "head.w".into(), shape: vec![3, 4], unit: 1, offset: 6 },
                TensorInfo { name: "head.b".into(), shape: vec![4], unit: 1, offset: 18 },
            ],
            head: HeadInfo { w_offset: 6, w_shape: [3, 4], b_offset: 18, classes: 4 },
            paper_units: vec![
                PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 },
                PaperUnit { fwd_flops: 1e8, param_bytes: 1e5 },
            ],
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn rejects_wrong_length() {
        let m = toy_manifest();
        assert!(Params::new(vec![0.0; 3], &m).is_err());
        assert!(Params::new(vec![0.0; 22], &m).is_ok());
    }

    #[test]
    fn unit_views_are_disjoint_and_cover() {
        let m = toy_manifest();
        let p = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        assert_eq!(p.unit(&m, 0), &(0..6).map(|x| x as f32).collect::<Vec<_>>()[..]);
        assert_eq!(p.unit(&m, 1).len(), 16);
        assert_eq!(p.unit(&m, 1)[0], 6.0);
    }

    #[test]
    fn head_class_indices_stride_by_classes() {
        let m = toy_manifest();
        let (idx, b) = Params::head_class_indices(&m, 2);
        // head.w offset 6, shape (3,4): class-2 column = 6+2, 6+6, 6+10
        assert_eq!(idx, vec![8, 12, 16]);
        assert_eq!(b, 20);
    }

    #[test]
    fn named_tensor_view() {
        let m = toy_manifest();
        let p = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        assert_eq!(p.tensor(&m, "head.b").unwrap(), &[18.0, 19.0, 20.0, 21.0]);
        assert!(p.tensor(&m, "nope").is_err());
    }

    #[test]
    fn delta_l1_detects_change() {
        let m = toy_manifest();
        let a = Params::new(vec![0.0; 22], &m).unwrap();
        let mut b = a.clone();
        b.theta[1] = 2.0;
        b.theta[7] = -1.0;
        assert_eq!(a.unit_delta_l1(&b, &m, 0), 2.0);
        assert_eq!(a.unit_delta_l1(&b, &m, 1), 1.0);
    }
}
