//! Flat parameter vector with manifest-driven segment views.
//!
//! The whole model is one `Vec<f32>` (matching the python side's flat θ);
//! freeze units, individual tensors, and the classifier head are views by
//! manifest offsets.  RigL's sparsity masks and CWR's head surgery operate
//! directly on these views.
//!
//! Every `Params` carries a process-unique `id` and a `generation` counter
//! that bumps on every mutable access.  `(id, generation)` is a stable
//! content key: the session's literal cache and the simulator's serving
//! cache use it to skip re-marshalling θ when nothing changed.  All
//! mutation is funneled through `theta_mut`/`set_theta`/`copy_from`, so
//! the compiler guarantees no write can bypass the counter.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::runtime::artifact::ModelManifest;

static NEXT_PARAMS_ID: AtomicU64 = AtomicU64::new(1);

fn next_id() -> u64 {
    NEXT_PARAMS_ID.fetch_add(1, Ordering::Relaxed)
}

/// Model parameters + metadata needed for segment addressing.
#[derive(Debug)]
pub struct Params {
    theta: Vec<f32>,
    id: u64,
    generation: u64,
}

impl Clone for Params {
    /// Clones get a fresh identity: two instances that later diverge must
    /// never collide in a `(id, generation)`-keyed cache.
    fn clone(&self) -> Params {
        Params { theta: self.theta.clone(), id: next_id(), generation: 0 }
    }
}

impl Params {
    pub fn new(theta: Vec<f32>, m: &ModelManifest) -> Result<Params> {
        anyhow::ensure!(
            theta.len() == m.theta_len,
            "theta length {} != manifest {}",
            theta.len(),
            m.theta_len
        );
        Ok(Params::from_vec(theta))
    }

    /// Wrap a raw θ vector without a manifest length check (reference
    /// snapshots held by freeze policies).
    pub fn from_vec(theta: Vec<f32>) -> Params {
        Params { theta, id: next_id(), generation: 0 }
    }

    /// Rebuild an instance with an exact saved `(id, generation)` identity
    /// (checkpoint restore).  The process-wide id counter is advanced past
    /// `id` so no later allocation can collide with the restored instance
    /// in an `(id, generation)`-keyed cache — and because the restored θ
    /// bytes are identical to what the id originally named, any stale
    /// cache entry that does match maps to identical content.
    pub fn restore(theta: Vec<f32>, id: u64, generation: u64) -> Params {
        NEXT_PARAMS_ID.fetch_max(id + 1, Ordering::Relaxed);
        Params { theta, id, generation }
    }

    /// Read-only view of the flat parameter vector.
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Mutable view; bumps the generation (conservatively — taking the
    /// borrow counts as a write).
    pub fn theta_mut(&mut self) -> &mut [f32] {
        self.generation += 1;
        &mut self.theta
    }

    /// Replace the whole vector (train-step output install).
    pub fn set_theta(&mut self, theta: Vec<f32>) {
        self.generation += 1;
        self.theta = theta;
    }

    /// Copy `other`'s contents into this instance, reusing the allocation
    /// and keeping this instance's `id` (the serving cache overwrites its
    /// slot in place).
    pub fn copy_from(&mut self, other: &Params) {
        self.generation += 1;
        self.theta.clone_from(&other.theta);
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    /// Process-unique instance id (cache key half 1).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Mutation counter (cache key half 2).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// View of one freeze unit's slice.
    pub fn unit<'a>(&'a self, m: &ModelManifest, u: usize) -> &'a [f32] {
        let s = m.unit_segments[u];
        &self.theta[s.offset..s.offset + s.len]
    }

    pub fn unit_mut<'a>(&'a mut self, m: &ModelManifest, u: usize) -> &'a mut [f32] {
        self.generation += 1;
        let s = m.unit_segments[u];
        &mut self.theta[s.offset..s.offset + s.len]
    }

    /// View of a named tensor.
    pub fn tensor<'a>(&'a self, m: &ModelManifest, name: &str) -> Result<&'a [f32]> {
        let t = m
            .tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("no tensor {name:?}"))?;
        Ok(&self.theta[t.offset..t.offset + t.size()])
    }

    /// Head weight column for class `c`: the row-major (H, C) weight matrix
    /// stores class `c` at stride C — returns (indices, bias_index).
    /// Used by CWR to copy/reset per-class discriminators.
    pub fn head_class_indices(m: &ModelManifest, c: usize) -> (Vec<usize>, usize) {
        let h = m.head.w_shape[0];
        let cdim = m.head.w_shape[1];
        debug_assert!(c < cdim);
        let idx = (0..h).map(|r| m.head.w_offset + r * cdim + c).collect();
        (idx, m.head.b_offset + c)
    }

    /// L2 norm of one unit's slice (used by SlimFit-style baselines).
    pub fn unit_norm(&self, m: &ModelManifest, u: usize) -> f32 {
        self.unit(m, u).iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// L1 of elementwise delta vs `other`, per unit.
    pub fn unit_delta_l1(&self, other: &Params, m: &ModelManifest, u: usize) -> f32 {
        self.unit(m, u)
            .iter()
            .zip(other.unit(m, u))
            .map(|(a, b)| (a - b).abs())
            .sum()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::runtime::artifact::{
        ArtifactNames, HeadInfo, ModelManifest, PaperUnit, Segment, TensorInfo,
    };

    pub(crate) fn toy_manifest() -> ModelManifest {
        // layout: embed.w (2x3=6) | head.w (3x4=12), head.b (4)
        ModelManifest {
            name: "toy".into(),
            d: 2,
            h: 3,
            blocks: 0,
            classes: 4,
            units: 2,
            kind: "relu_res".into(),
            theta_len: 22,
            batch_train: 16,
            batch_infer: 64,
            batch_probe: 16,
            unit_segments: vec![
                Segment { offset: 0, len: 6 },
                Segment { offset: 6, len: 16 },
            ],
            tensors: vec![
                TensorInfo { name: "embed.w".into(), shape: vec![2, 3], unit: 0, offset: 0 },
                TensorInfo { name: "head.w".into(), shape: vec![3, 4], unit: 1, offset: 6 },
                TensorInfo { name: "head.b".into(), shape: vec![4], unit: 1, offset: 18 },
            ],
            head: HeadInfo { w_offset: 6, w_shape: [3, 4], b_offset: 18, classes: 4 },
            paper_units: vec![
                PaperUnit { fwd_flops: 1e9, param_bytes: 1e6 },
                PaperUnit { fwd_flops: 1e8, param_bytes: 1e5 },
            ],
            artifacts: ArtifactNames::default(),
        }
    }

    #[test]
    fn rejects_wrong_length() {
        let m = toy_manifest();
        assert!(Params::new(vec![0.0; 3], &m).is_err());
        assert!(Params::new(vec![0.0; 22], &m).is_ok());
    }

    #[test]
    fn unit_views_are_disjoint_and_cover() {
        let m = toy_manifest();
        let p = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        assert_eq!(p.unit(&m, 0), &(0..6).map(|x| x as f32).collect::<Vec<_>>()[..]);
        assert_eq!(p.unit(&m, 1).len(), 16);
        assert_eq!(p.unit(&m, 1)[0], 6.0);
    }

    #[test]
    fn head_class_indices_stride_by_classes() {
        let m = toy_manifest();
        let (idx, b) = Params::head_class_indices(&m, 2);
        // head.w offset 6, shape (3,4): class-2 column = 6+2, 6+6, 6+10
        assert_eq!(idx, vec![8, 12, 16]);
        assert_eq!(b, 20);
    }

    #[test]
    fn named_tensor_view() {
        let m = toy_manifest();
        let p = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        assert_eq!(p.tensor(&m, "head.b").unwrap(), &[18.0, 19.0, 20.0, 21.0]);
        assert!(p.tensor(&m, "nope").is_err());
    }

    #[test]
    fn delta_l1_detects_change() {
        let m = toy_manifest();
        let a = Params::new(vec![0.0; 22], &m).unwrap();
        let mut b = a.clone();
        b.theta_mut()[1] = 2.0;
        b.theta_mut()[7] = -1.0;
        assert_eq!(a.unit_delta_l1(&b, &m, 0), 2.0);
        assert_eq!(a.unit_delta_l1(&b, &m, 1), 1.0);
    }

    #[test]
    fn generation_bumps_on_every_mutable_access() {
        let m = toy_manifest();
        let mut p = Params::new(vec![0.0; 22], &m).unwrap();
        let g0 = p.generation();
        let _ = p.theta(); // read: no bump
        assert_eq!(p.generation(), g0);
        p.theta_mut()[0] = 1.0;
        assert_eq!(p.generation(), g0 + 1);
        p.unit_mut(&m, 1)[0] = 2.0;
        assert_eq!(p.generation(), g0 + 2);
        p.set_theta(vec![0.0; 22]);
        assert_eq!(p.generation(), g0 + 3);
    }

    #[test]
    fn restore_keeps_identity_and_blocks_collisions() {
        let p = Params::from_vec(vec![1.0, 2.0]);
        let r = Params::restore(p.theta().to_vec(), p.id(), 7);
        assert_eq!(r.id(), p.id());
        assert_eq!(r.generation(), 7);
        assert_eq!(r.theta(), p.theta());
        // every allocation after a restore must get a strictly larger id
        let fresh = Params::from_vec(vec![0.0]);
        assert!(fresh.id() > r.id());
    }

    #[test]
    fn clones_get_fresh_identity_and_copy_from_keeps_it() {
        let m = toy_manifest();
        let a = Params::new(vec![1.0; 22], &m).unwrap();
        let b = a.clone();
        assert_ne!(a.id(), b.id());
        assert_eq!(a.theta(), b.theta());
        let mut c = Params::new(vec![0.0; 22], &m).unwrap();
        let cid = c.id();
        let g = c.generation();
        c.copy_from(&a);
        assert_eq!(c.id(), cid);
        assert_eq!(c.generation(), g + 1);
        assert_eq!(c.theta(), a.theta());
    }
}
