//! CopyWeights-with-Reinit (CWR) — the CORe50 paper's anti-forgetting
//! technique, applied by default in ETuner's experiments (paper §V-A).
//!
//! The head maintains two sets of per-class discriminators:
//!   * a *consolidated* bank holding the best weights learned for every
//!     class seen in past scenarios;
//!   * the *training* head that the current scenario fine-tunes.
//!
//! On a scenario change the coordinator (1) merges the rows of the classes
//! trained in the finished scenario into the bank (weighted by how often a
//! class has been seen), and (2) reinitializes the training rows of the
//! incoming scenario's classes.  At inference, the consolidated bank is
//! written into θ so past classes keep their discriminators.
//!
//! The bank carries a `generation` counter (bumped whenever consolidation
//! changes it) so the simulator's serving cache can tell whether a
//! previously bank-installed serving θ is still valid.

use crate::bitset::BitSet;
use crate::runtime::artifact::ModelManifest;

use super::params::Params;

#[derive(Clone, Debug)]
pub struct Cwr {
    /// consolidated per-class head weights: classes x (H+1) (bias last).
    bank: Vec<Vec<f32>>,
    /// how many scenarios contributed to each class's consolidated row.
    seen_count: Vec<u32>,
    /// bumped whenever the bank's contents change.
    generation: u64,
}

impl Cwr {
    pub fn new(m: &ModelManifest) -> Cwr {
        Cwr {
            bank: vec![vec![0.0; m.head.w_shape[0] + 1]; m.classes],
            seen_count: vec![0; m.classes],
            generation: 0,
        }
    }

    pub fn seen(&self, c: usize) -> bool {
        self.seen_count[c] > 0
    }

    /// Bank-content version (serving-cache invalidation key).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Checkpoint view: `(bank rows, seen counts, generation)`.
    pub fn ckpt_state(&self) -> (&[Vec<f32>], &[u32], u64) {
        (&self.bank, &self.seen_count, self.generation)
    }

    /// Rebuild from checkpointed state (exact generation included, so a
    /// restored serving cache keyed on it stays coherent).
    pub fn restore(
        bank: Vec<Vec<f32>>,
        seen_count: Vec<u32>,
        generation: u64,
    ) -> Cwr {
        Cwr { bank, seen_count, generation }
    }

    /// Merge one trained class row of θ into the bank (running average
    /// over scenarios, as CWR+ does).
    fn consolidate_class(&mut self, m: &ModelManifest, theta: &[f32], c: usize) {
        let h = m.head.w_shape[0];
        let cdim = m.head.w_shape[1];
        let n = self.seen_count[c] as f32;
        let row = &mut self.bank[c];
        for r in 0..h {
            let v = theta[m.head.w_offset + r * cdim + c];
            row[r] = (row[r] * n + v) / (n + 1.0);
        }
        row[h] = (row[h] * n + theta[m.head.b_offset + c]) / (n + 1.0);
        self.seen_count[c] += 1;
    }

    /// Merge the trained rows of `classes` from θ into the bank.
    pub fn consolidate(&mut self, m: &ModelManifest, p: &Params, classes: &[usize]) {
        if classes.is_empty() {
            return;
        }
        self.generation += 1;
        let theta = p.theta();
        for &c in classes {
            self.consolidate_class(m, theta, c);
        }
    }

    /// Bitset variant used by the simulator's trained-class accumulator
    /// (ascending order; the per-class merge is order-independent).
    pub fn consolidate_set(&mut self, m: &ModelManifest, p: &Params, classes: &BitSet) {
        if classes.is_empty() {
            return;
        }
        self.generation += 1;
        let theta = p.theta();
        // iterate via a local collect-free loop: BitSet::iter borrows
        // `classes`, which is disjoint from `self`.
        for c in classes.iter() {
            self.consolidate_class(m, theta, c);
        }
    }

    /// Write the consolidated bank into θ for every seen class (called
    /// before serving inference and at scenario start).
    pub fn install(&self, m: &ModelManifest, p: &mut Params) {
        let theta = p.theta_mut();
        for c in 0..m.classes {
            if self.seen_count[c] == 0 {
                continue;
            }
            self.write_class(m, theta, c);
        }
    }

    /// Write the bank into θ for every *seen* class not in `except`
    /// (serving-time install: classes of the live scenario keep their
    /// training rows).  O(classes) bit probes, no index vectors.
    pub fn install_except(&self, m: &ModelManifest, p: &mut Params, except: &BitSet) {
        let theta = p.theta_mut();
        for c in 0..m.classes {
            if self.seen_count[c] == 0 || except.contains(c) {
                continue;
            }
            self.write_class(m, theta, c);
        }
    }

    /// Build one scenario's serving θ into `dst`: copy the live `src`
    /// parameters (reusing `dst`'s allocation and identity) and install
    /// the consolidated bank for every seen class not in `except` — the
    /// live scenario's classes keep their training rows.  This is the
    /// primitive behind the serving engine's multi-head residency
    /// ([`crate::serve::BankSet`] keeps one such θ per active scenario);
    /// the two-step recipe is deliberately identical to what the old
    /// single-slot serving cache did, so bank contents are bit-identical
    /// to the pre-BankSet path.
    pub fn build_serving(
        &self,
        m: &ModelManifest,
        src: &Params,
        dst: &mut Params,
        except: &BitSet,
    ) {
        dst.copy_from(src);
        self.install_except(m, dst, except);
    }

    /// Write one class's consolidated row into θ.
    pub fn install_class(&self, m: &ModelManifest, p: &mut Params, c: usize) {
        self.write_class(m, p.theta_mut(), c);
    }

    fn write_class(&self, m: &ModelManifest, theta: &mut [f32], c: usize) {
        let h = m.head.w_shape[0];
        let cdim = m.head.w_shape[1];
        let row = &self.bank[c];
        for r in 0..h {
            theta[m.head.w_offset + r * cdim + c] = row[r];
        }
        theta[m.head.b_offset + c] = row[h];
    }

    /// Zero the training rows for `classes` (re-init on scenario entry so
    /// fresh classes start from a clean discriminator).
    pub fn reinit_rows(&self, m: &ModelManifest, p: &mut Params, classes: &[usize]) {
        if classes.is_empty() {
            return;
        }
        let h = m.head.w_shape[0];
        let cdim = m.head.w_shape[1];
        let theta = p.theta_mut();
        for &c in classes {
            for r in 0..h {
                theta[m.head.w_offset + r * cdim + c] = 0.0;
            }
            theta[m.head.b_offset + c] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests::toy_manifest;

    #[test]
    fn consolidate_then_install_roundtrips() {
        let m = toy_manifest();
        let mut p = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        let mut cwr = Cwr::new(&m);
        cwr.consolidate(&m, &p, &[1, 2]);
        assert!(cwr.seen(1) && cwr.seen(2) && !cwr.seen(0));
        // trash the head, install restores classes 1 and 2 only
        let orig = p.clone();
        for v in p.unit_mut(&m, 1) {
            *v = -99.0;
        }
        cwr.install(&m, &mut p);
        for c in [1usize, 2] {
            let (widx, bidx) = Params::head_class_indices(&m, c);
            for &i in &widx {
                assert_eq!(p.theta()[i], orig.theta()[i], "class {c} idx {i}");
            }
            assert_eq!(p.theta()[bidx], orig.theta()[bidx]);
        }
        let (w0, b0) = Params::head_class_indices(&m, 0);
        assert!(w0.iter().all(|&i| p.theta()[i] == -99.0));
        assert_eq!(p.theta()[b0], -99.0);
    }

    #[test]
    fn consolidation_averages_over_scenarios() {
        let m = toy_manifest();
        let mut cwr = Cwr::new(&m);
        let mut p = Params::new(vec![0.0; 22], &m).unwrap();
        let (widx, _) = Params::head_class_indices(&m, 3);
        p.theta_mut()[widx[0]] = 2.0;
        cwr.consolidate(&m, &p, &[3]);
        p.theta_mut()[widx[0]] = 4.0;
        cwr.consolidate(&m, &p, &[3]);
        let mut q = Params::new(vec![0.0; 22], &m).unwrap();
        cwr.install(&m, &mut q);
        assert_eq!(q.theta()[widx[0]], 3.0); // average of 2 and 4
    }

    #[test]
    fn reinit_zeroes_only_requested_rows() {
        let m = toy_manifest();
        let mut p = Params::new(vec![1.0; 22], &m).unwrap();
        let cwr = Cwr::new(&m);
        cwr.reinit_rows(&m, &mut p, &[0]);
        let (w0, b0) = Params::head_class_indices(&m, 0);
        assert!(w0.iter().all(|&i| p.theta()[i] == 0.0));
        assert_eq!(p.theta()[b0], 0.0);
        let (w1, _) = Params::head_class_indices(&m, 1);
        assert!(w1.iter().all(|&i| p.theta()[i] == 1.0));
    }

    #[test]
    fn install_except_skips_live_classes() {
        let m = toy_manifest();
        let mut p = Params::new(vec![5.0; 22], &m).unwrap();
        let mut cwr = Cwr::new(&m);
        cwr.consolidate(&m, &p, &[0, 1, 2]);
        // overwrite the whole head, then install all but class 1
        for v in p.unit_mut(&m, 1) {
            *v = -7.0;
        }
        let mut except = BitSet::new(m.classes);
        except.insert(1);
        cwr.install_except(&m, &mut p, &except);
        let (w0, b0) = Params::head_class_indices(&m, 0);
        assert!(w0.iter().all(|&i| p.theta()[i] == 5.0));
        assert_eq!(p.theta()[b0], 5.0);
        let (w1, b1) = Params::head_class_indices(&m, 1);
        assert!(w1.iter().all(|&i| p.theta()[i] == -7.0), "live class overwritten");
        assert_eq!(p.theta()[b1], -7.0);
        // class 3 was never consolidated: untouched
        let (w3, _) = Params::head_class_indices(&m, 3);
        assert!(w3.iter().all(|&i| p.theta()[i] == -7.0));
    }

    #[test]
    fn build_serving_equals_copy_plus_install_except() {
        let m = toy_manifest();
        let mut live = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        let mut cwr = Cwr::new(&m);
        cwr.consolidate(&m, &live, &[0, 1, 2]);
        live.theta_mut()[7] = -3.0; // diverge live θ from the bank

        let mut except = BitSet::new(m.classes);
        except.insert(1); // class 1 is "live": keeps its training row

        // reference: the old serving-cache recipe, step by step
        let mut want = live.clone();
        cwr.install_except(&m, &mut want, &except);

        let mut got = Params::new(vec![9.9; 22], &m).unwrap();
        let id = got.id();
        cwr.build_serving(&m, &live, &mut got, &except);
        assert_eq!(got.theta(), want.theta());
        assert_eq!(got.id(), id, "dst keeps its identity (in-place rebuild)");
    }

    #[test]
    fn generation_bumps_only_when_bank_changes() {
        let m = toy_manifest();
        let p = Params::new(vec![1.0; 22], &m).unwrap();
        let mut cwr = Cwr::new(&m);
        let g0 = cwr.generation();
        cwr.consolidate(&m, &p, &[]);
        assert_eq!(cwr.generation(), g0, "empty consolidation must not bump");
        cwr.consolidate(&m, &p, &[2]);
        assert_eq!(cwr.generation(), g0 + 1);
        let mut set = BitSet::new(m.classes);
        set.insert(0);
        cwr.consolidate_set(&m, &p, &set);
        assert_eq!(cwr.generation(), g0 + 2);
    }

    #[test]
    fn set_and_slice_consolidation_agree() {
        let m = toy_manifest();
        let mut p = Params::new((0..22).map(|x| x as f32 * 0.5).collect(), &m).unwrap();
        p.theta_mut()[7] = 3.25;
        let mut a = Cwr::new(&m);
        let mut b = Cwr::new(&m);
        a.consolidate(&m, &p, &[3, 0, 2]); // order must not matter
        let mut set = BitSet::new(m.classes);
        set.assign(&[0, 2, 3]);
        b.consolidate_set(&m, &p, &set);
        let mut qa = Params::new(vec![0.0; 22], &m).unwrap();
        let mut qb = Params::new(vec![0.0; 22], &m).unwrap();
        a.install(&m, &mut qa);
        b.install(&m, &mut qb);
        assert_eq!(qa.theta(), qb.theta());
    }
}
