//! CopyWeights-with-Reinit (CWR) — the CORe50 paper's anti-forgetting
//! technique, applied by default in ETuner's experiments (paper §V-A).
//!
//! The head maintains two sets of per-class discriminators:
//!   * a *consolidated* bank holding the best weights learned for every
//!     class seen in past scenarios;
//!   * the *training* head that the current scenario fine-tunes.
//!
//! On a scenario change the coordinator (1) merges the rows of the classes
//! trained in the finished scenario into the bank (weighted by how often a
//! class has been seen), and (2) reinitializes the training rows of the
//! incoming scenario's classes.  At inference, the consolidated bank is
//! written into θ so past classes keep their discriminators.

use crate::runtime::artifact::ModelManifest;

use super::params::Params;

#[derive(Clone, Debug)]
pub struct Cwr {
    /// consolidated per-class head weights: classes x (H+1) (bias last).
    bank: Vec<Vec<f32>>,
    /// how many scenarios contributed to each class's consolidated row.
    seen_count: Vec<u32>,
}

impl Cwr {
    pub fn new(m: &ModelManifest) -> Cwr {
        Cwr {
            bank: vec![vec![0.0; m.head.w_shape[0] + 1]; m.classes],
            seen_count: vec![0; m.classes],
        }
    }

    pub fn seen(&self, c: usize) -> bool {
        self.seen_count[c] > 0
    }

    /// Merge the trained rows of `classes` from θ into the bank
    /// (running average over scenarios, as CWR+ does).
    pub fn consolidate(&mut self, m: &ModelManifest, p: &Params, classes: &[usize]) {
        for &c in classes {
            let (widx, bidx) = Params::head_class_indices(m, c);
            let n = self.seen_count[c] as f32;
            let row = &mut self.bank[c];
            for (slot, &i) in row.iter_mut().zip(widx.iter()) {
                *slot = (*slot * n + p.theta[i]) / (n + 1.0);
            }
            let last = row.len() - 1;
            row[last] = (row[last] * n + p.theta[bidx]) / (n + 1.0);
            self.seen_count[c] += 1;
        }
    }

    /// Write the consolidated bank into θ for every seen class (called
    /// before serving inference and at scenario start).
    pub fn install(&self, m: &ModelManifest, p: &mut Params) {
        for c in 0..m.classes {
            if self.seen_count[c] == 0 {
                continue;
            }
            self.install_class(m, p, c);
        }
    }

    /// Write one class's consolidated row into θ.
    pub fn install_class(&self, m: &ModelManifest, p: &mut Params, c: usize) {
        let (widx, bidx) = Params::head_class_indices(m, c);
        let row = &self.bank[c];
        for (&i, &v) in widx.iter().zip(row.iter()) {
            p.theta[i] = v;
        }
        p.theta[bidx] = row[row.len() - 1];
    }

    /// Zero the training rows for `classes` (re-init on scenario entry so
    /// fresh classes start from a clean discriminator).
    pub fn reinit_rows(&self, m: &ModelManifest, p: &mut Params, classes: &[usize]) {
        for &c in classes {
            let (widx, bidx) = Params::head_class_indices(m, c);
            for &i in &widx {
                p.theta[i] = 0.0;
            }
            p.theta[bidx] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests::toy_manifest;

    #[test]
    fn consolidate_then_install_roundtrips() {
        let m = toy_manifest();
        let mut p = Params::new((0..22).map(|x| x as f32).collect(), &m).unwrap();
        let mut cwr = Cwr::new(&m);
        cwr.consolidate(&m, &p, &[1, 2]);
        assert!(cwr.seen(1) && cwr.seen(2) && !cwr.seen(0));
        // trash the head, install restores classes 1 and 2 only
        let orig = p.clone();
        for v in p.unit_mut(&m, 1) {
            *v = -99.0;
        }
        cwr.install(&m, &mut p);
        for c in [1usize, 2] {
            let (widx, bidx) = Params::head_class_indices(&m, c);
            for &i in &widx {
                assert_eq!(p.theta[i], orig.theta[i], "class {c} idx {i}");
            }
            assert_eq!(p.theta[bidx], orig.theta[bidx]);
        }
        let (w0, b0) = Params::head_class_indices(&m, 0);
        assert!(w0.iter().all(|&i| p.theta[i] == -99.0));
        assert_eq!(p.theta[b0], -99.0);
    }

    #[test]
    fn consolidation_averages_over_scenarios() {
        let m = toy_manifest();
        let mut cwr = Cwr::new(&m);
        let mut p = Params::new(vec![0.0; 22], &m).unwrap();
        let (widx, _) = Params::head_class_indices(&m, 3);
        p.theta[widx[0]] = 2.0;
        cwr.consolidate(&m, &p, &[3]);
        p.theta[widx[0]] = 4.0;
        cwr.consolidate(&m, &p, &[3]);
        let mut q = Params::new(vec![0.0; 22], &m).unwrap();
        cwr.install(&m, &mut q);
        assert_eq!(q.theta[widx[0]], 3.0); // average of 2 and 4
    }

    #[test]
    fn reinit_zeroes_only_requested_rows() {
        let m = toy_manifest();
        let mut p = Params::new(vec![1.0; 22], &m).unwrap();
        let cwr = Cwr::new(&m);
        cwr.reinit_rows(&m, &mut p, &[0]);
        let (w0, b0) = Params::head_class_indices(&m, 0);
        assert!(w0.iter().all(|&i| p.theta[i] == 0.0));
        assert_eq!(p.theta[b0], 0.0);
        let (w1, _) = Params::head_class_indices(&m, 1);
        assert!(w1.iter().all(|&i| p.theta[i] == 1.0));
    }
}
