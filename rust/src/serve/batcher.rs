//! Cross-request batching: coalesce queued requests into one padded
//! fixed-shape artifact execution and scatter per-request rows back out.
//!
//! The infer artifacts are AOT-lowered at a fixed `[batch_infer, d]` shape,
//! so the seed implementation paid one full-batch execute per request no
//! matter how few rows the request actually needed.  The batcher packs up
//! to `capacity_rows` rows from consecutive same-scenario requests into one
//! execute (remaining rows are zero-padded; the models are row-wise, so
//! padding rows cannot perturb real rows) and the per-request outputs are
//! recovered by row spans.
//!
//! Flush rules (checked in virtual time, so they are seed-deterministic):
//! * the batch is full (`rows_pending == capacity_rows`), or a request
//!   would overflow it;
//! * the oldest queued request has waited `window_s` (window 0 degenerates
//!   to one-request batches — bit-identical to unbatched serving);
//! * deadline-aware flush (opt-in via [`AdaptiveBatcher::with_deadline_slack`]):
//!   the oldest request's SLO deadline minus the service time is about to
//!   pass — waiting any longer would guarantee a violation, so the window
//!   is cut short;
//! * an arriving request belongs to a different scenario than the queued
//!   ones (serving θ is scenario-dependent);
//! * the simulation drains the queue (end of stream, or a fine-tuning
//!   round is about to occupy the device).

use super::queue::{QueuedRequest, RequestQueue};

/// Rows `row0 .. row0 + rows` of the padded batch belong to request
/// `index` (position in the flushed batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchSpan {
    pub index: usize,
    pub row0: usize,
    pub rows: usize,
}

/// One packed execute: padded row-major input plus the scatter map.
#[derive(Clone, Debug)]
pub struct PaddedBatch {
    /// `[capacity_rows, d]` row-major; rows past `rows_used` are zeros.
    pub x: Vec<f32>,
    pub spans: Vec<BatchSpan>,
    pub rows_used: usize,
    pub capacity_rows: usize,
}

/// Batching policy + pack/scatter mechanics.
#[derive(Clone, Debug)]
pub struct AdaptiveBatcher {
    /// Rows per execute (the artifact's `batch_infer`).
    pub capacity_rows: usize,
    /// Virtual-time coalescing window in seconds (0 = no coalescing).
    pub window_s: f64,
    /// Feature dimension.
    pub d: usize,
    /// `Some(service_s)`: cut the window short so the oldest request can
    /// still meet its `deadline_t` after a `service_s`-long execute.
    deadline_slack_s: Option<f64>,
}

impl AdaptiveBatcher {
    pub fn new(capacity_rows: usize, window_s: f64, d: usize) -> AdaptiveBatcher {
        AdaptiveBatcher { capacity_rows, window_s, d, deadline_slack_s: None }
    }

    /// Enable deadline-aware flushing: a batch never waits past the oldest
    /// request's `deadline_t - slack_s` (but also never flushes before the
    /// request arrived).
    pub fn with_deadline_slack(mut self, slack_s: f64) -> AdaptiveBatcher {
        self.deadline_slack_s = Some(slack_s);
        self
    }

    /// True when the oldest queued request's window (or SLO slack) has
    /// expired at `now` (its batch must be flushed at `due_t`, `<= now`).
    pub fn due(&self, queue: &RequestQueue, now: f64) -> bool {
        self.due_t(queue).is_some_and(|due| due <= now)
    }

    /// Flush deadline of the current batch: the oldest request's arrival +
    /// window, pulled forward to its SLO deadline minus the service slack
    /// when deadline-aware flushing is on.
    pub fn due_t(&self, queue: &RequestQueue) -> Option<f64> {
        queue.front().map(|r| {
            let mut due = r.arrival_t + self.window_s;
            if let Some(slack) = self.deadline_slack_s {
                due = due.min(r.deadline_t - slack).max(r.arrival_t);
            }
            due
        })
    }

    /// True when the queue must flush *before* accepting a request of
    /// `scenario`/`rows` (scenario boundary or row-capacity overflow).
    pub fn must_flush_before(
        &self,
        queue: &RequestQueue,
        scenario: usize,
        rows: usize,
    ) -> bool {
        match queue.front() {
            None => false,
            Some(front) => {
                front.scenario != scenario
                    || queue.rows_pending() + rows > self.capacity_rows
            }
        }
    }

    /// Pop one batch worth of requests: consecutive same-scenario requests
    /// until row capacity.  Returns an empty vec on an empty queue.
    pub fn take_batch(&self, queue: &mut RequestQueue) -> Vec<QueuedRequest> {
        let mut batch: Vec<QueuedRequest> = Vec::new();
        let mut rows = 0usize;
        while let Some(front) = queue.front() {
            if !batch.is_empty()
                && (front.scenario != batch[0].scenario
                    || rows + front.rows > self.capacity_rows)
            {
                break;
            }
            rows += front.rows;
            batch.push(queue.pop().unwrap());
            if rows >= self.capacity_rows {
                break;
            }
        }
        batch
    }

    /// Pack `batch` into a zero-padded `[capacity_rows, d]` input, reusing
    /// `scratch` as the output allocation.
    pub fn pack_into(&self, batch: &[QueuedRequest], scratch: &mut Vec<f32>) -> PaddedBatch {
        let mut x = std::mem::take(scratch);
        x.clear();
        x.resize(self.capacity_rows * self.d, 0.0);
        let mut spans = Vec::with_capacity(batch.len());
        let mut row = 0usize;
        for (index, req) in batch.iter().enumerate() {
            debug_assert_eq!(req.x.len(), req.rows * self.d);
            debug_assert!(row + req.rows <= self.capacity_rows, "batch overflow");
            x[row * self.d..(row + req.rows) * self.d].copy_from_slice(&req.x);
            spans.push(BatchSpan { index, row0: row, rows: req.rows });
            row += req.rows;
        }
        PaddedBatch { x, spans, rows_used: row, capacity_rows: self.capacity_rows }
    }

    /// Pack without a reusable scratch buffer (tests/benches).
    pub fn pack(&self, batch: &[QueuedRequest]) -> PaddedBatch {
        let mut scratch = Vec::new();
        self.pack_into(batch, &mut scratch)
    }
}

/// Scatter helper: the rows of `flat` (row-major, `width` values per row)
/// belonging to `span`.
pub fn span_rows<'a>(flat: &'a [f32], width: usize, span: &BatchSpan) -> &'a [f32] {
    &flat[span.row0 * width..(span.row0 + span.rows) * width]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: f64, scenario: usize, rows: usize, fill: f32) -> QueuedRequest {
        QueuedRequest {
            arrival_t: t,
            deadline_t: t + 1.0,
            scenario,
            stale_batches: 0,
            x: vec![fill; rows * 3],
            y: vec![1; rows],
            rows,
        }
    }

    fn batcher() -> AdaptiveBatcher {
        AdaptiveBatcher::new(8, 5.0, 3)
    }

    #[test]
    fn window_due_anchors_on_oldest() {
        let b = batcher();
        let mut q = RequestQueue::new();
        assert!(!b.due(&q, 100.0));
        q.push(req(10.0, 1, 2, 0.0));
        q.push(req(14.0, 1, 2, 0.0));
        assert!(!b.due(&q, 14.9));
        assert!(b.due(&q, 15.0));
        assert_eq!(b.due_t(&q), Some(15.0));
    }

    #[test]
    fn deadline_slack_pulls_the_flush_forward() {
        // window would flush at 15.0, but the oldest request's deadline
        // (10.0 + 1.0) minus the 0.4s service slack pulls it to 10.6.
        let b = batcher().with_deadline_slack(0.4);
        let mut q = RequestQueue::new();
        q.push(req(10.0, 1, 2, 0.0));
        assert_eq!(b.due_t(&q), Some(10.6));
        assert!(!b.due(&q, 10.5));
        assert!(b.due(&q, 10.6));
        // slack larger than the whole SLO never flushes before arrival
        let b = batcher().with_deadline_slack(5.0);
        assert_eq!(b.due_t(&q), Some(10.0));
    }

    #[test]
    fn scenario_and_capacity_cut_batches() {
        let b = batcher();
        let mut q = RequestQueue::new();
        q.push(req(1.0, 1, 4, 0.0));
        assert!(b.must_flush_before(&q, 2, 1), "scenario boundary");
        assert!(!b.must_flush_before(&q, 1, 4), "exactly fills capacity");
        assert!(b.must_flush_before(&q, 1, 5), "overflow");

        q.push(req(2.0, 1, 4, 0.0));
        q.push(req(3.0, 2, 2, 0.0));
        let first = b.take_batch(&mut q);
        assert_eq!(first.len(), 2, "same-scenario requests coalesce");
        let second = b.take_batch(&mut q);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].scenario, 2);
        assert!(b.take_batch(&mut q).is_empty());
    }

    #[test]
    fn pack_zero_pads_and_spans_cover_rows() {
        let b = batcher();
        let batch = vec![req(1.0, 1, 2, 1.5), req(2.0, 1, 3, 2.5)];
        let p = b.pack(&batch);
        assert_eq!(p.x.len(), 8 * 3);
        assert_eq!(p.rows_used, 5);
        assert_eq!(
            p.spans,
            vec![
                BatchSpan { index: 0, row0: 0, rows: 2 },
                BatchSpan { index: 1, row0: 2, rows: 3 },
            ]
        );
        assert!(p.x[..6].iter().all(|&v| v == 1.5));
        assert!(p.x[6..15].iter().all(|&v| v == 2.5));
        assert!(p.x[15..].iter().all(|&v| v == 0.0), "padding rows are zero");
        assert_eq!(span_rows(&p.x, 3, &p.spans[1]).len(), 9);
    }

    #[test]
    fn packed_rowwise_model_matches_single_executes() {
        // N requests through one padded execute == N one-request executes,
        // for any row-wise model (here: f(row) = [sum, max] per row).
        let b = AdaptiveBatcher::new(16, 0.0, 3);
        let rowwise = |x: &[f32], rows: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(rows * 2);
            for r in 0..rows {
                let row = &x[r * 3..(r + 1) * 3];
                out.push(row.iter().sum());
                out.push(row.iter().cloned().fold(f32::NEG_INFINITY, f32::max));
            }
            out
        };
        let reqs: Vec<QueuedRequest> = (0..4)
            .map(|i| {
                let rows = i + 1;
                QueuedRequest {
                    arrival_t: i as f64,
                    deadline_t: i as f64 + 1.0,
                    scenario: 3,
                    stale_batches: 0,
                    x: (0..rows * 3).map(|k| (i * 7 + k) as f32 * 0.5).collect(),
                    y: vec![0; rows],
                    rows,
                }
            })
            .collect();

        let packed = b.pack(&reqs);
        let batched_out = rowwise(&packed.x, packed.capacity_rows);
        for (req, span) in reqs.iter().zip(&packed.spans) {
            let single = b.pack(std::slice::from_ref(req));
            let single_out = rowwise(&single.x, single.capacity_rows);
            let got = span_rows(&batched_out, 2, span);
            let want = &single_out[..req.rows * 2];
            assert_eq!(got, want, "request {} diverged", span.index);
        }
    }
}
