//! Cross-request batching: coalesce queued requests into one padded
//! fixed-shape artifact execution and scatter per-request rows back out.
//!
//! The infer artifacts are AOT-lowered at a fixed `[batch_infer, d]` shape,
//! so the seed implementation paid one full-batch execute per request no
//! matter how few rows the request actually needed.  The batcher packs up
//! to `capacity_rows` rows into one execute (remaining rows are
//! zero-padded; the models are row-wise, so padding rows cannot perturb
//! real rows) and the per-request outputs are recovered by row spans.
//!
//! Since the scenario-sharded control plane (PR 5) the batcher no longer
//! cuts batches at scenario boundaries: the engine keeps one resident
//! serving θ per active scenario (see [`crate::serve::BankSet`]), so a
//! batch may hold *mixed-scenario* requests — the engine groups them by
//! scenario at execute time and scatters each request's predictions
//! through the right head.  Pop order is delegated to the engine's
//! [`AdmissionPolicy`] (FIFO or EDF), and the one remaining cut predicate
//! — row capacity — lives in a single shared function
//! ([`AdaptiveBatcher::fits`]; the seed duplicated it between its
//! admission-time `must_flush_before` check and the pop loop).
//!
//! Flush rules (checked in virtual time, so they are seed-deterministic):
//! * the queue holds at least one full execute's worth of rows
//!   ([`AdaptiveBatcher::capacity_reached`] — covers both the seed's
//!   exact-fill and would-overflow triggers);
//! * *some* queued request has waited `window_s` — the due anchor is the
//!   queue-wide minimum, not the policy-next request, so EDF's
//!   re-anchoring on ever-more-urgent arrivals can never starve an old
//!   request's expired window (window 0 degenerates to one-request
//!   batches — bit-identical to unbatched serving);
//! * deadline-aware flush (opt-in via [`AdaptiveBatcher::with_deadline_slack`]):
//!   some queued request's SLO deadline minus the service time is about
//!   to pass — waiting any longer would guarantee a violation, so the
//!   window is cut short;
//! * the simulation drains the queue (end of stream, or a fine-tuning
//!   round is about to occupy the device).

use super::admission::AdmissionPolicy;
use super::queue::{QueuedRequest, RequestQueue};

/// Rows `row0 .. row0 + rows` of the padded batch belong to request
/// `index` (position in the flushed batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchSpan {
    pub index: usize,
    pub row0: usize,
    pub rows: usize,
}

/// One packed execute: padded row-major input plus the scatter map.
#[derive(Clone, Debug)]
pub struct PaddedBatch {
    /// `[capacity_rows, d]` row-major; rows past `rows_used` are zeros.
    pub x: Vec<f32>,
    pub spans: Vec<BatchSpan>,
    pub rows_used: usize,
    pub capacity_rows: usize,
}

/// Batching policy + pack/scatter mechanics.
#[derive(Clone, Debug)]
pub struct AdaptiveBatcher {
    /// Rows per execute (the artifact's `batch_infer`).
    pub capacity_rows: usize,
    /// Virtual-time coalescing window in seconds (0 = no coalescing).
    pub window_s: f64,
    /// Feature dimension.
    pub d: usize,
    /// `Some(service_s)`: cut the window short so the policy-next request
    /// can still meet its `deadline_t` after a `service_s`-long execute.
    deadline_slack_s: Option<f64>,
}

impl AdaptiveBatcher {
    pub fn new(capacity_rows: usize, window_s: f64, d: usize) -> AdaptiveBatcher {
        AdaptiveBatcher { capacity_rows, window_s, d, deadline_slack_s: None }
    }

    /// Enable deadline-aware flushing: a batch never waits past the
    /// policy-next request's `deadline_t - slack_s` (but also never
    /// flushes before the request arrived).
    pub fn with_deadline_slack(mut self, slack_s: f64) -> AdaptiveBatcher {
        self.deadline_slack_s = Some(slack_s);
        self
    }

    /// THE batch-cut predicate: can a `req_rows`-row request join a batch
    /// already holding `rows` rows?  Shared by the pop loop and the
    /// capacity flush trigger — the seed duplicated this logic between
    /// `must_flush_before` and `take_batch`, which is exactly where the
    /// two paths would have drifted when the redesign dropped the
    /// scenario-boundary half of the old condition.
    pub fn fits(&self, rows: usize, req_rows: usize) -> bool {
        rows + req_rows <= self.capacity_rows
    }

    /// True when the queue holds at least one full execute of rows: the
    /// capacity flush trigger (equivalent to the seed's exact-fill and
    /// would-overflow checks combined, since an arriving request is now
    /// enqueued *before* the flush decision).
    pub fn capacity_reached(&self, rows_pending: usize) -> bool {
        !self.fits(rows_pending, 1)
    }

    /// True when some queued request's window (or SLO slack) has expired
    /// at `now` (a batch must be flushed at `due_t`, `<= now`).
    pub fn due(&self, queue: &RequestQueue, now: f64) -> bool {
        self.due_t(queue).is_some_and(|due| due <= now)
    }

    /// One request's flush deadline: its arrival + window, pulled forward
    /// to its SLO deadline minus the service slack when deadline-aware
    /// flushing is on (but never before the request arrived).
    fn request_due(&self, r: &QueuedRequest) -> f64 {
        let mut due = r.arrival_t + self.window_s;
        if let Some(slack) = self.deadline_slack_s {
            due = due.min(r.deadline_t - slack).max(r.arrival_t);
        }
        due
    }

    /// Flush deadline of the queue: the *minimum* per-request due time
    /// over everything queued.  Anchoring on the minimum — not on the
    /// policy-next request — is what keeps the window guarantee under
    /// EDF: a stream of ever-more-urgent arrivals re-anchors the policy
    /// head forever, but the oldest request's expired window still
    /// forces a flush.  Under FIFO with a uniform SLO the minimum IS the
    /// front request, so the seed behaviour is unchanged.
    ///
    /// The scan is O(queue depth) per call — deliberate: every flush
    /// already does O(depth · rows · d) pack/execute work, so a few f64
    /// compares per queued request cannot dominate; a running-min
    /// structure would only pay off if deadlines stopped being per-pop
    /// removable (revisit if profiles ever disagree).
    pub fn due_t(&self, queue: &RequestQueue) -> Option<f64> {
        queue
            .iter()
            .map(|r| self.request_due(r))
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Pop one batch worth of requests in policy order until row capacity.
    /// Scenarios may mix — the engine re-groups them per execute.  Returns
    /// an empty vec on an empty queue.
    pub fn take_batch(
        &self,
        queue: &mut RequestQueue,
        policy: &dyn AdmissionPolicy,
    ) -> Vec<QueuedRequest> {
        let mut batch: Vec<QueuedRequest> = Vec::new();
        let mut rows = 0usize;
        while let Some(i) = policy.next_index(queue) {
            let next_rows = queue.get(i).unwrap().rows;
            if !batch.is_empty() && !self.fits(rows, next_rows) {
                break;
            }
            rows += next_rows;
            batch.push(queue.remove(i).unwrap());
            if rows >= self.capacity_rows {
                break;
            }
        }
        batch
    }

    /// Pack `batch` into a zero-padded `[capacity_rows, d]` input, reusing
    /// `scratch` as the output allocation.  All requests must share one
    /// scenario (the engine packs per scenario group).
    pub fn pack_into(&self, batch: &[QueuedRequest], scratch: &mut Vec<f32>) -> PaddedBatch {
        let mut x = std::mem::take(scratch);
        x.clear();
        x.resize(self.capacity_rows * self.d, 0.0);
        let mut spans = Vec::with_capacity(batch.len());
        let mut row = 0usize;
        for (index, req) in batch.iter().enumerate() {
            debug_assert_eq!(req.x.len(), req.rows * self.d);
            debug_assert!(row + req.rows <= self.capacity_rows, "batch overflow");
            debug_assert_eq!(req.scenario, batch[0].scenario, "mixed-scenario pack");
            x[row * self.d..(row + req.rows) * self.d].copy_from_slice(&req.x);
            spans.push(BatchSpan { index, row0: row, rows: req.rows });
            row += req.rows;
        }
        PaddedBatch { x, spans, rows_used: row, capacity_rows: self.capacity_rows }
    }

    /// Pack without a reusable scratch buffer (tests/benches).
    pub fn pack(&self, batch: &[QueuedRequest]) -> PaddedBatch {
        let mut scratch = Vec::new();
        self.pack_into(batch, &mut scratch)
    }
}

/// Scatter helper: the rows of `flat` (row-major, `width` values per row)
/// belonging to `span`.
pub fn span_rows<'a>(flat: &'a [f32], width: usize, span: &BatchSpan) -> &'a [f32] {
    &flat[span.row0 * width..(span.row0 + span.rows) * width]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::admission::{Edf, Fifo};

    fn req(t: f64, scenario: usize, rows: usize, fill: f32) -> QueuedRequest {
        QueuedRequest {
            arrival_t: t,
            deadline_t: t + 1.0,
            scenario,
            stale_batches: 0,
            x: vec![fill; rows * 3],
            y: vec![1; rows],
            rows,
        }
    }

    fn batcher() -> AdaptiveBatcher {
        AdaptiveBatcher::new(8, 5.0, 3)
    }

    #[test]
    fn window_due_anchors_on_the_earliest_due_in_the_queue() {
        let b = batcher();
        let mut q = RequestQueue::new();
        assert!(!b.due(&q, 100.0));
        q.push(req(10.0, 1, 2, 0.0));
        q.push(req(14.0, 1, 2, 0.0));
        assert!(!b.due(&q, 14.9));
        assert!(b.due(&q, 15.0));
        assert_eq!(b.due_t(&q), Some(15.0));
        // the anchor is the queue-wide minimum, independent of pop
        // policy: an urgent late arrival must not defer the oldest
        // request's expired window (EDF starvation guard)
        let mut q = RequestQueue::new();
        q.push(req(10.0, 1, 2, 0.0)); // due 15.0
        let mut urgent = req(12.0, 1, 2, 0.0);
        urgent.deadline_t = 10.5; // inverted: later arrival, earlier due
        q.push(urgent); // due 17.0
        assert_eq!(b.due_t(&q), Some(15.0), "oldest window still anchors");
    }

    #[test]
    fn deadline_slack_pulls_the_flush_forward() {
        // window would flush at 15.0, but the oldest request's deadline
        // (10.0 + 1.0) minus the 0.4s service slack pulls it to 10.6.
        let b = batcher().with_deadline_slack(0.4);
        let mut q = RequestQueue::new();
        q.push(req(10.0, 1, 2, 0.0));
        assert_eq!(b.due_t(&q), Some(10.6));
        assert!(!b.due(&q, 10.5));
        assert!(b.due(&q, 10.6));
        // slack larger than the whole SLO never flushes before arrival
        let b = batcher().with_deadline_slack(5.0);
        assert_eq!(b.due_t(&q), Some(10.0));
        // a deadline-tight LATER arrival pulls the queue-wide due below
        // the front's: the minimum anchor honours it
        let b = batcher().with_deadline_slack(0.4);
        let mut q = RequestQueue::new();
        q.push(req(10.0, 1, 2, 0.0)); // due 10.6
        let mut tight = req(10.2, 1, 2, 0.0);
        tight.deadline_t = 10.5; // due = max(10.2, 10.5 - 0.4) = 10.2
        q.push(tight);
        assert_eq!(b.due_t(&q), Some(10.2));
    }

    #[test]
    fn capacity_cuts_batches_but_scenarios_mix() {
        let b = batcher();
        assert!(b.fits(4, 4), "exactly fills capacity");
        assert!(!b.fits(4, 5), "overflow");
        assert!(!b.capacity_reached(7));
        assert!(b.capacity_reached(8));
        assert!(b.capacity_reached(9));

        let mut q = RequestQueue::new();
        q.push(req(1.0, 1, 4, 0.0));
        q.push(req(2.0, 2, 2, 0.0)); // different scenario: no longer a cut
        q.push(req(3.0, 1, 4, 0.0)); // would overflow (4+2+4 > 8)
        let first = b.take_batch(&mut q, &Fifo);
        assert_eq!(first.len(), 2, "mixed-scenario requests coalesce");
        assert_eq!(first[0].scenario, 1);
        assert_eq!(first[1].scenario, 2);
        let second = b.take_batch(&mut q, &Fifo);
        assert_eq!(second.len(), 1);
        assert!(b.take_batch(&mut q, &Fifo).is_empty());
    }

    #[test]
    fn edf_pops_deadline_order_without_backfill() {
        let b = batcher();
        let mut q = RequestQueue::new();
        let mut a = req(1.0, 1, 4, 0.0);
        a.deadline_t = 9.0;
        let mut c = req(2.0, 2, 6, 0.0);
        c.deadline_t = 3.0; // most urgent but 6 rows
        let mut d = req(3.0, 1, 2, 0.0);
        d.deadline_t = 5.0;
        q.push(a);
        q.push(c);
        q.push(d);
        // EDF: c (6 rows) then d (2 rows) exactly fill; a waits — strict
        // deadline order, no backfilling around the capacity cut.
        let batch = b.take_batch(&mut q, &Edf);
        assert_eq!(
            batch.iter().map(|r| r.arrival_t).collect::<Vec<_>>(),
            vec![2.0, 3.0]
        );
        assert_eq!(q.len(), 1);
        assert_eq!(q.front().unwrap().arrival_t, 1.0);
    }

    #[test]
    fn pack_zero_pads_and_spans_cover_rows() {
        let b = batcher();
        let batch = vec![req(1.0, 1, 2, 1.5), req(2.0, 1, 3, 2.5)];
        let p = b.pack(&batch);
        assert_eq!(p.x.len(), 8 * 3);
        assert_eq!(p.rows_used, 5);
        assert_eq!(
            p.spans,
            vec![
                BatchSpan { index: 0, row0: 0, rows: 2 },
                BatchSpan { index: 1, row0: 2, rows: 3 },
            ]
        );
        assert!(p.x[..6].iter().all(|&v| v == 1.5));
        assert!(p.x[6..15].iter().all(|&v| v == 2.5));
        assert!(p.x[15..].iter().all(|&v| v == 0.0), "padding rows are zero");
        assert_eq!(span_rows(&p.x, 3, &p.spans[1]).len(), 9);
    }

    #[test]
    fn packed_rowwise_model_matches_single_executes() {
        // N requests through one padded execute == N one-request executes,
        // for any row-wise model (here: f(row) = [sum, max] per row).
        let b = AdaptiveBatcher::new(16, 0.0, 3);
        let rowwise = |x: &[f32], rows: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(rows * 2);
            for r in 0..rows {
                let row = &x[r * 3..(r + 1) * 3];
                out.push(row.iter().sum());
                out.push(row.iter().cloned().fold(f32::NEG_INFINITY, f32::max));
            }
            out
        };
        let reqs: Vec<QueuedRequest> = (0..4)
            .map(|i| {
                let rows = i + 1;
                QueuedRequest {
                    arrival_t: i as f64,
                    deadline_t: i as f64 + 1.0,
                    scenario: 3,
                    stale_batches: 0,
                    x: (0..rows * 3).map(|k| (i * 7 + k) as f32 * 0.5).collect(),
                    y: vec![0; rows],
                    rows,
                }
            })
            .collect();

        let packed = b.pack(&reqs);
        let batched_out = rowwise(&packed.x, packed.capacity_rows);
        for (req, span) in reqs.iter().zip(&packed.spans) {
            let single = b.pack(std::slice::from_ref(req));
            let single_out = rowwise(&single.x, single.capacity_rows);
            let got = span_rows(&batched_out, 2, span);
            let want = &single_out[..req.rows * 2];
            assert_eq!(got, want, "request {} diverged", span.index);
        }
    }
}
