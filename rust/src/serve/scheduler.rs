//! Tune-vs-serve arbitration for the single edge accelerator.
//!
//! The device executes one artifact at a time: a fine-tuning round and an
//! inference batch contend for it.  The scheduler keeps the device-busy
//! horizon in virtual time — requests flushed while a round runs start
//! after it and pay the delay — and may *defer* a triggered round when the
//! serving backlog is deep (bounded by a consecutive-defer cap so training
//! never starves).  With batching disabled the queue is always empty at
//! trigger time, so the scheduler never changes the seed behaviour.

/// Outcome of a round-trigger arbitration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundDecision {
    /// Run the round now (after draining pending requests).
    Proceed,
    /// Serve the backlog first; re-evaluate at the next trigger.
    Defer,
}

#[derive(Clone, Debug)]
pub struct Scheduler {
    /// Virtual time at which the device finishes its current work.
    device_free_at: f64,
    /// Queue depth at which a triggered round is deferred (0 = never).
    defer_backlog: usize,
    /// Starvation guard: max rounds deferred back-to-back.
    max_defers: u32,
    consecutive_defers: u32,
    rounds_deferred: u64,
    /// Accumulated device occupancy by serving executes (virtual s) —
    /// the time-in-state readout behind `Report::time_serving_s`.
    serve_busy_s: f64,
    /// Accumulated device occupancy by fine-tuning rounds (virtual s).
    round_busy_s: f64,
}

impl Scheduler {
    pub fn new(defer_backlog: usize, max_defers: u32) -> Scheduler {
        Scheduler {
            device_free_at: 0.0,
            defer_backlog,
            max_defers,
            consecutive_defers: 0,
            rounds_deferred: 0,
            serve_busy_s: 0.0,
            round_busy_s: 0.0,
        }
    }

    pub fn device_free_at(&self) -> f64 {
        self.device_free_at
    }

    pub fn rounds_deferred(&self) -> u64 {
        self.rounds_deferred
    }

    /// Total virtual device time spent executing serving batches.
    pub fn serve_busy_s(&self) -> f64 {
        self.serve_busy_s
    }

    /// Total virtual device time spent inside fine-tuning rounds.
    pub fn round_busy_s(&self) -> f64 {
        self.round_busy_s
    }

    /// Admit one serving execute due at `due_t`; returns its service start
    /// (the later of the deadline and the device-busy horizon) and extends
    /// the horizon by `service_s`.
    pub fn admit_serve(&mut self, due_t: f64, service_s: f64) -> f64 {
        let start = due_t.max(self.device_free_at);
        self.device_free_at = start + service_s;
        self.serve_busy_s += service_s;
        start
    }

    /// Soonest virtual time one `service_s`-long execute could complete
    /// for work arriving at `t`, if it were served ahead of everything
    /// queued.  This is the optimistic bound the control plane's
    /// SLO-infeasibility shedder tests: a request whose deadline precedes
    /// even this can never be met, so admitting it only wastes an execute.
    pub fn earliest_completion(&self, t: f64, service_s: f64) -> f64 {
        t.max(self.device_free_at) + service_s
    }

    /// Arbitrate a triggered fine-tuning round against `backlog` pending
    /// requests.
    pub fn consider_round(&mut self, backlog: usize) -> RoundDecision {
        let defer = self.defer_backlog > 0
            && backlog >= self.defer_backlog
            && self.consecutive_defers < self.max_defers;
        if defer {
            self.consecutive_defers += 1;
            self.rounds_deferred += 1;
            RoundDecision::Defer
        } else {
            self.consecutive_defers = 0;
            RoundDecision::Proceed
        }
    }

    /// A round started at `t` and occupies the device for `duration_s`
    /// (virtual seconds from the cost ledger).
    pub fn on_round(&mut self, t: f64, duration_s: f64) {
        let start = t.max(self.device_free_at);
        self.device_free_at = start + duration_s;
        self.round_busy_s += duration_s;
    }

    /// Checkpoint every mutable field.  `device_free_at` and
    /// `consecutive_defers` shape future round/serve decisions, so they
    /// are fingerprint-relevant state; the busy accumulators only feed the
    /// time-in-state readout but round-trip anyway so resumed reports stay
    /// self-consistent past the resume point.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.f64(self.device_free_at);
        w.usize(self.defer_backlog);
        w.u32(self.max_defers);
        w.u32(self.consecutive_defers);
        w.u64(self.rounds_deferred);
        w.f64(self.serve_busy_s);
        w.f64(self.round_busy_s);
    }

    /// Restore state saved by [`Scheduler::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        self.device_free_at = r.f64()?;
        self.defer_backlog = r.usize()?;
        self.max_defers = r.u32()?;
        self.consecutive_defers = r.u32()?;
        self.rounds_deferred = r.u64()?;
        self.serve_busy_s = r.f64()?;
        self.round_busy_s = r.f64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_after_round_pays_the_delay() {
        let mut s = Scheduler::new(4, 2);
        s.on_round(100.0, 30.0);
        assert_eq!(s.device_free_at(), 130.0);
        // a batch due mid-round starts when the round ends
        let start = s.admit_serve(110.0, 2.0);
        assert_eq!(start, 130.0);
        assert_eq!(s.device_free_at(), 132.0);
        // an idle-device batch starts at its deadline
        let start = s.admit_serve(200.0, 2.0);
        assert_eq!(start, 200.0);
    }

    #[test]
    fn defers_under_backlog_with_starvation_cap() {
        let mut s = Scheduler::new(4, 2);
        assert_eq!(s.consider_round(0), RoundDecision::Proceed);
        assert_eq!(s.consider_round(3), RoundDecision::Proceed);
        assert_eq!(s.consider_round(4), RoundDecision::Defer);
        assert_eq!(s.consider_round(9), RoundDecision::Defer);
        // third consecutive trigger under backlog: cap forces the round
        assert_eq!(s.consider_round(9), RoundDecision::Proceed);
        // cap resets after a round proceeds
        assert_eq!(s.consider_round(5), RoundDecision::Defer);
        assert_eq!(s.rounds_deferred(), 3);
    }

    #[test]
    fn earliest_completion_is_the_idle_or_busy_bound() {
        let mut s = Scheduler::new(0, 0);
        // idle device: arrival + service
        assert_eq!(s.earliest_completion(10.0, 2.0), 12.0);
        s.on_round(10.0, 30.0); // busy until 40.0
        assert_eq!(s.earliest_completion(10.0, 2.0), 42.0);
        assert_eq!(s.earliest_completion(50.0, 2.0), 52.0);
    }

    #[test]
    fn busy_accumulators_split_serving_from_tuning() {
        let mut s = Scheduler::new(0, 0);
        s.on_round(0.0, 30.0);
        s.admit_serve(10.0, 2.0);
        s.admit_serve(40.0, 3.0);
        s.on_round(100.0, 20.0);
        assert!((s.round_busy_s() - 50.0).abs() < 1e-12);
        assert!((s.serve_busy_s() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn zero_threshold_never_defers() {
        let mut s = Scheduler::new(0, 2);
        assert_eq!(s.consider_round(1000), RoundDecision::Proceed);
        assert_eq!(s.rounds_deferred(), 0);
    }
}
