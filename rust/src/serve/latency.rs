//! Per-request latency and SLO accounting for the serving engine.
//!
//! The simulation's [`crate::cost::energy::CostBook`] deliberately charges
//! only *fine-tuning* costs (the paper's Fig. 3/8/9 metrics never include
//! the inference pass), so serving keeps its own ledger: queueing delay in
//! virtual time plus the batched service time of one fixed-shape execute,
//! priced through the same [`DeviceModel`] the training ledger uses.
//!
//! Since PR 7 the samples live in [`Histogram`]s
//! ([`crate::metrics::hist`]) instead of raw `Vec<f64>`s: log-bucketed
//! counts make the distributions mergeable across sweep workers, while the
//! exact sample set is retained so percentiles stay *nearest-rank over the
//! exact samples* — bit-identical to the sorted-`Vec` math this module
//! used before (asserted by `percentiles_match_legacy_sorted_vec` below).
//!
//! Since the scenario-sharded control plane (PR 5) the ledger also keys
//! every observation by scenario — mixed-scenario load means one
//! scenario's burst can starve another's tail, which a global percentile
//! hides — and tracks *deadline misses* separately from SLO violations:
//! with crafted per-request deadlines (the EDF path) a request can miss
//! its own deadline while staying under the global SLO, and vice versa.

use std::collections::BTreeMap;

use crate::cost::device::DeviceModel;
use crate::cost::flops;
use crate::metrics::hist::Histogram;
use crate::metrics::ScenarioLatency;
use crate::runtime::artifact::ModelManifest;

/// End-of-run latency/SLO digest (all times in milliseconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    pub violations: u64,
    /// Fraction of requests served within the SLO (1.0 when none missed).
    pub attainment: f64,
}

/// Per-scenario slice of the ledger.
#[derive(Clone, Debug, Default)]
struct ScenarioLedger {
    hist: Histogram,
    deadline_misses: u64,
}

/// Serving-side cost model + latency ledger.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// Service time of one padded execute: the artifact always computes
    /// all `batch_infer` rows, occupied or padding.
    exec_s: f64,
    slo_s: f64,
    hist: Histogram,
    violations: u64,
    deadline_misses: u64,
    queue_delay_total_s: f64,
    service_total_s: f64,
    /// scenario -> its own latency histogram + miss count (BTreeMap keeps
    /// report emission deterministic).
    per_scenario: BTreeMap<usize, ScenarioLedger>,
}

impl LatencyModel {
    pub fn new(device: &DeviceModel, m: &ModelManifest, slo_s: f64) -> LatencyModel {
        LatencyModel {
            exec_s: device.compute_s(flops::infer_flops(m, m.batch_infer)),
            slo_s,
            hist: Histogram::new(),
            violations: 0,
            deadline_misses: 0,
            queue_delay_total_s: 0.0,
            service_total_s: 0.0,
            per_scenario: BTreeMap::new(),
        }
    }

    /// Virtual service time of one padded artifact execution.
    pub fn exec_s(&self) -> f64 {
        self.exec_s
    }

    pub fn slo_s(&self) -> f64 {
        self.slo_s
    }

    /// Record one padded execute's device occupancy (once per execute —
    /// requests sharing a batch share its service time).
    pub fn charge_execute(&mut self, service_s: f64) {
        self.service_total_s += service_s;
    }

    /// Record one served request of `scenario`; returns its end-to-end
    /// latency (s).  `deadline_missed` is computed by the engine from the
    /// request's own `deadline_t` (which need not be `arrival + SLO`).
    pub fn observe(
        &mut self,
        scenario: usize,
        queue_delay_s: f64,
        service_s: f64,
        deadline_missed: bool,
    ) -> f64 {
        debug_assert!(queue_delay_s >= 0.0, "negative queue delay");
        let latency = queue_delay_s + service_s;
        self.hist.record(latency);
        self.queue_delay_total_s += queue_delay_s;
        if latency > self.slo_s {
            self.violations += 1;
        }
        let led = self.per_scenario.entry(scenario).or_default();
        led.hist.record(latency);
        if deadline_missed {
            led.deadline_misses += 1;
            self.deadline_misses += 1;
        }
        latency
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Served requests whose completion passed their own `deadline_t`.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses
    }

    /// Total virtual time requests spent waiting for the device.
    pub fn queue_delay_total_s(&self) -> f64 {
        self.queue_delay_total_s
    }

    /// Total virtual device occupancy across executes (via
    /// [`Self::charge_execute`], once per padded execute).
    pub fn service_total_s(&self) -> f64 {
        self.service_total_s
    }

    /// The global end-to-end latency distribution (seconds).
    pub fn hist(&self) -> &Histogram {
        &self.hist
    }

    /// Per-scenario latency histograms in ascending scenario order, for
    /// export into the report's [`crate::metrics::hist::HistRegistry`].
    pub fn scenario_hists(&self) -> impl Iterator<Item = (usize, &Histogram)> {
        self.per_scenario.iter().map(|(&s, led)| (s, &led.hist))
    }

    /// Per-scenario `(scenario, histogram, deadline_misses)` triples in
    /// ascending scenario order — the raw ledgers the fleet layer merges
    /// across engines before recomputing scenario digests.
    pub fn scenario_ledgers(
        &self,
    ) -> impl Iterator<Item = (usize, &Histogram, u64)> {
        self.per_scenario
            .iter()
            .map(|(&s, led)| (s, &led.hist, led.deadline_misses))
    }

    /// Nearest-rank percentile of recorded latencies, in milliseconds.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.hist.percentile(p) * 1e3
    }

    /// Per-scenario latency digests in ascending scenario order
    /// ([`crate::metrics::Report::per_scenario_latency`]).
    pub fn per_scenario(&self) -> Vec<ScenarioLatency> {
        self.per_scenario
            .iter()
            .map(|(&scenario, led)| ScenarioLatency {
                scenario,
                requests: led.hist.count(),
                mean_ms: led.hist.mean() * 1e3,
                p95_ms: led.hist.percentile(95.0) * 1e3,
                max_ms: led.hist.max() * 1e3,
                deadline_misses: led.deadline_misses,
            })
            .collect()
    }

    /// Checkpoint the ledger (`exec_s`/`slo_s` are config, rebuilt on
    /// restore).  Histograms persist as their exact sample sets and are
    /// rebuilt by re-recording — bucket counts are a pure function of the
    /// samples, so the round trip is bit-exact.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.f64s(self.hist.samples());
        w.u64(self.violations);
        w.u64(self.deadline_misses);
        w.f64(self.queue_delay_total_s);
        w.f64(self.service_total_s);
        w.usize(self.per_scenario.len());
        for (&s, led) in &self.per_scenario {
            w.usize(s);
            w.f64s(led.hist.samples());
            w.u64(led.deadline_misses);
        }
    }

    /// Restore state saved by [`LatencyModel::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        let mut hist = Histogram::new();
        for v in r.f64s()? {
            hist.record(v);
        }
        self.hist = hist;
        self.violations = r.u64()?;
        self.deadline_misses = r.u64()?;
        self.queue_delay_total_s = r.f64()?;
        self.service_total_s = r.f64()?;
        self.per_scenario.clear();
        let n = r.usize()?;
        for _ in 0..n {
            let s = r.usize()?;
            let mut led = ScenarioLedger::default();
            for v in r.f64s()? {
                led.hist.record(v);
            }
            led.deadline_misses = r.u64()?;
            self.per_scenario.insert(s, led);
        }
        Ok(())
    }

    pub fn summary(&self) -> LatencySummary {
        let n = self.hist.count();
        if n == 0 {
            return LatencySummary { attainment: 1.0, ..LatencySummary::default() };
        }
        LatencySummary {
            p50_ms: self.hist.percentile(50.0) * 1e3,
            p95_ms: self.hist.percentile(95.0) * 1e3,
            p99_ms: self.hist.percentile(99.0) * 1e3,
            mean_ms: self.hist.mean() * 1e3,
            max_ms: self.hist.max() * 1e3,
            violations: self.violations,
            attainment: 1.0 - self.violations as f64 / n as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(slo_s: f64) -> LatencyModel {
        LatencyModel {
            exec_s: 0.010,
            slo_s,
            hist: Histogram::new(),
            violations: 0,
            deadline_misses: 0,
            queue_delay_total_s: 0.0,
            service_total_s: 0.0,
            per_scenario: BTreeMap::new(),
        }
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let mut lm = model(1.0);
        for i in 1..=100 {
            lm.observe(0, i as f64 * 1e-3, 0.0, false);
        }
        assert!((lm.percentile_ms(50.0) - 50.0).abs() < 1e-9);
        assert!((lm.percentile_ms(95.0) - 95.0).abs() < 1e-9);
        assert!((lm.percentile_ms(99.0) - 99.0).abs() < 1e-9);
        let s = lm.summary();
        assert!((s.max_ms - 100.0).abs() < 1e-9);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
    }

    /// The histogram-backed percentiles must be *bit-identical* to the
    /// sorted-`Vec` nearest-rank math this module used before PR 7.
    #[test]
    fn percentiles_match_legacy_sorted_vec() {
        fn legacy(samples: &[f64], p: f64) -> f64 {
            if samples.is_empty() {
                return 0.0;
            }
            let mut sorted = samples.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let r = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
            sorted[r.clamp(1, sorted.len()) - 1] * 1e3
        }
        let mut lm = model(0.5);
        let mut raw = Vec::new();
        let mut x = 0.013f64;
        for i in 0..313 {
            x = (x * 3.9 * (1.0 - x)).abs().max(1e-6); // logistic-map jitter
            let q = x * 0.8;
            let svc = 0.002 + (i % 7) as f64 * 1e-4;
            lm.observe(i % 3, q, svc, false);
            raw.push(q + svc);
        }
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(
                lm.percentile_ms(p).to_bits(),
                legacy(&raw, p).to_bits(),
                "p{p} drifted from the legacy sorted-Vec value"
            );
        }
    }

    #[test]
    fn slo_violations_counted_strictly_above() {
        let mut lm = model(0.050);
        lm.observe(1, 0.049, 0.0, false);
        lm.observe(1, 0.050, 0.0, false); // exactly at SLO: not a violation
        lm.observe(1, 0.051, 0.0, true);
        lm.observe(2, 0.200, 0.0, true);
        assert_eq!(lm.violations(), 2);
        assert_eq!(lm.deadline_misses(), 2);
        let s = lm.summary();
        assert!((s.attainment - 0.5).abs() < 1e-12);
    }

    #[test]
    fn per_scenario_ledgers_split_the_samples() {
        let mut lm = model(0.100);
        lm.observe(3, 0.010, 0.0, false);
        lm.observe(1, 0.020, 0.0, true);
        lm.observe(3, 0.030, 0.0, false);
        let per = lm.per_scenario();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].scenario, 1, "ascending scenario order");
        assert_eq!(per[0].requests, 1);
        assert_eq!(per[0].deadline_misses, 1);
        assert!((per[0].mean_ms - 20.0).abs() < 1e-9);
        assert_eq!(per[1].scenario, 3);
        assert_eq!(per[1].requests, 2);
        assert!((per[1].mean_ms - 20.0).abs() < 1e-9);
        assert!((per[1].max_ms - 30.0).abs() < 1e-9);
        assert_eq!(per[1].deadline_misses, 0);
    }

    #[test]
    fn empty_summary_is_benign() {
        let lm = model(1.0);
        let s = lm.summary();
        assert_eq!(s.violations, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert!((s.attainment - 1.0).abs() < 1e-12);
    }
}
