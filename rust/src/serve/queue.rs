//! The serving request queue: inference requests that have arrived (their
//! test draw is already materialized — sampling happens at arrival time so
//! the world RNG stream is consumed in event order) but have not yet been
//! executed.  The [`crate::serve::AdaptiveBatcher`] decides when a prefix
//! of this queue becomes one padded artifact execution.

use std::collections::VecDeque;

/// One pending inference request.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    /// Virtual arrival time (the event-stream timestamp).
    pub arrival_t: f64,
    /// Latency deadline: `arrival_t + SLO`.
    pub deadline_t: f64,
    /// Scenario active when the request arrived (fixes the serving head:
    /// requests of different scenarios never share an execute).
    pub scenario: usize,
    /// Training batches buffered but not yet trained on at arrival (the
    /// model-staleness proxy recorded per request since the seed).
    pub stale_batches: usize,
    /// Test draw, row-major `[rows, d]`.
    pub x: Vec<f32>,
    /// Ground-truth labels, `rows` long.
    pub y: Vec<i32>,
    /// Rows this request contributes to a padded batch.
    pub rows: usize,
}

/// FIFO of pending requests with depth instrumentation.
#[derive(Debug, Default)]
pub struct RequestQueue {
    q: VecDeque<QueuedRequest>,
    peak_depth: usize,
    total_enqueued: u64,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    pub fn push(&mut self, req: QueuedRequest) {
        self.q.push_back(req);
        self.total_enqueued += 1;
        self.peak_depth = self.peak_depth.max(self.q.len());
    }

    pub fn pop(&mut self) -> Option<QueuedRequest> {
        self.q.pop_front()
    }

    /// Oldest pending request (the batching window anchors on it).
    pub fn front(&self) -> Option<&QueuedRequest> {
        self.q.front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Rows pending across all queued requests.
    pub fn rows_pending(&self) -> usize {
        self.q.iter().map(|r| r.rows).sum()
    }

    /// Deepest the queue has ever been (backlog instrumentation).
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    pub fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: f64, scenario: usize, rows: usize) -> QueuedRequest {
        QueuedRequest {
            arrival_t: t,
            deadline_t: t + 0.25,
            scenario,
            stale_batches: 0,
            x: vec![0.0; rows * 4],
            y: vec![0; rows],
            rows,
        }
    }

    #[test]
    fn fifo_order_and_depth_tracking() {
        let mut q = RequestQueue::new();
        assert!(q.is_empty());
        q.push(req(1.0, 1, 2));
        q.push(req(2.0, 1, 3));
        q.push(req(3.0, 2, 1));
        assert_eq!(q.len(), 3);
        assert_eq!(q.rows_pending(), 6);
        assert_eq!(q.peak_depth(), 3);
        assert_eq!(q.front().unwrap().arrival_t, 1.0);
        assert_eq!(q.pop().unwrap().arrival_t, 1.0);
        assert_eq!(q.pop().unwrap().arrival_t, 2.0);
        q.push(req(4.0, 2, 1));
        // peak depth is historical, not current
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak_depth(), 3);
        assert_eq!(q.total_enqueued(), 4);
    }
}
