//! The serving request queue: inference requests that have arrived (their
//! test draw is already materialized — sampling happens at arrival time so
//! the world RNG stream is consumed in event order) but have not yet been
//! executed.  The queue itself is ordering-agnostic: the
//! [`crate::serve::AdaptiveBatcher`] pops requests at positions chosen by
//! the engine's [`crate::serve::AdmissionPolicy`] (FIFO front, or EDF's
//! earliest deadline) and decides when they become one padded artifact
//! execution.

use std::collections::VecDeque;

/// One pending inference request.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    /// Virtual arrival time (the event-stream timestamp).
    pub arrival_t: f64,
    /// Latency deadline in virtual time.  The simulator derives it as
    /// `arrival_t + SLO`, but the control plane treats it as the
    /// request's own contract: EDF orders by it and deadline-miss
    /// accounting tests against it, so library callers may set any
    /// per-request value (it need not be uniform across requests).
    pub deadline_t: f64,
    /// Scenario active when the request arrived (fixes the serving head:
    /// requests of different scenarios never share an execute).
    pub scenario: usize,
    /// Training batches buffered but not yet trained on at arrival (the
    /// model-staleness proxy recorded per request since the seed).
    pub stale_batches: usize,
    /// Test draw, row-major `[rows, d]`.
    pub x: Vec<f32>,
    /// Ground-truth labels, `rows` long.
    pub y: Vec<i32>,
    /// Rows this request contributes to a padded batch.
    pub rows: usize,
}

/// Arrival-ordered pending requests with depth instrumentation.
#[derive(Debug, Default)]
pub struct RequestQueue {
    q: VecDeque<QueuedRequest>,
    peak_depth: usize,
    total_enqueued: u64,
    /// Running sum of queued rows, maintained on push/pop/remove so the
    /// per-poll capacity check is O(1) even on deep backlogs (the queue
    /// is unbounded unless `--max-queue` is set).
    rows_pending: usize,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    pub fn push(&mut self, req: QueuedRequest) {
        self.rows_pending += req.rows;
        self.q.push_back(req);
        self.total_enqueued += 1;
        self.peak_depth = self.peak_depth.max(self.q.len());
    }

    pub fn pop(&mut self) -> Option<QueuedRequest> {
        let r = self.q.pop_front();
        if let Some(r) = &r {
            self.rows_pending -= r.rows;
        }
        r
    }

    /// Oldest pending request (what FIFO anchors the window on).
    pub fn front(&self) -> Option<&QueuedRequest> {
        self.q.front()
    }

    /// Pending request at queue position `i` (0 = oldest).
    pub fn get(&self, i: usize) -> Option<&QueuedRequest> {
        self.q.get(i)
    }

    /// Remove and return the request at position `i` (the EDF pop path;
    /// the element shift is O(n) per pop, which is fine at edge queue
    /// depths — the O(n) *row summation* per poll is what the cached
    /// counter avoids).
    pub fn remove(&mut self, i: usize) -> Option<QueuedRequest> {
        let r = self.q.remove(i);
        if let Some(r) = &r {
            self.rows_pending -= r.rows;
        }
        r
    }

    /// Iterate pending requests in position (arrival) order.
    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.q.iter()
    }

    /// Reinsert requests at the queue front, preserving their order
    /// (error recovery: a failed flush puts its unserved requests back).
    /// Not counted as new arrivals — `total_enqueued` and `peak_depth`
    /// stay put (the depth can only return to a level already peaked).
    pub fn requeue_front(&mut self, reqs: Vec<QueuedRequest>) {
        for req in reqs.into_iter().rev() {
            self.rows_pending += req.rows;
            self.q.push_front(req);
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Rows pending across all queued requests (O(1): maintained on
    /// push/pop/remove).
    pub fn rows_pending(&self) -> usize {
        self.rows_pending
    }

    /// Deepest the queue has ever been (backlog instrumentation).
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    pub fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: f64, scenario: usize, rows: usize) -> QueuedRequest {
        QueuedRequest {
            arrival_t: t,
            deadline_t: t + 0.25,
            scenario,
            stale_batches: 0,
            x: vec![0.0; rows * 4],
            y: vec![0; rows],
            rows,
        }
    }

    #[test]
    fn fifo_order_and_depth_tracking() {
        let mut q = RequestQueue::new();
        assert!(q.is_empty());
        q.push(req(1.0, 1, 2));
        q.push(req(2.0, 1, 3));
        q.push(req(3.0, 2, 1));
        assert_eq!(q.len(), 3);
        assert_eq!(q.rows_pending(), 6);
        assert_eq!(q.peak_depth(), 3);
        assert_eq!(q.front().unwrap().arrival_t, 1.0);
        assert_eq!(q.pop().unwrap().arrival_t, 1.0);
        assert_eq!(q.pop().unwrap().arrival_t, 2.0);
        q.push(req(4.0, 2, 1));
        // peak depth is historical, not current
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak_depth(), 3);
        assert_eq!(q.total_enqueued(), 4);
    }

    #[test]
    fn positional_access_supports_policy_pops() {
        let mut q = RequestQueue::new();
        q.push(req(1.0, 1, 2));
        q.push(req(2.0, 1, 3));
        q.push(req(3.0, 2, 1));
        assert_eq!(q.get(1).unwrap().arrival_t, 2.0);
        assert!(q.get(3).is_none());
        assert_eq!(
            q.iter().map(|r| r.arrival_t).collect::<Vec<_>>(),
            vec![1.0, 2.0, 3.0]
        );
        // out-of-order removal (what EDF does) keeps the rest in order
        assert_eq!(q.remove(1).unwrap().arrival_t, 2.0);
        assert_eq!(q.len(), 2);
        assert_eq!(q.rows_pending(), 3);
        assert_eq!(q.front().unwrap().arrival_t, 1.0);
        assert_eq!(q.get(1).unwrap().arrival_t, 3.0);
    }
}
