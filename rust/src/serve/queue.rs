//! The serving request queue: inference requests that have arrived (their
//! test draw is already materialized — sampling happens at arrival time so
//! the world RNG stream is consumed in event order) but have not yet been
//! executed.  The queue itself is ordering-agnostic: the
//! [`crate::serve::AdaptiveBatcher`] pops requests at positions chosen by
//! the engine's [`crate::serve::AdmissionPolicy`] (FIFO front, or EDF's
//! earliest deadline) and decides when they become one padded artifact
//! execution.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Monotone order-preserving `u64` key for a (non-NaN) `f64` deadline:
/// the sign-flip bit trick, with `-0.0` normalized to `+0.0` so
/// numerically equal deadlines compare equal — exactly the naive scan's
/// `<` semantics, which the EDF side-index must reproduce bit-for-bit.
fn deadline_key(v: f64) -> u64 {
    let v = if v == 0.0 { 0.0 } else { v };
    let b = v.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// One pending inference request.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    /// Virtual arrival time (the event-stream timestamp).
    pub arrival_t: f64,
    /// Latency deadline in virtual time.  The simulator derives it as
    /// `arrival_t + SLO`, but the control plane treats it as the
    /// request's own contract: EDF orders by it and deadline-miss
    /// accounting tests against it, so library callers may set any
    /// per-request value (it need not be uniform across requests).
    pub deadline_t: f64,
    /// Scenario active when the request arrived (fixes the serving head:
    /// requests of different scenarios never share an execute).
    pub scenario: usize,
    /// Training batches buffered but not yet trained on at arrival (the
    /// model-staleness proxy recorded per request since the seed).
    pub stale_batches: usize,
    /// Test draw, row-major `[rows, d]`.
    pub x: Vec<f32>,
    /// Ground-truth labels, `rows` long.
    pub y: Vec<i32>,
    /// Rows this request contributes to a padded batch.
    pub rows: usize,
}

/// Arrival-ordered pending requests with depth instrumentation.
#[derive(Debug, Default)]
pub struct RequestQueue {
    q: VecDeque<QueuedRequest>,
    /// Arrival sequence numbers parallel to `q`, strictly ascending in
    /// position order (requeue renumbers to restore the invariant).
    /// They double as the EDF tie-break key: ascending seq == ascending
    /// queue position, so the heap's `(deadline, seq)` min is exactly
    /// the naive scan's first-lowest-index-among-earliest-deadlines.
    seqs: VecDeque<u64>,
    next_seq: u64,
    /// Lazy EDF side-index: a min-heap of `(deadline_key, seq)` built on
    /// the first [`RequestQueue::edf_next_index`] call and maintained on
    /// push.  Pops and removals leave stale entries behind (lazy
    /// deletion: a peeked seq no longer in `seqs` is discarded), so an
    /// EDF flush is amortized O(log n) per pop instead of the naive
    /// scan's O(n).  `None` = not built yet, or invalidated by
    /// [`RequestQueue::requeue_front`]'s renumbering.
    edf: RefCell<Option<BinaryHeap<Reverse<(u64, u64)>>>>,
    peak_depth: usize,
    total_enqueued: u64,
    /// Running sum of queued rows, maintained on push/pop/remove so the
    /// per-poll capacity check is O(1) even on deep backlogs (the queue
    /// is unbounded unless `--max-queue` is set).
    rows_pending: usize,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    pub fn push(&mut self, req: QueuedRequest) {
        if let Some(heap) = self.edf.get_mut().as_mut() {
            heap.push(Reverse((deadline_key(req.deadline_t), self.next_seq)));
        }
        self.seqs.push_back(self.next_seq);
        self.next_seq += 1;
        self.rows_pending += req.rows;
        self.q.push_back(req);
        self.total_enqueued += 1;
        self.peak_depth = self.peak_depth.max(self.q.len());
    }

    pub fn pop(&mut self) -> Option<QueuedRequest> {
        let r = self.q.pop_front();
        if let Some(r) = &r {
            self.seqs.pop_front();
            self.rows_pending -= r.rows;
        }
        r
    }

    /// Oldest pending request (what FIFO anchors the window on).
    pub fn front(&self) -> Option<&QueuedRequest> {
        self.q.front()
    }

    /// Pending request at queue position `i` (0 = oldest).
    pub fn get(&self, i: usize) -> Option<&QueuedRequest> {
        self.q.get(i)
    }

    /// Remove and return the request at position `i` (the EDF pop path;
    /// the element shift is O(n) per pop, which is fine at edge queue
    /// depths — the O(n) *row summation* per poll is what the cached
    /// counter avoids).
    pub fn remove(&mut self, i: usize) -> Option<QueuedRequest> {
        let r = self.q.remove(i);
        if let Some(r) = &r {
            self.seqs.remove(i);
            self.rows_pending -= r.rows;
        }
        r
    }

    /// Iterate pending requests in position (arrival) order.
    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.q.iter()
    }

    /// Reinsert requests at the queue front, preserving their order
    /// (error recovery: a failed flush puts its unserved requests back).
    /// Not counted as new arrivals — `total_enqueued` and `peak_depth`
    /// stay put (the depth can only return to a level already peaked).
    pub fn requeue_front(&mut self, reqs: Vec<QueuedRequest>) {
        for req in reqs.into_iter().rev() {
            self.rows_pending += req.rows;
            self.q.push_front(req);
        }
        // Prepending would need seqs below the current front; renumber
        // every position instead and drop the heap (rebuilt on the next
        // EDF pop).  O(n), but requeues only happen on the rare flush-
        // failure recovery path.
        self.seqs.clear();
        self.seqs.extend(0..self.q.len() as u64);
        self.next_seq = self.q.len() as u64;
        *self.edf.get_mut() = None;
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Rows pending across all queued requests (O(1): maintained on
    /// push/pop/remove).
    pub fn rows_pending(&self) -> usize {
        self.rows_pending
    }

    /// Deepest the queue has ever been (backlog instrumentation).
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    pub fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    /// Checkpoint the depth instrumentation and the arrival sequence
    /// counter.  The queue's *contents* are never persisted: every round
    /// boundary is a quiesce point (the simulation drains before
    /// checkpointing), so only the counters survive a resume.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        debug_assert!(self.q.is_empty(), "checkpointing a non-empty queue");
        w.usize(self.peak_depth);
        w.u64(self.total_enqueued);
        w.u64(self.next_seq);
    }

    /// Restore state saved by [`RequestQueue::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        self.peak_depth = r.usize()?;
        self.total_enqueued = r.u64()?;
        self.next_seq = r.u64()?;
        Ok(())
    }

    /// Queue position of the earliest-deadline request (ties: lowest
    /// position), or `None` when empty — the amortized backend of
    /// [`crate::serve::admission::Edf::next_index`], bit-identical to a
    /// naive full scan with strict-`<` comparison (pinned by tests here
    /// and in `serve/admission.rs`).  Deadlines must not be NaN (they
    /// never are: every producer derives them from finite virtual time).
    ///
    /// Amortized O(log n) per pop: the side-index min-heap is built once
    /// per backlog (and after a requeue), maintained on push, and stale
    /// entries from pops/removals are discarded lazily on peek.
    pub fn edf_next_index(&self) -> Option<usize> {
        if self.q.is_empty() {
            return None;
        }
        let mut slot = self.edf.borrow_mut();
        let heap = slot.get_or_insert_with(|| {
            self.q
                .iter()
                .zip(self.seqs.iter())
                .map(|(r, &s)| Reverse((deadline_key(r.deadline_t), s)))
                .collect()
        });
        loop {
            // Every live seq has a heap entry (built from the live queue,
            // maintained on push, only invalidated wholesale), so a
            // non-empty queue guarantees a live peek eventually.
            let Reverse((_, seq)) =
                *heap.peek().expect("heap covers all live requests");
            match self.seqs.binary_search(&seq) {
                Ok(i) => return Some(i),
                Err(_) => {
                    heap.pop();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: f64, scenario: usize, rows: usize) -> QueuedRequest {
        QueuedRequest {
            arrival_t: t,
            deadline_t: t + 0.25,
            scenario,
            stale_batches: 0,
            x: vec![0.0; rows * 4],
            y: vec![0; rows],
            rows,
        }
    }

    #[test]
    fn fifo_order_and_depth_tracking() {
        let mut q = RequestQueue::new();
        assert!(q.is_empty());
        q.push(req(1.0, 1, 2));
        q.push(req(2.0, 1, 3));
        q.push(req(3.0, 2, 1));
        assert_eq!(q.len(), 3);
        assert_eq!(q.rows_pending(), 6);
        assert_eq!(q.peak_depth(), 3);
        assert_eq!(q.front().unwrap().arrival_t, 1.0);
        assert_eq!(q.pop().unwrap().arrival_t, 1.0);
        assert_eq!(q.pop().unwrap().arrival_t, 2.0);
        q.push(req(4.0, 2, 1));
        // peak depth is historical, not current
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak_depth(), 3);
        assert_eq!(q.total_enqueued(), 4);
    }

    #[test]
    fn positional_access_supports_policy_pops() {
        let mut q = RequestQueue::new();
        q.push(req(1.0, 1, 2));
        q.push(req(2.0, 1, 3));
        q.push(req(3.0, 2, 1));
        assert_eq!(q.get(1).unwrap().arrival_t, 2.0);
        assert!(q.get(3).is_none());
        assert_eq!(
            q.iter().map(|r| r.arrival_t).collect::<Vec<_>>(),
            vec![1.0, 2.0, 3.0]
        );
        // out-of-order removal (what EDF does) keeps the rest in order
        assert_eq!(q.remove(1).unwrap().arrival_t, 2.0);
        assert_eq!(q.len(), 2);
        assert_eq!(q.rows_pending(), 3);
        assert_eq!(q.front().unwrap().arrival_t, 1.0);
        assert_eq!(q.get(1).unwrap().arrival_t, 3.0);
    }

    /// The naive scan `Edf::next_index` used before the side-index: the
    /// parity oracle, kept verbatim.
    fn naive_edf(q: &RequestQueue) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in q.iter().enumerate() {
            if best.is_none_or(|(_, d)| r.deadline_t < d) {
                best = Some((i, r.deadline_t));
            }
        }
        best.map(|(i, _)| i)
    }

    fn req_d(deadline_t: f64) -> QueuedRequest {
        QueuedRequest { deadline_t, ..req(0.0, 0, 1) }
    }

    #[test]
    fn deadline_key_is_monotone_over_ugly_floats() {
        let vals = [
            f64::NEG_INFINITY,
            -1e9,
            -1.5,
            -0.0,
            0.0,
            1e-300,
            0.25,
            1.0,
            1e9,
            1e15,
            f64::INFINITY,
        ];
        for w in vals.windows(2) {
            if w[0] == w[1] {
                assert_eq!(deadline_key(w[0]), deadline_key(w[1]));
            } else {
                assert!(deadline_key(w[0]) < deadline_key(w[1]));
            }
        }
    }

    #[test]
    fn edf_side_index_matches_naive_scan_with_ties() {
        let mut q = RequestQueue::new();
        // deterministic pseudo-random deadlines with deliberate ties
        let mut x = 7u64;
        for i in 0..64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let d = ((x >> 33) % 16) as f64 * 0.5; // few distinct values -> many ties
            q.push(req_d(d));
            if i % 3 == 0 {
                // interleave pops so the heap carries stale entries
                let want = naive_edf(&q);
                assert_eq!(q.edf_next_index(), want);
                q.remove(want.unwrap());
            }
        }
        // drain in EDF order: every pop must agree with the naive scan
        while !q.is_empty() {
            let want = naive_edf(&q);
            assert_eq!(q.edf_next_index(), want, "depth {}", q.len());
            q.remove(want.unwrap());
        }
        assert_eq!(q.edf_next_index(), None);
    }

    #[test]
    fn edf_side_index_survives_requeue_and_front_pops() {
        let mut q = RequestQueue::new();
        for d in [9.0, 3.0, 3.0, 7.0, 1.0, 3.0] {
            q.push(req_d(d));
        }
        assert_eq!(q.edf_next_index(), Some(4)); // the lone 1.0
        // FIFO-style front pop invalidates nothing (lazy deletion)
        q.pop();
        assert_eq!(q.edf_next_index(), naive_edf(&q));
        // recovery requeue renumbers positions and rebuilds the heap
        let a = q.remove(q.edf_next_index().unwrap()).unwrap();
        let b = q.remove(q.edf_next_index().unwrap()).unwrap();
        q.requeue_front(vec![a, b]);
        assert_eq!(q.edf_next_index(), naive_edf(&q));
        assert_eq!(q.edf_next_index(), Some(0), "requeued 1.0 leads again");
        // pushes after a rebuild keep extending the live heap
        q.push(req_d(0.5));
        assert_eq!(q.edf_next_index(), naive_edf(&q));
        assert_eq!(q.edf_next_index(), Some(q.len() - 1));
        while let Some(i) = q.edf_next_index() {
            assert_eq!(Some(i), naive_edf(&q));
            q.remove(i);
        }
    }
}
