//! # The serving engine (cross-request batching + latency/SLO accounting)
//!
//! EdgeOL's deployment premise is *in-situ online learning*: one edge
//! accelerator both serves streaming inference requests and fine-tunes the
//! deployed model.  The seed implementation executed one fixed-shape
//! artifact per request with no notion of queueing, latency, or contention
//! with fine-tuning rounds.  This module is the subsystem between the
//! event stream and [`crate::model::ModelSession`]:
//!
//! * [`queue`] — pending requests with arrival times, deadlines, and their
//!   already-drawn test rows (sampling at arrival keeps the world RNG
//!   stream in event order);
//! * [`batcher`] — coalesces consecutive same-scenario requests into one
//!   padded `[batch_infer, d]` execute within a virtual-time window, and
//!   scatters per-request predictions/energy scores back out;
//! * [`latency`] — queueing delay + batched service time priced through
//!   [`crate::cost::device::DeviceModel`]; p50/p95/p99 digests and
//!   SLO-violation counts;
//! * [`scheduler`] — arbitrates the single device between fine-tuning
//!   rounds and inference bursts: requests arriving mid-round pay the
//!   delay, and a triggered round can be deferred under backlog (bounded
//!   by a starvation cap), feeding LazyTune's request-pressure term a real
//!   queue depth;
//! * [`engine`] — the glue object the simulation drives (`submit`/`pump`/
//!   `drain`), which also owns the cached bank-installed serving θ.
//!
//! **Determinism contract:** everything here runs in virtual time off the
//! seeded event stream.  With `batch_window_s == 0` every batch holds
//! exactly one full-draw request and reports are bit-identical to the
//! pre-engine serving path (enforced by `tests/serving_engine.rs`); the
//! latency/batch fields are serving-side instrumentation, excluded from
//! [`crate::metrics::Report::fingerprint`] like the other perf counters.

pub mod batcher;
pub mod engine;
pub mod latency;
pub mod queue;
pub mod scheduler;

pub use batcher::{AdaptiveBatcher, BatchSpan, PaddedBatch};
pub use engine::{ServeEngine, ServedRequest};
pub use latency::{LatencyModel, LatencySummary};
pub use queue::{QueuedRequest, RequestQueue};
pub use scheduler::{RoundDecision, Scheduler};

/// Serving-engine knobs (part of [`crate::sim::RunConfig`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Virtual-time coalescing window, seconds.  `0.0` (the default)
    /// degenerates to one-request batches: bit-identical reports to the
    /// pre-engine serving path.
    pub batch_window_s: f64,
    /// Latency SLO in milliseconds (violation accounting only; no request
    /// is ever dropped).
    pub slo_ms: f64,
    /// Rows drawn per request.  `None` (the default) keeps the seed's
    /// full `batch_infer` draw when the window is 0 and picks
    /// `batch_infer / 8` (≥ 1) when a real window is set; `Some(r)`
    /// forces `r` (clamped to the batch capacity).  Ignored entirely in
    /// `--no-batching` mode, which always uses the full draw.
    pub rows_per_request: Option<usize>,
    /// Queue depth at which the scheduler defers a triggered round
    /// (`0` = never defer).
    pub defer_backlog: usize,
    /// Starvation guard: max consecutive round deferrals.
    pub max_defers: u32,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            batch_window_s: 0.0,
            slo_ms: 250.0,
            rows_per_request: None,
            defer_backlog: 4,
            max_defers: 2,
        }
    }
}

impl ServeConfig {
    pub fn slo_s(&self) -> f64 {
        self.slo_ms / 1e3
    }

    /// Resolve the per-request row draw for an artifact batch capacity.
    pub fn rows_per_request(&self, batch_infer: usize) -> usize {
        match self.rows_per_request {
            Some(r) => r.clamp(1, batch_infer),
            None if self.batch_window_s > 0.0 => (batch_infer / 8).max(1),
            None => batch_infer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_the_degenerate_identity_mode() {
        let c = ServeConfig::default();
        assert_eq!(c.batch_window_s, 0.0);
        assert_eq!(c.rows_per_request(64), 64, "unbatched keeps the full draw");
    }

    #[test]
    fn batched_rows_default_to_an_eighth_of_capacity() {
        let mut c =
            ServeConfig { batch_window_s: 10.0, ..ServeConfig::default() };
        assert_eq!(c.rows_per_request(64), 8);
        assert_eq!(c.rows_per_request(4), 1);
        c.rows_per_request = Some(999);
        assert_eq!(c.rows_per_request(64), 64, "clamped to capacity");
    }
}
